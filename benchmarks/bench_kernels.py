"""Kernel micro-bench: Pallas (interpret on CPU) vs jnp reference.

CPU wall times are NOT TPU predictions (interpret mode is a correctness
vehicle); the derived column reports the kernels' analytic HBM-traffic
advantage — the quantity that matters at the TPU roofline:

  flash attention: jnp path writes S_q x S_k score tensors (f32) per head;
  the kernel keeps them in VMEM -> traffic ratio reported as score_bytes /
  (q+k+v+o bytes).
  rwkv/ssd: jnp scan round-trips the recurrent state through HBM every step;
  the kernel keeps it in VMEM scratch -> ratio = state traffic / io traffic.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _t(fn, *args, n=3):
    fn(*args)                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n


def run(csv_rows: list):
    rng = np.random.default_rng(0)

    # flash attention
    B, S, Hq, Hkv, D = 1, 1024, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    t_ref = _t(lambda *a: ref.flash_attention(*a), q, k, v)
    score_bytes = B * Hq * S * S * 4
    io_bytes = (q.size + k.size + v.size + q.size) * 4
    csv_rows.append(
        f"kern_flash_attention,{t_ref*1e6:.0f},"
        f"hbm_traffic_saved_ratio={score_bytes/io_bytes:.1f}x;"
        f"jnp_ref_s={t_ref:.4f}")

    # rwkv6
    B, S, H, K = 2, 512, 4, 64
    r = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(B, S, H, K)) * 0.3, jnp.float32)
    vv = jnp.asarray(rng.normal(size=(B, S, H, K)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.9, 0.999, (B, S, H, K)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)), jnp.float32)
    t_ref = _t(lambda *a: ref.rwkv6_scan(*a)[0], r, kk, vv, w, u)
    state_traffic = B * H * K * K * 4 * 2 * S          # state r/w per step
    io = (r.size * 4) * 5
    csv_rows.append(
        f"kern_rwkv6_scan,{t_ref*1e6:.0f},"
        f"hbm_traffic_saved_ratio={state_traffic/io:.1f}x;"
        f"jnp_ref_s={t_ref:.4f}")

    # ssd
    B, S, H, P, N = 2, 512, 4, 64, 16
    xdt = jnp.asarray(rng.normal(size=(B, S, H, P)) * 0.1, jnp.float32)
    la = jnp.asarray(np.log(rng.uniform(0.9, 0.999, (B, S, H))), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)) * 0.3, jnp.float32)
    t_ref = _t(lambda *a: ref.ssd_scan(*a)[0], xdt, la, Bm, Cm)
    state_traffic = B * H * N * P * 4 * 2 * S
    io = xdt.size * 4 * 2 + (Bm.size + Cm.size) * 4
    csv_rows.append(
        f"kern_ssd_scan,{t_ref*1e6:.0f},"
        f"hbm_traffic_saved_ratio={state_traffic/io:.1f}x;"
        f"jnp_ref_s={t_ref:.4f}")

    # rmsnorm fusion: 2 passes (fused) vs 4 (naive)
    x = jnp.asarray(rng.normal(size=(4096, 1024)), jnp.float32)
    s = jnp.ones((1024,), jnp.float32)
    t_ref = _t(lambda *a: ref.rmsnorm(*a), x, s)
    csv_rows.append(
        f"kern_rmsnorm,{t_ref*1e6:.0f},"
        f"hbm_traffic_saved_ratio=2.0x;jnp_ref_s={t_ref:.4f}")
