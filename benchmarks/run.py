"""Benchmark harness — one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (and tees to results/bench.csv).
A module's ``run`` may also return a JSON-serializable payload, written to
``results/BENCH_<name>.json`` — machine-readable perf tracked across PRs
(the CI uploads them as artifacts).

  bench_mcmc     paper Table 1 (task-farm MCMC)
  bench_dmc      paper Table 2 (DMC + dynamic load balancing, scaled-size)
  bench_schwarz  paper Table 3 (Boussinesq additive Schwarz speedup)
  bench_overhead paper §1/§5 (function-centric layer overhead)
  bench_runtime  executor runtime (farm speedup + cross-tier parity)
  bench_kernels  Pallas kernel suite (traffic-saving ratios)
  bench_serve    paged continuous-batching engine (tokens/s, slot scaling,
                 pages-in-use high-water, chunked-prefill anti-stall)
"""
import json
import os
import sys
import traceback


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    from benchmarks import (bench_dmc, bench_kernels, bench_mcmc,
                            bench_overhead, bench_runtime, bench_schwarz,
                            bench_serve)
    mods = {"mcmc": bench_mcmc, "dmc": bench_dmc, "schwarz": bench_schwarz,
            "overhead": bench_overhead, "runtime": bench_runtime,
            "kernels": bench_kernels, "serve": bench_serve}
    rows = ["name,us_per_call,derived"]
    payloads: dict[str, object] = {}
    failed: list[str] = []
    for name, mod in mods.items():
        if only and name != only:
            continue
        try:
            payload = mod.run(rows)
            if payload is not None:
                payloads[name] = payload
        except Exception as e:
            traceback.print_exc()
            failed.append(name)
            rows.append(f"{name},FAILED,{type(e).__name__}: {e}")
    out = "\n".join(rows)
    print(out)
    os.makedirs("results", exist_ok=True)
    with open("results/bench.csv", "w") as f:
        f.write(out + "\n")
    for name, payload in payloads.items():
        path = f"results/BENCH_{name}.json"
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"[bench] wrote {path}")
    if only and failed:
        # a specifically requested bench must not fail green (CI gates on it)
        sys.exit(1)


if __name__ == '__main__':
    main()
