"""Paper Table 3: Boussinesq/additive-Schwarz speedup.

The paper fixes a 1000x1000 mesh, 40 steps, and reports speedup vs CPUs
(91-103%).  Same structure here: fixed global grid, 40 steps, subdomain count
swept over subprocess device counts; correctness pinned by serial-vs-Schwarz
agreement (max |eta_s - eta_p|)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap


def _run(n_dev: int, steps: int = 40, ny: int = 64) -> dict:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import time, jax, numpy as np
        from repro.apps import boussinesq as bq
        p = bq.BoussinesqParams(nx=64, ny={ny}, dt=0.02, eps=0.3, alpha=0.05)
        mesh = jax.make_mesh(({n_dev},), ("data",))
        bq.run_parallel(mesh, p, steps=2)        # warmup
        t0 = time.perf_counter()
        eta_p, phi_p, hist = bq.run_parallel(mesh, p, steps={steps})
        dt = time.perf_counter() - t0
        eta_s, _, _ = bq.run_serial(p, steps={steps})
        err = float(np.abs(np.asarray(eta_s) - np.asarray(eta_p)).max())
        iters = float(np.asarray(hist["iters"]).mean())
        print("RESULT", dt, err, iters)
    """)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420,
                         env=dict(os.environ,
                                  PYTHONPATH=os.path.join(root, "src")))
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    _, dt, err, iters = line.split()
    return {"time_s": float(dt), "err": float(err), "iters": float(iters)}


def run(csv_rows: list):
    base = None
    for n in (1, 2, 4, 8):
        r = _run(n)
        base = base or r["time_s"]
        csv_rows.append(
            f"schwarz_{n}sub,{r['time_s']*1e6:.0f},"
            f"speedup={base/r['time_s']:.2f};max_err={r['err']:.2e};"
            f"schwarz_iters={r['iters']:.0f}")
