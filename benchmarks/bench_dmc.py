"""Paper Table 2: DMC scaled-size scaling with dynamic load balancing.

The paper's test keeps 200 walkers per processor and reports near-constant
wall time as processors grow (85-88% efficiency).  On one host we reproduce
the *structure*: the SPMD step is run over 1/2/4/8 fake devices in
subprocesses, walkers per shard held constant, and we report wall time +
rebalance counts.  Constant time across device counts = the paper's scaled
scalability; the load balancer's fire count shows the population dynamics."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time


def _run_spmd_dmc(n_dev: int, walkers_per_shard: int = 128,
                  steps: int = 200) -> dict:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import time, jax
        from repro.apps import dmc
        mesh = jax.make_mesh(({n_dev},), ("data",))
        # warmup compile
        dmc.run_parallel(mesh, n_walkers={walkers_per_shard * n_dev},
                         timesteps=2, tau=0.02)
        t0 = time.perf_counter()
        out = dmc.run_parallel(mesh, n_walkers={walkers_per_shard * n_dev},
                               timesteps={steps}, tau=0.02)
        dt = time.perf_counter() - t0
        print("RESULT", dt, float(out["e0_estimate"]), int(out["rebalances"]))
    """)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420,
                         env=dict(os.environ,
                                  PYTHONPATH=os.path.join(root, "src")))
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    _, dt, e0, reb = line.split()
    return {"time_s": float(dt), "e0": float(e0), "rebalances": int(reb)}


def run(csv_rows: list):
    base = None
    for n in (1, 2, 4, 8):
        r = _run_spmd_dmc(n)
        base = base or r["time_s"]
        eff = base / r["time_s"]
        csv_rows.append(
            f"dmc_{n}dev,{r['time_s']*1e6:.0f},"
            f"walkers={128*n};e0={r['e0']:.3f};rebalances={r['rebalances']};"
            f"scaled_eff={eff:.2f}")
