"""Executor-runtime benchmarks.

Two claims measured:

1. **Farm throughput** — the :class:`ThreadFarmExecutor` must beat the serial
   farm by >= 3x on 8 workers for task sets that release the GIL (device
   compute / I/O), since that is the whole point of making ``host_task_farm``
   genuinely concurrent.
2. **Cross-tier parity** — all four executors return identical results on the
   quickstart parabola problem (the acceptance criterion of the runtime
   refactor).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime import (MeshExecutor, SerialExecutor,
                                ThreadFarmExecutor, VmapExecutor)


def _farm_speedup(csv_rows, name, thunks, workers=8):
    t0 = time.perf_counter()
    serial = [t() for t in thunks]
    t_serial = time.perf_counter() - t0

    farm = ThreadFarmExecutor(num_workers=workers)
    t0 = time.perf_counter()
    threaded, stats = farm.map_callables(thunks)
    t_farm = time.perf_counter() - t0

    assert serial == threaded or np.allclose(
        np.asarray(serial, dtype=float), np.asarray(threaded, dtype=float)), name
    speedup = t_serial / max(t_farm, 1e-9)
    csv_rows.append(
        f"runtime_farm_{name},{t_farm*1e6:.0f},"
        f"serial_s={t_serial:.4f};farm_s={t_farm:.4f};"
        f"workers={workers};speedup={speedup:.2f}x;"
        f"steals={stats['steals']};rebalances={stats['rebalances']}")
    return speedup


def run(csv_rows: list):
    # -- 1a. I/O-bound task set (pure GIL release) ---------------------------
    def io_task(i):
        return lambda: (time.sleep(0.02), i)[1]

    _farm_speedup(csv_rows, "io_bound", [io_task(i) for i in range(32)])

    # -- 1b. device-bound task set (jitted programs, shapes differ per task
    # bucket — the serve engine's prefill pattern) ---------------------------
    fns = {}
    for bucket in (256, 384, 512, 640):
        f = jax.jit(lambda x: jnp.linalg.matrix_power(x @ x.T, 4).sum())
        f(jnp.eye(bucket)).block_until_ready()          # compile up front
        fns[bucket] = f

    def dev_task(i):
        bucket = (256, 384, 512, 640)[i % 4]
        x = jnp.eye(bucket) * (1.0 + 1e-6 * i)
        return lambda: float(fns[bucket](x).block_until_ready())

    _farm_speedup(csv_rows, "device_bound", [dev_task(i) for i in range(32)])

    # -- 2. four-executor parity on the quickstart problem -------------------
    M, N, L = 16, 24, 10.0
    x = jnp.linspace(0, L, N)
    vals = jnp.linspace(-1, 1, M)
    aa, bb = jnp.meshgrid(vals, vals, indexing="ij")

    def initialize():
        return {"a": aa.ravel(), "b": bb.ravel()}

    def func(task):
        return task["a"] * x ** 2 + task["b"] * x + 5.0

    def finalize(out):
        return np.asarray(out)

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    execs = {"serial": SerialExecutor(), "vmap": VmapExecutor(),
             "mesh": MeshExecutor(mesh),
             "thread": ThreadFarmExecutor(num_workers=8)}
    outs, times = {}, {}
    for name, ex in execs.items():
        t0 = time.perf_counter()
        outs[name] = ex.run(initialize, func, finalize)
        times[name] = time.perf_counter() - t0
    ref = outs["serial"]
    ok = all(np.allclose(outs[n], ref, rtol=1e-5, atol=1e-6) for n in outs)
    csv_rows.append(
        "runtime_parity," + f"{times['vmap']*1e6:.0f}," +
        ";".join(f"{n}_s={t:.4f}" for n, t in times.items()) +
        f";identical={ok}")
    assert ok, "executor tiers disagree on the quickstart problem"
