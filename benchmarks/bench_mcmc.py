"""Paper Table 1: MCMC ideal-point estimation — task-farm scaling.

The paper reports wall time vs CPUs at ~90% parallel efficiency for 5
legislatures.  On one CPU device we measure the framework analogue: chains
run (a) serially (the paper's 1-CPU column), (b) through the vmapped
task farm (the single-device parallel path), and report the layer's speedup
plus per-legislature problem scaling (members x votes, like Table 1 rows).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.apps import mcmc


# legislature sizes scaled down from the paper's Table 1 (members, votes)
LEGISLATURES = [
    ("EP1-like", 55, 80),
    ("EP2-like", 64, 120),
    ("EP3-like", 60, 160),
]


def run(csv_rows: list):
    for name, n_leg, n_votes in LEGISLATURES:
        y, truth = mcmc.make_synthetic_votes(
            jax.random.PRNGKey(1), n_leg=n_leg, n_votes=n_votes)
        prob = mcmc.IdealPointProblem(y, n_chains=4, n_iter=100, burn=50)

        # serial (paper's 1-CPU baseline)
        t0 = time.perf_counter()
        mcmc.solve_serial(prob)
        t_serial = time.perf_counter() - t0

        # vmapped task farm (single-device parallel path), incl. compile
        prob2 = mcmc.IdealPointProblem(y, n_chains=4, n_iter=100, burn=50)
        mcmc.solve_vmap(prob2)          # warmup/compile
        t0 = time.perf_counter()
        res = mcmc.solve_vmap(prob2)
        t_par = time.perf_counter() - t0

        corr = abs(np.corrcoef(np.asarray(res["x_mean"]),
                               np.asarray(truth["x"]))[0, 1])
        csv_rows.append(
            f"mcmc_{name},{t_par*1e6:.0f},serial_s={t_serial:.3f};"
            f"farm_s={t_par:.3f};speedup={t_serial/t_par:.2f};corr={corr:.3f}")
