"""Serving throughput: continuous batching vs sequential request handling.

The engine's win is slot
reuse: decode ticks amortize across live requests.  Reported: tokens/s with
max_slots=1 (sequential) vs max_slots=4 (continuous batching) on the smoke
dense model — the ratio is the batching speedup the slot machinery delivers.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine


def _throughput(model, params, slots: int, n_req: int = 8,
                max_new: int = 16):
    eng = ServeEngine(model, params, max_slots=slots, max_len=128)
    rng = np.random.default_rng(0)
    for _ in range(n_req):
        eng.submit(rng.integers(0, model.cfg.vocab, 8), max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    return toks / dt, eng.stats["ticks"], toks


def run(csv_rows: list):
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _throughput(model, params, 2, n_req=2, max_new=4)   # warm compiles

    seq, seq_ticks, toks = _throughput(model, params, slots=1)
    cb, cb_ticks, _ = _throughput(model, params, slots=4)
    csv_rows.append(f"serve_sequential,{1e6/seq:.0f},tok_per_s={seq:.1f};"
                    f"decode_ticks={seq_ticks}")
    # On memory-bound accelerators a decode tick's cost is ~flat in batch, so
    # the tick ratio is the real continuous-batching speedup; CPU tok/s is
    # compute-bound and does not show it.
    csv_rows.append(f"serve_continuous4,{1e6/cb:.0f},tok_per_s={cb:.1f};"
                    f"decode_ticks={cb_ticks};"
                    f"ticks_saved={seq_ticks/cb_ticks:.2f}x")
