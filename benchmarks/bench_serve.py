"""Serving throughput + the paged KV-cache scaling win.

Five comparisons on the smoke models:

1. Continuous batching vs sequential request handling (dense path): the
   tick ratio is the real batching speedup on memory-bound accelerators.
2. **Equal-KV-budget slot scaling**: with the same token budget of KV
   memory, the dense engine reserves ``max_slots x max_len`` up front and
   caps out, while the paged engine admits 2x the concurrent slots and its
   pages-in-use high-water mark stays far below the dense reservation.
3. **Chunked prefill anti-stall**: while a long prompt prefills in chunks,
   an already-live request keeps emitting a token every tick.
4. **Shared-prefix prefill reuse**: requests sharing a 192-token system
   prompt, prefix cache on vs off at the same page budget.  Cache-on
   prefills only each request's unique tail (the shared pages are matched
   in the radix index and incref'd), so prefill-token throughput rises and
   the pages-in-use high-water falls.
5. **Tensor-parallel decode scaling** (subprocess with 8 forced host
   devices): the MoE smoke config scaled to serving size, decoded by the
   tp=1 engine vs the tp=8 sharded engine.  The speedup tracks the host's
   free cores — 8 sharded device programs overlap on whatever cores exist,
   so a 2-core container shows ~1.2-1.7x while an 8-core host has 8x of
   expert-GEMM headroom.

``run`` returns a machine-readable payload that ``benchmarks.run`` writes
to ``results/BENCH_serve.json`` so the perf trajectory is tracked across
PRs.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine

MAX_LEN = 128
PAGE = 16

# run in a subprocess: the host device count must be forced before jax
# initializes, and the parent bench process keeps 1 device
_TP_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, time
import jax, numpy as np
from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine

cfg = smoke_config("qwen3-moe-235b-a22b").replace(
    remat="none", d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
    expert_d_ff=1024)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

def decode_tput(mesh):
    eng = ServeEngine(model, params, max_slots=8, max_len=128, paged=True,
                      page_size=16, prefill_chunk=64, mesh=mesh)
    rng = np.random.default_rng(0)
    eng.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=2)
    eng.run_until_drained()                    # warm: compile both paths
    eng.finished.clear()
    warm_ticks = eng.stats["ticks"]
    for _ in range(8):
        eng.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=32)
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    eng.close()
    return {"tok_per_s": toks / dt, "tokens": toks,
            "ticks": eng.stats["ticks"] - warm_ticks}

tp1 = decode_tput(None)
tp8 = decode_tput(jax.make_mesh((8,), ("model",)))
speedup = tp8["tok_per_s"] / tp1["tok_per_s"]
# the 2x target needs real cores behind the 8 virtual devices; record the
# verdict explicitly so the tracked artifact states its own validity
print(json.dumps({"tp1": tp1, "tp8": tp8, "speedup_x": speedup,
                  "target_2x_met": speedup >= 2.0,
                  "host_cores": os.cpu_count()}))
"""


def _tp_scaling() -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _TP_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert out.returncode == 0, f"tp bench failed:\n{out.stderr[-2000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def _drain_tracking_peak(eng):
    """run_until_drained, recording the peak number of live slots."""
    peak = 0
    for _ in range(10_000):
        busy = eng.tick()
        peak = max(peak, len(eng.sched.live_slots()))
        if not busy and not eng.sched.has_work():
            break
    return peak


def _throughput(model, params, slots: int, *, paged: bool, n_req: int = 8,
                max_new: int = 16, num_pages=None):
    eng = ServeEngine(model, params, max_slots=slots, max_len=MAX_LEN,
                      paged=paged, page_size=PAGE, num_pages=num_pages,
                      prefill_chunk=32)
    rng = np.random.default_rng(0)
    for _ in range(n_req):
        eng.submit(rng.integers(0, model.cfg.vocab, 8), max_new_tokens=max_new)
    t0 = time.perf_counter()
    peak = _drain_tracking_peak(eng)
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in eng.finished)
    eng.close()
    return {"tok_per_s": toks / dt, "ticks": eng.stats["ticks"],
            "tokens": toks, "peak_slots": peak,
            "pages_high_water": eng.pool.high_water if eng.pool else None,
            "preemptions": eng.stats["preemptions"]}


def _shared_prefix(model, params, *, prefix_cache: bool, n_req: int = 8,
                   prefix_len: int = 192, tail_len: int = 8):
    """Prefill-token throughput on a shared-system-prompt workload
    (prefill-dominated: a long shared prefix, two decode tokens each).

    One untimed request warms the jit shapes AND (cache-on) seeds the
    prefix index — the steady state of production traffic.  The timed
    requests then measure how fast prompt tokens become resident KV.
    """
    max_len = 2 * MAX_LEN
    eng = ServeEngine(model, params, max_slots=2, max_len=max_len,
                      paged=True, page_size=PAGE, prefill_chunk=32,
                      num_pages=2 * max_len // PAGE,
                      prefix_cache=prefix_cache)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, model.cfg.vocab, prefix_len).tolist()
    prompts = [shared + rng.integers(0, model.cfg.vocab, tail_len).tolist()
               for _ in range(n_req + 1)]
    eng.submit(prompts[0], max_new_tokens=2)        # warm compile + cache
    eng.run_until_drained()
    eng.finished.clear()
    t0 = time.perf_counter()
    for p in prompts[1:]:
        eng.submit(p, max_new_tokens=2)
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    prompt_toks = sum(len(p) for p in prompts[1:])
    assert len(done) == n_req and all(r.error is None for r in done)
    s = eng.stats
    eng.close()
    return {"prefill_tok_per_s": prompt_toks / dt, "prompt_tokens": prompt_toks,
            "pages_high_water": s["pages_high_water"],
            "prefix_hits": s["prefix_hits"],
            "prefix_hit_tokens": s["prefix_hit_tokens"],
            "cow_copies": s["cow_copies"], "evictions": s["evictions"]}


def _prefill_stall(model, params, *, paged: bool):
    """Tokens a live request emits during a 96-token prompt's prefill."""
    eng = ServeEngine(model, params, max_slots=2, max_len=MAX_LEN,
                      paged=paged, page_size=PAGE, prefill_chunk=16,
                      chunks_per_tick=1)
    eng.submit([3, 1, 4], max_new_tokens=64)
    eng.run_until_drained(max_ticks=2)          # short request is live
    short = eng.sched.slot_req[0]
    eng.submit(list(range(1, 97)), max_new_tokens=2)
    long_req = eng.queue[-1]
    n0 = len(short.output)
    ticks = 0
    while not long_req.output and ticks < 30:
        eng.tick()
        ticks += 1
    emitted = len(short.output) - n0
    eng.close()
    return {"ticks_to_long_first_token": ticks,
            "short_tokens_during_prefill": emitted}


def run(csv_rows: list):
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _throughput(model, params, 2, paged=False, n_req=2, max_new=4)  # warm
    _throughput(model, params, 2, paged=True, n_req=2, max_new=4,
                num_pages=2 * MAX_LEN // PAGE)

    seq = _throughput(model, params, 1, paged=False)
    cb = _throughput(model, params, 4, paged=False)
    csv_rows.append(
        f"serve_sequential,{1e6/seq['tok_per_s']:.0f},"
        f"tok_per_s={seq['tok_per_s']:.1f};decode_ticks={seq['ticks']}")
    # On memory-bound accelerators a decode tick's cost is ~flat in batch, so
    # the tick ratio is the real continuous-batching speedup; CPU tok/s is
    # compute-bound and does not show it.
    csv_rows.append(
        f"serve_continuous4,{1e6/cb['tok_per_s']:.0f},"
        f"tok_per_s={cb['tok_per_s']:.1f};decode_ticks={cb['ticks']};"
        f"ticks_saved={seq['ticks']/cb['ticks']:.2f}x")

    # equal KV budget: 4 dense slots' worth of pages, 2x the slots paged
    budget_tokens = 4 * MAX_LEN
    dense = _throughput(model, params, 4, paged=False)
    paged = _throughput(model, params, 8, paged=True,
                        num_pages=budget_tokens // PAGE)
    csv_rows.append(
        f"serve_paged8_equal_budget,{1e6/paged['tok_per_s']:.0f},"
        f"tok_per_s={paged['tok_per_s']:.1f};decode_ticks={paged['ticks']};"
        f"peak_slots={paged['peak_slots']}vs{dense['peak_slots']};"
        f"pages_hw={paged['pages_high_water']}"
        f"of{budget_tokens // PAGE}")

    stall = _prefill_stall(model, params, paged=True)
    csv_rows.append(
        f"serve_chunked_prefill,{stall['ticks_to_long_first_token']},"
        f"short_tokens_during_96tok_prefill="
        f"{stall['short_tokens_during_prefill']}")

    pc_on = _shared_prefix(model, params, prefix_cache=True)
    pc_off = _shared_prefix(model, params, prefix_cache=False)
    pc_speedup = pc_on["prefill_tok_per_s"] / pc_off["prefill_tok_per_s"]
    csv_rows.append(
        f"serve_prefix_cache,{1e6/pc_on['prefill_tok_per_s']:.0f},"
        f"prefill_tok_per_s={pc_on['prefill_tok_per_s']:.1f};"
        f"off={pc_off['prefill_tok_per_s']:.1f};"
        f"speedup={pc_speedup:.2f}x;"
        f"pages_hw_on={pc_on['pages_high_water']};"
        f"pages_hw_off={pc_off['pages_high_water']};"
        f"hit_tokens={pc_on['prefix_hit_tokens']}")

    tp = _tp_scaling()
    csv_rows.append(
        f"serve_tp8_moe_decode,{1e6/tp['tp8']['tok_per_s']:.0f},"
        f"tok_per_s={tp['tp8']['tok_per_s']:.1f};"
        f"tp1={tp['tp1']['tok_per_s']:.1f};"
        f"speedup={tp['speedup_x']:.2f}x_on_{os.cpu_count()}cores")

    return {
        "sequential": seq, "continuous4": cb,
        "dense_equal_budget": dense, "paged_equal_budget": paged,
        "dense_reserved_pages": budget_tokens // PAGE,
        "budget_tokens": budget_tokens,
        "chunked_prefill": stall,
        "slot_scaling_x": paged["peak_slots"] / max(dense["peak_slots"], 1),
        "prefix_cache": {
            "on": pc_on, "off": pc_off, "speedup_x": pc_speedup,
            "target_1p5x_met": pc_speedup >= 1.5,
            "high_water_reduced": (pc_on["pages_high_water"]
                                   < pc_off["pages_high_water"]),
        },
        "tp_scaling": tp,
    }
