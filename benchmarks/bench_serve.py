"""Serving throughput + the paged KV-cache scaling win.

Seven comparisons on the smoke models:

1. Continuous batching vs sequential request handling (dense path): the
   tick ratio is the real batching speedup on memory-bound accelerators.
2. **Equal-KV-budget slot scaling**: with the same token budget of KV
   memory, the dense engine reserves ``max_slots x max_len`` up front and
   caps out, while the paged engine admits 2x the concurrent slots and its
   pages-in-use high-water mark stays far below the dense reservation.
3. **Chunked prefill anti-stall**: while a long prompt prefills in chunks,
   an already-live request keeps emitting a token every tick.
4. **Shared-prefix prefill reuse**: requests sharing a 192-token system
   prompt, prefix cache on vs off at the same page budget.  Cache-on
   prefills only each request's unique tail (the shared pages are matched
   in the radix index and incref'd), so prefill-token throughput rises and
   the pages-in-use high-water falls.
5. **Tensor-parallel decode scaling** (subprocess with 8 forced host
   devices): the MoE smoke config scaled to serving size, decoded by the
   tp=1 engine vs the tp=8 sharded engine.  The speedup tracks the host's
   free cores — 8 sharded device programs overlap on whatever cores exist,
   so a 2-core container shows ~1.2-1.7x while an 8-core host has 8x of
   expert-GEMM headroom.
6. **Quantized int8 KV at an equal HBM budget**: the byte budget 8
   full-precision slots cost buys the quant-on engine 2x the concurrent
   slots (3.2x fewer KV bytes/token on the f32 smoke model), with
   teacher-forced greedy agreement recorded alongside the tok/s numbers.
7. **Speculative decode** (`--spec-decode ngram`): decode tokens/s on a
   shared-prefix workload whose greedy decode is genuinely repetitive
   (the MoE smoke model falls into token loops, the bread-and-butter case
   for prompt-lookup drafting), spec-on vs spec-off at the SAME KV
   budget.  The acceptance rate is recorded alongside — the speedup is
   tokens-per-verify-window times the verify/decode cost ratio, so it
   rises with acceptance.

8. **Expert-parallel MoE decode + load-aware placement** (subprocess with
   8 forced host devices): the same scaled MoE config decoded by the
   serial engine vs the ep=2 ("expert", "model") engine (all-to-all
   dispatch/combine), with and without in-band re-placement.  Plan quality
   rides along as deterministic integer math: the max/mean rank-imbalance
   reduction ``plan_placement`` achieves on the engine's own measured
   routing window and on two synthetic hot-expert windows (adjacent-hot
   and dominant-with-zeros, the replication/eviction regime) — seeded, so
   the perf gate can hold the gains to a tight tolerance.

``run`` returns a machine-readable payload that ``benchmarks.run`` writes
to ``results/BENCH_serve.json`` so the perf trajectory is tracked across
PRs.
"""
from __future__ import annotations

import functools
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine

MAX_LEN = 128
PAGE = 16

# run in a subprocess: the host device count must be forced before jax
# initializes, and the parent bench process keeps 1 device
_TP_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, time
import jax, numpy as np
from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine

cfg = smoke_config("qwen3-moe-235b-a22b").replace(
    remat="none", d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
    expert_d_ff=1024)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

def decode_tput(mesh):
    eng = ServeEngine(model, params, max_slots=8, max_len=128, paged=True,
                      page_size=16, prefill_chunk=64, mesh=mesh)
    rng = np.random.default_rng(0)
    eng.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=2)
    eng.run_until_drained()                    # warm: compile both paths
    eng.finished.clear()
    warm_ticks = eng.stats["ticks"]
    for _ in range(8):
        eng.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=32)
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    eng.close()
    return {"tok_per_s": toks / dt, "tokens": toks,
            "ticks": eng.stats["ticks"] - warm_ticks}

tp1 = decode_tput(None)
tp8 = decode_tput(jax.make_mesh((8,), ("model",)))
speedup = tp8["tok_per_s"] / tp1["tok_per_s"]
# the 2x target needs real cores behind the 8 virtual devices; record the
# verdict explicitly so the tracked artifact states its own validity
print(json.dumps({"tp1": tp1, "tp8": tp8, "speedup_x": speedup,
                  "target_2x_met": speedup >= 2.0,
                  "host_cores": os.cpu_count()}))
"""


def _forced_devices(script: str, what: str) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert out.returncode == 0, f"{what} bench failed:\n{out.stderr[-2000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def _tp_scaling() -> dict:
    return _forced_devices(_TP_SCRIPT, "tp")


# expert-parallel decode + load-aware placement, same scaled MoE config and
# forced-device protocol as the tp bench above
_EP_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, time
import jax, numpy as np
from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine, identity_plan, imbalance, plan_placement

cfg = smoke_config("qwen3-moe-235b-a22b").replace(
    remat="none", d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
    expert_d_ff=1024)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

def decode_tput(mesh, **kw):
    eng = ServeEngine(model, params, max_slots=8, max_len=128, paged=True,
                      page_size=16, prefill_chunk=64, mesh=mesh, **kw)
    rng = np.random.default_rng(0)
    eng.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=2)
    eng.run_until_drained()                    # warm: compile both paths
    eng.finished.clear()
    warm_ticks = eng.stats["ticks"]
    for _ in range(8):
        eng.submit(rng.integers(0, cfg.vocab, 8), max_new_tokens=32)
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    s = dict(eng.stats)
    eng.close()
    return {"tok_per_s": toks / dt, "tokens": toks,
            "ticks": s["ticks"] - warm_ticks,
            "moe_tokens_routed": s["moe_tokens_routed"],
            "moe_dropped_tokens": s["moe_dropped_tokens"],
            "expert_tokens": s["expert_tokens"],
            "expert_imbalance": s["expert_imbalance"],
            "placement_updates": s["placement_updates"]}

serial = decode_tput(None)
mesh = jax.make_mesh((2, 1), ("expert", "model"))
ep2 = decode_tput(mesh)
ep2_placed = decode_tput(mesh, placement_interval=4)

def plan_gain(window, ep):
    # deterministic integer math: identity layout vs plan_placement on one
    # measured routing window, scored as max/mean per-rank token load
    window = np.asarray(window)
    plan = plan_placement(window, ep)
    before = imbalance(identity_plan(window.size, ep).rank_loads(window))
    after = imbalance(plan.rank_loads(window))
    return {"identity_imbalance": before, "planned_imbalance": after,
            "imbalance_gain": before / after,
            "replicated_experts": int((plan.split_q > 0).sum()),
            "evicted_experts": int((plan.slot_a < 0).sum())}

out = {"serial": serial, "ep2": ep2, "ep2_placed": ep2_placed,
       "ep2_vs_serial_x": ep2["tok_per_s"] / serial["tok_per_s"],
       "placement_overhead_x": ep2["tok_per_s"] / ep2_placed["tok_per_s"],
       # streams must be mesh- and placement-invariant, so routed/dropped
       # totals agree across all three engines; record the check's verdict
       "telemetry_invariant": (
           serial["expert_tokens"] == ep2["expert_tokens"]
           == ep2_placed["expert_tokens"]),
       "measured": plan_gain(ep2["expert_tokens"], 2),
       "skewed": plan_gain([1000, 900, 10, 10, 10, 10, 10, 10], 2),
       "dominant": plan_gain([5000, 0, 10, 10, 0, 10, 10, 10], 2),
       "host_cores": os.cpu_count()}
print(json.dumps(out))
"""


def _moe_ep_bench() -> dict:
    return _forced_devices(_EP_SCRIPT, "moe ep")


def _drain_tracking_peak(eng):
    """run_until_drained, recording the peak number of live slots."""
    peak = 0
    for _ in range(10_000):
        busy = eng.tick()
        peak = max(peak, len(eng.sched.live_slots()))
        if not busy and not eng.sched.has_work():
            break
    return peak


def _throughput(model, params, slots: int, *, paged: bool, n_req: int = 8,
                max_new: int = 16, num_pages=None, kv_quant=None):
    eng = ServeEngine(model, params, max_slots=slots, max_len=MAX_LEN,
                      paged=paged, page_size=PAGE, num_pages=num_pages,
                      prefill_chunk=32, kv_quant=kv_quant)
    rng = np.random.default_rng(0)
    for _ in range(n_req):
        eng.submit(rng.integers(0, model.cfg.vocab, 8), max_new_tokens=max_new)
    t0 = time.perf_counter()
    peak = _drain_tracking_peak(eng)
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in eng.finished)
    eng.close()
    return {"tok_per_s": toks / dt, "ticks": eng.stats["ticks"],
            "tokens": toks, "peak_slots": peak,
            "pages_high_water": eng.pool.high_water if eng.pool else None,
            "preemptions": eng.stats["preemptions"]}


def _shared_prefix(model, params, *, prefix_cache: bool, n_req: int = 8,
                   prefix_len: int = 192, tail_len: int = 8):
    """Prefill-token throughput on a shared-system-prompt workload
    (prefill-dominated: a long shared prefix, two decode tokens each).

    One untimed request warms the jit shapes AND (cache-on) seeds the
    prefix index — the steady state of production traffic.  The timed
    requests then measure how fast prompt tokens become resident KV.
    """
    max_len = 2 * MAX_LEN
    eng = ServeEngine(model, params, max_slots=2, max_len=max_len,
                      paged=True, page_size=PAGE, prefill_chunk=32,
                      num_pages=2 * max_len // PAGE,
                      prefix_cache=prefix_cache)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, model.cfg.vocab, prefix_len).tolist()
    prompts = [shared + rng.integers(0, model.cfg.vocab, tail_len).tolist()
               for _ in range(n_req + 1)]
    eng.submit(prompts[0], max_new_tokens=2)        # warm compile + cache
    eng.run_until_drained()
    eng.finished.clear()
    t0 = time.perf_counter()
    for p in prompts[1:]:
        eng.submit(p, max_new_tokens=2)
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    prompt_toks = sum(len(p) for p in prompts[1:])
    assert len(done) == n_req and all(r.error is None for r in done)
    s = eng.stats
    eng.close()
    return {"prefill_tok_per_s": prompt_toks / dt, "prompt_tokens": prompt_toks,
            "pages_high_water": s["pages_high_water"],
            "prefix_hits": s["prefix_hits"],
            "prefix_hit_tokens": s["prefix_hit_tokens"],
            "cow_copies": s["cow_copies"], "evictions": s["evictions"]}


def _prefill_stall(model, params, *, paged: bool):
    """Tokens a live request emits during a 96-token prompt's prefill."""
    eng = ServeEngine(model, params, max_slots=2, max_len=MAX_LEN,
                      paged=paged, page_size=PAGE, prefill_chunk=16,
                      chunks_per_tick=1)
    eng.submit([3, 1, 4], max_new_tokens=64)
    eng.run_until_drained(max_ticks=2)          # short request is live
    short = eng.sched.slot_req[0]
    eng.submit(list(range(1, 97)), max_new_tokens=2)
    long_req = eng.queue[-1]
    n0 = len(short.output)
    ticks = 0
    while not long_req.output and ticks < 30:
        eng.tick()
        ticks += 1
    emitted = len(short.output) - n0
    eng.close()
    return {"ticks_to_long_first_token": ticks,
            "short_tokens_during_prefill": emitted}


def _spec_history_prompts(model, params, *, slots, max_len, n_req):
    """A growing-chat-history workload: each prompt is a base prompt plus
    the model's OWN previous greedy turn (one untimed generation pass) —
    the re-serving scenario where the continuation is maximally
    predictable from the visible stream, which is prompt-lookup
    drafting's bread-and-butter case."""
    rng = np.random.default_rng(0)
    shared = rng.integers(0, model.cfg.vocab, 24)
    bases = [np.concatenate([shared, rng.integers(0, model.cfg.vocab, 4)])
             for _ in range(n_req)]
    eng = ServeEngine(model, params, max_slots=slots, max_len=max_len,
                      paged=True, page_size=PAGE, prefill_chunk=64,
                      num_pages=slots * max_len // PAGE, prefix_cache=False)
    for b in bases:
        eng.submit(b, max_new_tokens=96)
    done = eng.run_until_drained()
    eng.close()
    return [np.concatenate([bases[r.rid], np.asarray(r.output, np.int32)])
            for r in done]


def _spec_decode(model, params, prompts, *, spec: bool, max_new: int = 96,
                 spec_k: int = 8, slots: int = 4, max_len: int = 512):
    """Decode tokens/s with speculative ngram drafting on vs off, equal KV
    budget.  The MoE smoke model's greedy decode settles into repetitive
    token loops — exactly the regime prompt-lookup drafting targets.  The
    prefix cache stays off (orthogonal feature) so every request prefills
    with identical chunk shapes: the warm pass below compiles every
    prefill / decode / verify-width shape the timed phase will hit."""
    from repro.serve.spec import NgramDrafter
    eng = ServeEngine(model, params, max_slots=slots, max_len=max_len,
                      paged=True, page_size=PAGE, prefill_chunk=64,
                      num_pages=slots * max_len // PAGE, prefix_cache=False,
                      spec_decode=NgramDrafter() if spec else None,
                      spec_k=spec_k)
    for p in prompts[:2]:   # warm: all jit shapes, both verify widths
        eng.submit(p, max_new_tokens=max_new)
    eng.run_until_drained()
    eng.finished.clear()
    # one admission wave (len(prompts) == slots), prefill untimed: the
    # metric is DECODE tokens/s, so the clock starts once every slot is
    # live and counts only tokens emitted from then on
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    while eng.queue or eng.sched.prefilling_slots():
        eng.tick()
    live = [eng.sched.slot_req[s] for s in eng.sched.live_slots()]
    t0_tokens = sum(len(r.output) for r in live)
    ticks0 = eng.stats["ticks"]
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done) - t0_tokens
    assert len(done) == len(prompts) and all(r.error is None for r in done)
    s = dict(eng.stats)
    eng.close()
    return {"tok_per_s": toks / dt, "tokens": toks,
            "ticks": s["ticks"] - ticks0,
            "draft_proposed": s["draft_proposed"],
            "draft_accepted": s["draft_accepted"],
            "acceptance_rate": s["acceptance_rate"]}


def _kv_quant_bench(model, params):
    """int8 KV pages at an equal HBM budget.

    The budget is what 8 full-precision slots of ``MAX_LEN`` tokens cost in
    KV bytes.  The quant-off engine spends it on 8 slots; the quant-on
    engine's pages are 3.2x smaller (int8 values + f32 per-row scales vs
    f32 values), so the same bytes hold 2x the slots (capped at 16 here to
    bound CPU runtime — the affordable count is recorded separately) and
    the same request wave runs at twice the concurrency.

    Accuracy rides along: teacher-forced greedy agreement (same prompt,
    first sampled token, the deterministic gate the tests enforce at 0.95)
    over 48 prompts, quant-on vs quant-off.
    """
    from repro.serve.quant import kv_bytes_per_token, make_kv_quant
    bpt_off = kv_bytes_per_token(model.paged_leaf_specs())
    bpt_on = kv_bytes_per_token(
        model.paged_leaf_specs(make_kv_quant("int8")))
    budget_tokens = 8 * MAX_LEN
    budget_bytes = budget_tokens * bpt_off
    pages_off = budget_tokens // PAGE
    pages_on = budget_bytes // (bpt_on * PAGE)
    slots_affordable = (pages_on * PAGE) // MAX_LEN
    slots_on = min(16, slots_affordable)

    off = _throughput(model, params, 8, paged=True, n_req=16,
                      num_pages=pages_off)
    on = _throughput(model, params, slots_on, paged=True, n_req=16,
                     num_pages=pages_on, kv_quant="int8")

    def first_tokens(kv_quant):
        eng = ServeEngine(model, params, max_slots=8, max_len=MAX_LEN,
                          paged=True, page_size=PAGE, kv_quant=kv_quant)
        rng = np.random.default_rng(1)
        for _ in range(48):
            plen = int(rng.integers(4, 60))
            eng.submit(rng.integers(0, model.cfg.vocab, plen),
                       max_new_tokens=1)
        done = eng.run_until_drained()
        eng.close()
        return {r.rid: r.output[0] for r in done}

    a, b = first_tokens(None), first_tokens("int8")
    match = sum(a[r] == b[r] for r in a) / len(a)
    slot_x = on["peak_slots"] / max(off["peak_slots"], 1)
    return {
        "bytes_per_token": {"off": bpt_off, "int8": bpt_on,
                            "ratio_x": bpt_off / bpt_on},
        "equal_hbm": {
            "budget_bytes": budget_bytes,
            "off": dict(off, slots=8, num_pages=pages_off),
            "int8": dict(on, slots=slots_on, num_pages=pages_on),
            "slots_affordable_int8": slots_affordable,
            "slot_scaling_x": slot_x,
            "target_1p8x_met": slot_x >= 1.8,
        },
        "token_match": {"n": len(a), "match_rate": match,
                        "target_0p95_met": match >= 0.95},
    }


def _traffic_bench(model, params):
    """Open-loop traffic: Poisson and bursty arrivals at two load levels.

    Every number before this came from "submit everything, drain" — no
    arrival process, so no queueing delay and no latency distribution.
    Here the harness submits at seeded arrival times under the virtual
    clock, so TTFT/ITL/e2e percentiles and SLO goodput are measured in
    TICKS and are a deterministic function of the seed: the perf gate can
    hold them to a tight tolerance because only a real scheduling change
    (not runner noise) moves them.  Wall seconds ride along untracked.
    """
    from repro.serve.traffic import make_workload, run_traffic
    out = {}
    for kind in ("poisson", "bursty"):
        for label, rate in (("low", 0.25), ("high", 1.0)):
            wl = make_workload(kind=kind, n_requests=16, rate=rate,
                               vocab=model.cfg.vocab, seed=7,
                               max_new_tokens=8, shared_prefix_len=8,
                               n_sessions=2)
            eng = ServeEngine(model, params, max_slots=4, max_len=MAX_LEN,
                              paged=True, page_size=PAGE, prefill_chunk=32)
            t0 = time.perf_counter()
            res = run_traffic(eng, wl, slo={"ttft": 24.0, "e2e": 96.0})
            dt = time.perf_counter() - t0
            eng.close()
            rep = res["report"]
            out[f"{kind}_{label}"] = {
                "rate": rate, "n_requests": rep["n_requests"],
                "tokens": rep["tokens"], "span_ticks": rep["span"],
                "wall_seconds": dt,
                "ttft": rep["ttft"], "itl": rep["itl"], "e2e": rep["e2e"],
                "tok_per_tick": rep["tok_per_s"], "goodput": rep["goodput"],
            }
    return out


def _paged_kernel_microbench(*, B=4, Hq=4, Hkv=2, D=32, ps=16, P=4,
                             iters=20):
    """Fused multi-query paged-attention kernel vs the jnp gather fallback,
    at the decode (W=1) and spec-verify (W=8) window shapes the engine
    actually issues.  Both sides are jitted and warmed; calls/s per path.

    Off-TPU the Pallas side runs interpret=True (Python-evaluated grid), so
    the kernel-vs-fallback RATIO is only meaningful on a real TPU — the
    ``interpreted`` flag is recorded so the tracked artifact states its own
    validity, and the perf gate watches each path's absolute calls/s for
    cliffs rather than the cross-path ratio.
    """
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    from repro.models import attention as A

    N = B * P + 1                            # live pages + trash page
    rng = np.random.default_rng(0)
    kp = jnp.asarray(rng.normal(size=(N, ps, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, ps, Hkv, D)), jnp.float32)
    tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    fallback = jax.jit(functools.partial(A.paged_window_attention,
                                         use_pallas=False))
    kernel = kops.paged_attention_mq

    def time_path(fn, q, lens):
        fn(q, kp, vp, tables, lens).block_until_ready()     # warm/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(q, kp, vp, tables, lens)
        out.block_until_ready()
        return iters / (time.perf_counter() - t0)

    out = {"interpreted": jax.default_backend() != "tpu",
           "shape": {"B": B, "Hq": Hq, "Hkv": Hkv, "D": D,
                     "page_size": ps, "pages_per_seq": P}}
    for name, W in (("decode", 1), ("verify", 8)):
        q = jnp.asarray(rng.normal(size=(B, W, Hq, D)), jnp.float32)
        lens = jnp.asarray(rng.integers(1, P * ps - W + 1, size=B),
                           jnp.int32)
        kern = time_path(kernel, q, lens)
        # fallback takes n_cached (= kernel lengths - 1)
        fb = time_path(fallback, q, lens - 1)
        out[name] = {"window": W, "kernel_calls_per_s": kern,
                     "fallback_calls_per_s": fb,
                     "kernel_vs_fallback_x": kern / fb}
    return out


def run(csv_rows: list):
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _throughput(model, params, 2, paged=False, n_req=2, max_new=4)  # warm
    _throughput(model, params, 2, paged=True, n_req=2, max_new=4,
                num_pages=2 * MAX_LEN // PAGE)

    seq = _throughput(model, params, 1, paged=False)
    cb = _throughput(model, params, 4, paged=False)
    csv_rows.append(
        f"serve_sequential,{1e6/seq['tok_per_s']:.0f},"
        f"tok_per_s={seq['tok_per_s']:.1f};decode_ticks={seq['ticks']}")
    # On memory-bound accelerators a decode tick's cost is ~flat in batch, so
    # the tick ratio is the real continuous-batching speedup; CPU tok/s is
    # compute-bound and does not show it.
    csv_rows.append(
        f"serve_continuous4,{1e6/cb['tok_per_s']:.0f},"
        f"tok_per_s={cb['tok_per_s']:.1f};decode_ticks={cb['ticks']};"
        f"ticks_saved={seq['ticks']/cb['ticks']:.2f}x")

    # equal KV budget: 4 dense slots' worth of pages, 2x the slots paged
    budget_tokens = 4 * MAX_LEN
    dense = _throughput(model, params, 4, paged=False)
    paged = _throughput(model, params, 8, paged=True,
                        num_pages=budget_tokens // PAGE)
    csv_rows.append(
        f"serve_paged8_equal_budget,{1e6/paged['tok_per_s']:.0f},"
        f"tok_per_s={paged['tok_per_s']:.1f};decode_ticks={paged['ticks']};"
        f"peak_slots={paged['peak_slots']}vs{dense['peak_slots']};"
        f"pages_hw={paged['pages_high_water']}"
        f"of{budget_tokens // PAGE}")

    stall = _prefill_stall(model, params, paged=True)
    csv_rows.append(
        f"serve_chunked_prefill,{stall['ticks_to_long_first_token']},"
        f"short_tokens_during_96tok_prefill="
        f"{stall['short_tokens_during_prefill']}")

    pc_on = _shared_prefix(model, params, prefix_cache=True)
    pc_off = _shared_prefix(model, params, prefix_cache=False)
    pc_speedup = pc_on["prefill_tok_per_s"] / pc_off["prefill_tok_per_s"]
    csv_rows.append(
        f"serve_prefix_cache,{1e6/pc_on['prefill_tok_per_s']:.0f},"
        f"prefill_tok_per_s={pc_on['prefill_tok_per_s']:.1f};"
        f"off={pc_off['prefill_tok_per_s']:.1f};"
        f"speedup={pc_speedup:.2f}x;"
        f"pages_hw_on={pc_on['pages_high_water']};"
        f"pages_hw_off={pc_off['pages_high_water']};"
        f"hit_tokens={pc_on['prefix_hit_tokens']}")

    kvq = _kv_quant_bench(model, params)
    eq = kvq["equal_hbm"]
    csv_rows.append(
        f"serve_kv_quant_int8,{1e6/eq['int8']['tok_per_s']:.0f},"
        f"tok_per_s={eq['int8']['tok_per_s']:.1f};"
        f"off={eq['off']['tok_per_s']:.1f};"
        f"bytes_per_token={kvq['bytes_per_token']['int8']}"
        f"vs{kvq['bytes_per_token']['off']};"
        f"slots_equal_hbm={eq['int8']['peak_slots']}"
        f"vs{eq['off']['peak_slots']};"
        f"token_match={kvq['token_match']['match_rate']:.3f}")

    traffic = _traffic_bench(model, params)
    for key in ("poisson_high", "bursty_high"):
        t = traffic[key]
        csv_rows.append(
            f"serve_traffic_{key},{t['ttft']['p99']:.0f},"
            f"ttft_p99_ticks={t['ttft']['p99']:.1f};"
            f"ttft_p50={t['ttft']['p50']:.1f};"
            f"goodput_tok_per_tick={t['goodput']['tok_per_s']:.3f};"
            f"slo_attainment={t['goodput']['slo_attainment']:.2f};"
            f"wall_s={t['wall_seconds']:.2f}")

    moe_cfg = smoke_config("qwen3-moe-235b-a22b").replace(remat="none")
    moe_model = build_model(moe_cfg)
    moe_params = moe_model.init(jax.random.PRNGKey(0))
    spec_prompts = _spec_history_prompts(moe_model, moe_params, slots=4,
                                         max_len=512, n_req=4)
    spec_off = _spec_decode(moe_model, moe_params, spec_prompts, spec=False)
    spec_on = _spec_decode(moe_model, moe_params, spec_prompts, spec=True)
    spec_speedup = spec_on["tok_per_s"] / spec_off["tok_per_s"]
    csv_rows.append(
        f"serve_spec_decode,{1e6/spec_on['tok_per_s']:.0f},"
        f"tok_per_s={spec_on['tok_per_s']:.1f};"
        f"off={spec_off['tok_per_s']:.1f};"
        f"speedup={spec_speedup:.2f}x;"
        f"acceptance_rate={spec_on['acceptance_rate']:.2f};"
        f"ticks={spec_on['ticks']}vs{spec_off['ticks']}")

    pk = _paged_kernel_microbench()
    csv_rows.append(
        f"serve_paged_kernel_decode,{1e6/pk['decode']['kernel_calls_per_s']:.0f},"
        f"kernel_calls_per_s={pk['decode']['kernel_calls_per_s']:.1f};"
        f"fallback={pk['decode']['fallback_calls_per_s']:.1f};"
        f"interpreted={pk['interpreted']}")
    csv_rows.append(
        f"serve_paged_kernel_verify8,{1e6/pk['verify']['kernel_calls_per_s']:.0f},"
        f"kernel_calls_per_s={pk['verify']['kernel_calls_per_s']:.1f};"
        f"fallback={pk['verify']['fallback_calls_per_s']:.1f};"
        f"interpreted={pk['interpreted']}")

    tp = _tp_scaling()
    csv_rows.append(
        f"serve_tp8_moe_decode,{1e6/tp['tp8']['tok_per_s']:.0f},"
        f"tok_per_s={tp['tp8']['tok_per_s']:.1f};"
        f"tp1={tp['tp1']['tok_per_s']:.1f};"
        f"speedup={tp['speedup_x']:.2f}x_on_{os.cpu_count()}cores")

    ep = _moe_ep_bench()
    csv_rows.append(
        f"serve_moe_ep2_decode,{1e6/ep['ep2']['tok_per_s']:.0f},"
        f"tok_per_s={ep['ep2']['tok_per_s']:.1f};"
        f"serial={ep['serial']['tok_per_s']:.1f};"
        f"placed={ep['ep2_placed']['tok_per_s']:.1f};"
        f"skew_gain={ep['skewed']['imbalance_gain']:.2f}x;"
        f"dominant_gain={ep['dominant']['imbalance_gain']:.2f}x;"
        f"dropped={ep['ep2']['moe_dropped_tokens']}")

    return {
        "sequential": seq, "continuous4": cb,
        "dense_equal_budget": dense, "paged_equal_budget": paged,
        "dense_reserved_pages": budget_tokens // PAGE,
        "budget_tokens": budget_tokens,
        "chunked_prefill": stall,
        "slot_scaling_x": paged["peak_slots"] / max(dense["peak_slots"], 1),
        "prefix_cache": {
            "on": pc_on, "off": pc_off, "speedup_x": pc_speedup,
            "target_1p5x_met": pc_speedup >= 1.5,
            "high_water_reduced": (pc_on["pages_high_water"]
                                   < pc_off["pages_high_water"]),
        },
        "spec_decode": {
            "on": spec_on, "off": spec_off, "speedup_x": spec_speedup,
            "target_1p5x_met": spec_speedup >= 1.5,
        },
        "kv_quant": kvq,
        "traffic": traffic,
        "paged_kernel": pk,
        "tp_scaling": tp,
        "moe_ep": ep,
    }
