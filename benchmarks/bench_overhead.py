"""Paper §1/§5 claim: the function-centric layer adds negligible overhead
over the underlying "serial code".

Measured here as: generic-layer dispatch (solve_problem / time_integration /
Trainer plumbing) vs calling the compute function directly.  The paper's
claim holds if overhead is a few percent."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import solve_problem, time_integration, vmap_solve_problem
from repro.core.runtime import ThreadFarmExecutor


def run(csv_rows: list):
    # -- task farm overhead --------------------------------------------------
    x = jnp.linspace(0, 10, 4096)
    f = jax.jit(lambda a: (a * x ** 2 + 3 * x + 5).sum())
    f(1.0).block_until_ready()
    n_tasks = 256

    t0 = time.perf_counter()
    out = [f(float(i)) for i in range(n_tasks)]
    jax.block_until_ready(out)
    t_direct = time.perf_counter() - t0

    def initialize():
        return [((float(i),), {}) for i in range(n_tasks)]

    t0 = time.perf_counter()
    solve_problem(initialize, f, jax.block_until_ready)
    t_layer = time.perf_counter() - t0
    csv_rows.append(
        f"overhead_taskfarm,{t_layer*1e6:.0f},"
        f"direct_s={t_direct:.4f};layer_s={t_layer:.4f};"
        f"overhead={100*(t_layer/t_direct-1):.1f}%")

    # -- thread-farm scheduling overhead (same tasks, concurrent runtime) ----
    farm = ThreadFarmExecutor(num_workers=8)
    farm.map_callables([lambda: None] * 8)   # warm the persistent pool
    t0 = time.perf_counter()
    farm.run(initialize, f, jax.block_until_ready)
    t_farm = time.perf_counter() - t0
    csv_rows.append(
        f"overhead_threadfarm,{t_farm*1e6:.0f},"
        f"direct_s={t_direct:.4f};farm_s={t_farm:.4f};"
        f"overhead={100*(t_farm/t_direct-1):.1f}%")

    # -- time-integration overhead -------------------------------------------
    # realistic per-step work (~ms), as in any actual simulation/train step
    w = jnp.eye(1024) * 1e-3
    step = jax.jit(lambda s: s * 0.999 + s @ w)
    s0 = jnp.ones((1024, 1024))
    step(s0).block_until_ready()
    steps = 100

    t0 = time.perf_counter()
    s = s0
    for _ in range(steps):
        s = step(s)
    s.block_until_ready()
    t_direct = time.perf_counter() - t0

    class W:
        def __init__(self):
            self.s = s0

        def __len__(self):
            return 1

        def finalize_timestep(self, old, new):
            pass

    def initialize():
        return W(), steps

    def do_timestep(w):
        w.s = step(w.s)
        return None

    t0 = time.perf_counter()
    time_integration(initialize, do_timestep, lambda o: jax.block_until_ready(s))
    t_layer = time.perf_counter() - t0
    csv_rows.append(
        f"overhead_timeloop,{t_layer*1e6:.0f},"
        f"direct_s={t_direct:.4f};layer_s={t_layer:.4f};"
        f"overhead={100*(t_layer/t_direct-1):.1f}%")
