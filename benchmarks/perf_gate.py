"""CI perf-regression gate: fresh BENCH_serve.json vs the committed baseline.

    python -m benchmarks.perf_gate results/BENCH_serve.json \
        results/BENCH_baseline.json --tolerance 2.0

Compares the serving throughput numbers that track real engine hot paths
(decode tokens/s, paged decode at the equal-KV budget, shared-prefix
prefill tokens/s, speculative decode tokens/s) and fails ONLY when a fresh
number is more than ``tolerance`` times slower than the baseline — shared
CI runners are noisy, so the gate is deliberately generous: it catches
cliffs (an accidentally quadratic scheduler, a jit cache miss per tick),
not drift.  Missing metrics on either side are reported and skipped, so
the baseline can trail new benchmarks by one PR.

Refreshing the baseline after an intentional perf change:

    python -m benchmarks.run serve
    cp results/BENCH_serve.json results/BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys

# (dotted path into the BENCH_serve payload, direction) — "higher" metrics
# regress by shrinking, "lower" metrics (latencies) regress by growing
METRICS = [
    ("continuous4.tok_per_s", "higher"),             # dense continuous batching
    ("paged_equal_budget.tok_per_s", "higher"),      # paged decode, equal KV
    ("prefix_cache.on.prefill_tok_per_s", "higher"), # shared-prefix reuse
    ("spec_decode.on.tok_per_s", "higher"),          # speculative decode
    # int8 KV pages at the equal-HBM budget: quant-on decode must not
    # cliff vs its own baseline, and neither may the quant-off reference
    ("kv_quant.equal_hbm.int8.tok_per_s", "higher"),
    ("kv_quant.equal_hbm.off.tok_per_s", "higher"),
    # fused multi-query paged-attention microbench: each path's absolute
    # calls/s (kernel side is interpret-mode off-TPU, so the gate watches
    # both paths for cliffs instead of the cross-path ratio)
    ("paged_kernel.decode.kernel_calls_per_s", "higher"),
    ("paged_kernel.decode.fallback_calls_per_s", "higher"),
    ("paged_kernel.verify.kernel_calls_per_s", "higher"),
    ("paged_kernel.verify.fallback_calls_per_s", "higher"),
    # open-loop traffic under the virtual clock: tick-denominated, so
    # deterministic per seed — only a real scheduling change moves them
    ("traffic.poisson_high.ttft.p99", "lower"),
    ("traffic.poisson_high.goodput.tok_per_s", "higher"),
    ("traffic.bursty_high.ttft.p99", "lower"),
    ("traffic.bursty_high.goodput.tok_per_s", "higher"),
    # expert-parallel MoE decode: ep=2 tok/s must not cliff, and the
    # placement gains on the synthetic skewed windows are deterministic
    # integer math (seeded), so a drop means the rebalancer itself changed
    ("moe_ep.ep2.tok_per_s", "higher"),
    ("moe_ep.ep2_placed.tok_per_s", "higher"),
    ("moe_ep.skewed.imbalance_gain", "higher"),
    ("moe_ep.dominant.imbalance_gain", "higher"),
]


def dig(payload: dict, path: str):
    cur = payload
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur if isinstance(cur, (int, float)) else None


def gate(fresh: dict, baseline: dict, tolerance: float) -> list[str]:
    """Returns the list of failure messages (empty = gate passes)."""
    failures = []
    for path, direction in METRICS:
        f, b = dig(fresh, path), dig(baseline, path)
        if f is None or b is None or b <= 0:
            print(f"[perf-gate] SKIP {path}: fresh={f} baseline={b}")
            continue
        if direction == "higher":       # throughput: regress by shrinking
            ratio = b / f if f > 0 else float("inf")
        else:                           # latency: regress by growing
            ratio = f / b
        verdict = "FAIL" if ratio > tolerance else "ok"
        print(f"[perf-gate] {verdict:>4} {path}: fresh={f:.1f} "
              f"baseline={b:.1f} regression={ratio:.2f}x "
              f"({direction} is better, tolerance {tolerance:.1f}x)")
        if ratio > tolerance:
            failures.append(
                f"{path}: {f:.1f} vs baseline {b:.1f} "
                f"({ratio:.2f}x worse > {tolerance:.1f}x tolerance)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly generated BENCH_serve.json")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="max allowed slowdown factor (default 2.0)")
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = gate(fresh, baseline, args.tolerance)
    if failures:
        print("[perf-gate] throughput regression detected:", file=sys.stderr)
        for msg in failures:
            print(f"[perf-gate]   {msg}", file=sys.stderr)
        sys.exit(1)
    print("[perf-gate] PASS")


if __name__ == "__main__":
    main()
