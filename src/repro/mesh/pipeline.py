"""Pipeline parallelism, function-centric: the user supplies ``stage_fn``
(one pipeline stage's computation — the paper's ``subdomain_solve`` role);
this module supplies the generic schedule and the stage-boundary transfer
(the paper's ``communicate``: a neighbour ``ppermute``, exactly the additive
Schwarz ghost-exchange pattern applied to the layer dimension).

GPipe schedule over a mesh axis ``axis`` with S stages and M microbatches:
the classic loop runs T = M + S - 1 ticks; at tick t, stage s processes
microbatch t - s.  Implemented SPMD-style inside ``shard_map``: every stage
executes every tick (TPUs are lock-stepped anyway); activations advance one
stage per tick via ``ppermute``; outputs are collected from the last stage.
Bubble fraction = (S-1)/T, reported by :func:`bubble_fraction`.

This is deliberately the *forward* pipeline primitive (inference / activation
pipelining across pods); it composes with the rest of the stack as a user
function and is exercised by tests + the multi-pod dry-run flag rather than
being welded into every model.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import Comm
from repro.core.comm import shard_map as _comm_shard_map


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn: Callable, params_stacked, x_micro, mesh,
                   *, axis: str = "pod"):
    """Run a GPipe forward pipeline over ``axis``.

    Args:
      stage_fn: (stage_params, h) -> h — one stage's computation (a user
        function; e.g. a block of transformer layers).
      params_stacked: pytree whose leaves have a leading (n_stages,) axis,
        sharded over ``axis`` (each device row holds its stage's params).
      x_micro: (n_micro, micro_batch, ...) microbatched input (replicated
        over ``axis``).
      mesh: the device mesh containing ``axis``.

    Returns (n_micro, micro_batch, ...) outputs of the LAST stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    p_specs = jax.tree_util.tree_map(lambda _: P(axis), params_stacked)

    def body(params_local, x_all):
        comm = Comm(axis)
        stage = jax.lax.axis_index(axis)
        sp = jax.tree_util.tree_map(lambda a: a[0], params_local)
        micro_shape = x_all.shape[1:]

        def tick(carry, t):
            h_in, outs = carry
            # stage 0 injects microbatch t (if still in range)
            mb = jnp.take(x_all, jnp.clip(t, 0, n_micro - 1), axis=0)
            h = jnp.where(stage == 0, mb, h_in)
            h = stage_fn(sp, h)
            # last stage emits microbatch t - (n_stages - 1)
            out_idx = t - (n_stages - 1)
            valid = jnp.logical_and(out_idx >= 0, stage == n_stages - 1)
            outs = jax.lax.cond(
                out_idx >= 0,
                lambda o: o.at[jnp.maximum(out_idx, 0)].set(
                    jnp.where(valid, h, o[jnp.maximum(out_idx, 0)])),
                lambda o: o, outs)
            # advance the pipe: stage s -> s+1 (ring; wraparound ignored)
            h_next = comm.shift(h, offset=1)
            return (h_next, outs), None

        h0 = jnp.zeros(micro_shape, x_all.dtype)
        outs0 = jnp.zeros((n_micro,) + micro_shape, x_all.dtype)
        (_, outs), _ = jax.lax.scan(tick, (h0, outs0), jnp.arange(ticks))
        # every device holds `outs`, but only the last stage's is real:
        # broadcast it (replicated output spec needs agreement)
        outs = comm.broadcast_from(outs, root=n_stages - 1)
        return outs

    return jax.jit(_comm_shard_map(
        body, mesh=mesh,
        in_specs=(p_specs, P()),
        out_specs=P(),
        check_vma=False))(params_stacked, x_micro)


def reference_apply(stage_fn: Callable, params_stacked, x_micro):
    """Oracle: run the stages sequentially on one device."""
    n_stages = jax.tree_util.tree_leaves(params_stacked)[0].shape[0]

    def one(h):
        for s in range(n_stages):
            sp = jax.tree_util.tree_map(lambda a: a[s], params_stacked)
            h = stage_fn(sp, h)
        return h

    return jax.vmap(one)(x_micro)
