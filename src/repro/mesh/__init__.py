from repro.mesh.axes import (LOGICAL_RULES_1POD, LOGICAL_RULES_2POD, AxisRules,
                             logical_to_mesh, logical_to_sharding, rules_for_mesh)
from repro.mesh.ring import ring_attention
from repro.mesh.pipeline import pipeline_apply, bubble_fraction
