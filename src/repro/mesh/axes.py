"""Logical-axis sharding rules (MaxText-style), the GSPMD face of the paper's
``simple_partitioning``: a single generic mapping from *logical* tensor axes to
mesh axes replaces per-tensor hand sharding.

Baseline scheme:

* batch          -> ("data",) / ("pod","data")      pure DP
* seq            -> "model"                          sequence/context parallel
                    (attention q is seq-sharded; KV is all-gathered, which is
                    cheap under GQA — no head-count divisibility constraints,
                    so the exact published head counts are kept, unpadded)
* kv_seq         -> "model"                          decode caches sharded along
                    sequence; softmax over the sharded axis lowers to the
                    flash-decoding merge (psum/pmax) under GSPMD
* mlp/vocab/experts/inner/rwkv_v -> "model"          Megatron TP (all assigned
                    dims divide 16)
* embed_w        -> "data"                           FSDP storage sharding of
                    every weight's d_model dim; gathered per-layer inside the
                    scan (ZeRO-3), required for >=14B optimizer states
* everything else unsharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    rules: Mapping[str, Any]  # logical name -> mesh axis (str | tuple | None)
    mesh: Any = None          # the Mesh these rules target (None = serial)

    def get(self, name):
        if name is None:
            return None
        if name not in self.rules:
            raise KeyError(f"unknown logical axis {name!r}")
        return self.rules[name]

    def replace(self, **updates) -> "AxisRules":
        d = dict(self.rules)
        d.update(updates)
        return AxisRules(d, self.mesh)

    def with_mesh(self, mesh) -> "AxisRules":
        return AxisRules(self.rules, mesh)


_BASE = {
    # activations
    "batch": ("data",),
    "seq": "model",          # sequence/context parallelism
    "kv_seq": "model",       # decode KV caches along sequence
    "embed": None,
    "q_heads": None,         # exact head counts kept; heads not TP-sharded
    "kv_heads": None,
    "head_dim": None,
    "expert_cap": None,
    "frames": None,
    # weights
    "embed_w": "data",       # FSDP storage axis for weight d_model dims
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_embed": "data",  # expert-weight d_model dim: FSDP (train mode)
    "expert_mlp": None,      # expert-weight ff dim: set to "data" for the
                             # weight-stationary expert-TP decode mode
    "layers": None,
    "state": None,
    "conv_k": None,
    "inner": "model",        # mamba d_inner channels / heads
    "ssm_heads": "model",    # mamba head axis (d_inner/head_dim)
    "rwkv_v": "model",       # rwkv per-head value channels
}

LOGICAL_RULES_1POD = AxisRules(dict(_BASE))
LOGICAL_RULES_2POD = AxisRules({**_BASE, "batch": ("pod", "data")})


def rules_for_mesh(mesh: Mesh, overrides: Mapping[str, Any] | None = None) -> AxisRules:
    rules = LOGICAL_RULES_2POD if "pod" in mesh.axis_names else LOGICAL_RULES_1POD
    if overrides:
        rules = rules.replace(**overrides)
    return rules.with_mesh(mesh)


def serial_rules() -> AxisRules:
    """Single-device rules (smoke tests): everything replicated."""
    return AxisRules({k: None for k in _BASE})


def logical_to_mesh(spec: P, rules: AxisRules) -> P:
    """Translate a logical PartitionSpec to a mesh PartitionSpec."""
    return P(*(rules.get(ax) for ax in spec))


def logical_to_sharding(spec: P, mesh: Mesh, rules: AxisRules) -> NamedSharding:
    return NamedSharding(mesh, logical_to_mesh(spec, rules))


def constrain(x, spec: P, rules: AxisRules | None):
    """``with_sharding_constraint`` in logical-axis terms.

    With ``rules=None`` (single-device smoke tests) this is a no-op, so model
    code is written once and runs both serially and distributed — the paper's
    serial/parallel duality.  When the rules carry their mesh the constraint
    is a full NamedSharding (no ambient ``with mesh:`` needed); inside a
    ``shard_map`` body (manual axes) constraints are skipped."""
    if rules is None:
        return x
    try:
        if rules.mesh is not None:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(rules.mesh, logical_to_mesh(spec, rules)))
        return jax.lax.with_sharding_constraint(x, logical_to_mesh(spec, rules))
    except (ValueError, RuntimeError):
        return x
