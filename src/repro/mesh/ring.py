"""Ring attention — the additive-Schwarz neighbour-exchange pattern applied
to sequence-parallel attention ("Schwarz → neighbour-exchange parallelism").

Q stays put (each shard owns a contiguous sequence block); K/V blocks rotate
around the ring one hop per step (``ppermute``, the paper's ``communicate``),
and the online-softmax state (acc, m, l) accumulates exactly as in the flash
kernel — so after n hops every shard has attended over the full sequence
while only ever holding 1/n of K/V.  Peak memory O(S/n), wire per device =
(n-1)/n · |K,V|, fully overlappable with the block computation on TPU.

This is the long-context training/prefill alternative to the gather-KV path
in ``models/transformer.py`` (which is cheaper for GQA at moderate S but
holds full K/V).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.comm import Comm

NEG_INF = -1e30


def ring_attention(q, k, v, comm: Comm, *, causal: bool = True):
    """q, k, v: (B, S_local, H, D) — this shard's sequence block, laid out
    rank-contiguously along ``comm.axis``.  Returns (B, S_local, H, D)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    n = comm.size()
    rank = comm.rank()
    scale = D ** -0.5

    qg = (q.reshape(B, Sq, Hkv, G, D) * scale).astype(jnp.float32)
    q_pos = rank * Sq + jnp.arange(Sq)

    def block(carry, kc, vc, k_pos):
        acc, m, l = carry
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc.astype(jnp.float32))
        if causal:
            ok = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return acc, m_new, l

    def hop(i, carry):
        acc, m, l, kc, vc = carry
        src = (rank - i) % n                     # whose block we now hold
        k_pos = src * Sq + jnp.arange(Sq)
        acc, m, l = block((acc, m, l), kc, vc, k_pos)
        kc = comm.shift(kc, offset=1)            # pass blocks around the ring
        vc = comm.shift(vc, offset=1)
        return acc, m, l, kc, vc

    acc0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    acc, m, l, _, _ = jax.lax.fori_loop(0, n, hop, (acc0, m0, l0, k, v))

    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D).astype(q.dtype)
