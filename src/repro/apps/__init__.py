"""The paper's §4 applications, implemented end-to-end in JAX:

* :mod:`repro.apps.mcmc`       — §4.1 ideal-point MCMC (task farm, Table 1)
* :mod:`repro.apps.dmc`        — §4.2 diffusion Monte Carlo with dynamic load
                                 balancing (Table 2)
* :mod:`repro.apps.boussinesq` — §4.3 Boussinesq waves via additive Schwarz
                                 (Table 3)
"""
