"""Boussinesq water waves via additive Schwarz (paper §4.3 / Appendix C).

Model (constant depth H=1, so the ∇H terms of (C.1) vanish):

    continuity (explicit):  (eta^l - eta^{l-1})/dt + ∇·((1 + a·eta)∇phi) = 0
    bernoulli  (implicit):  (phi^l - phi^{l-1})/dt + (a/2)|∇phi|² + eta^l
                            - (e/3) ∇² (phi^l - phi^{l-1})/dt = 0

Each time step therefore needs one *implicit Helmholtz solve*
``(I - c ∇²) dphi = rhs`` with ``c = e/3`` — this is the paper's KONTIT/BERIT
role, and exactly where additive Schwarz enters: the **same serial Jacobi
kernel** (:func:`jacobi_sweeps` — the "25-year-old Fortran code" stand-in,
written once with no knowledge of parallelism) is reused per subdomain, while
the generic :func:`repro.core.schwarz.additive_schwarz_iterations` supplies
the outer iteration, halo ``communicate``, and the paper's convergence test.

Domain decomposition: 1-D row blocks over a mesh axis; every local field
carries one ghost row on each side.  Physical BCs are no-flux (mirror);
the x-direction is handled inside the stencil with edge padding.

Validation: the Schwarz-parallel solution must match the single-domain serial
solve (same kernel, global Jacobi) to stencil tolerance, and mass
(sum of eta) must be conserved under no-flux BCs.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import Comm, make_comm, shard_map
from repro.core.runtime import (Executor, MeshExecutor, SerialExecutor,
                                make_executor)
from repro.core.schwarz import (additive_schwarz_iterations, halo_exchange,
                                simple_convergence_test)


@dataclasses.dataclass(frozen=True)
class BoussinesqParams:
    nx: int = 128
    ny: int = 128
    dx: float = 0.1
    dt: float = 0.02
    alpha: float = 0.0          # nonlinearity
    eps: float = 0.3            # dispersion
    jacobi_sweeps: int = 6      # per Schwarz iteration
    schwarz_max_iter: int = 200
    schwarz_threshold: float = 1e-10

    @property
    def c(self) -> float:
        return self.eps / 3.0


# ---------------------------------------------------------------------------
# "Legacy serial kernel": pure stencils on a ghost-padded block.
# Knows nothing about meshes or communication (the paper's F77 role).
# ---------------------------------------------------------------------------

def _pad_x(f):
    """Mirror-pad the x (last) axis: no-flux east/west walls."""
    return jnp.pad(f, ((0, 0), (1, 1)), mode="edge")


def laplacian(f, dx):
    """5-point Laplacian of the interior of a y-ghost-padded block.

    f: (ny_loc + 2, nx) -> (ny_loc, nx)."""
    fx = _pad_x(f)
    return (f[:-2, :] + f[2:, :] + fx[1:-1, :-2] + fx[1:-1, 2:]
            - 4.0 * f[1:-1, :]) / (dx * dx)


def grad_sq(f, dx):
    """|∇f|² of the interior (central differences)."""
    fx = _pad_x(f)
    gy = (f[2:, :] - f[:-2, :]) / (2 * dx)
    gx = (fx[1:-1, 2:] - fx[1:-1, :-2]) / (2 * dx)
    return gx * gx + gy * gy


def div_k_grad(k, f, dx):
    """∇·(k ∇f) of the interior, k on cell centres (ghost-padded like f)."""
    kx, fx = _pad_x(k), _pad_x(f)
    ke = 0.5 * (kx[1:-1, 1:-1] + kx[1:-1, 2:])
    kw = 0.5 * (kx[1:-1, 1:-1] + kx[1:-1, :-2])
    kn = 0.5 * (k[1:-1, :] + k[2:, :])
    ks = 0.5 * (k[1:-1, :] + k[:-2, :])
    return (ke * (fx[1:-1, 2:] - fx[1:-1, 1:-1])
            - kw * (fx[1:-1, 1:-1] - fx[1:-1, :-2])
            + kn * (f[2:, :] - f[1:-1, :])
            - ks * (f[1:-1, :] - f[:-2, :])) / (dx * dx)


def jacobi_sweeps(dphi, rhs, c, dx, n_sweeps: int):
    """n Jacobi sweeps for (I - c∇²) dphi = rhs on a ghost-padded block.

    Ghost rows are held fixed (they are the Schwarz artificial BCs)."""
    diag = 1.0 + 4.0 * c / (dx * dx)

    def sweep(dphi, _):
        fx = _pad_x(dphi)
        nb = (dphi[:-2, :] + dphi[2:, :]
              + fx[1:-1, :-2] + fx[1:-1, 2:]) / (dx * dx)
        interior = (rhs + c * nb) / diag
        return dphi.at[1:-1, :].set(interior), None

    dphi, _ = jax.lax.scan(sweep, dphi, None, length=n_sweeps)
    return dphi


# ---------------------------------------------------------------------------
# BCs and the per-time-step update (shared serial/parallel)
# ---------------------------------------------------------------------------

def apply_physical_bc(f, comm: Comm | None):
    """Mirror into the ghost rows at the *global* north/south walls.

    On interior subdomain edges the ghosts come from neighbours; shard 0's
    south ghost and shard n-1's north ghost are physical walls."""
    if comm is None:
        return f.at[0, :].set(f[1, :]).at[-1, :].set(f[-2, :])
    rank = comm.rank()
    n = comm.size()
    f = jnp.where(rank == 0, f.at[0, :].set(f[1, :]), f)
    f = jnp.where(rank == n - 1, f.at[-1, :].set(f[-2, :]), f)
    return f


def _communicate(f, comm):
    """Refresh ghost rows from neighbours (then physical BCs overwrite the
    outer walls)."""
    if comm is None:
        return f
    left, right = halo_exchange(f[1:-1], comm, halo=1, axis=0)
    return f.at[0, :].set(left[-1, :]).at[-1, :].set(right[0, :])


def timestep(eta, phi, p: BoussinesqParams, comm: Comm | None):
    """One Boussinesq step on ghost-padded local blocks (serial: comm=None
    and the 'local block' is the global domain).

    Returns (eta, phi, schwarz_iters)."""
    refresh = (lambda f: apply_physical_bc(_communicate(f, comm), comm))

    # -- continuity: explicit eta update ------------------------------------
    phi = refresh(phi)
    eta = refresh(eta)
    depth = 1.0 + p.alpha * eta
    eta_new_int = eta[1:-1, :] - p.dt * div_k_grad(depth, phi, p.dx)
    eta = refresh(eta.at[1:-1, :].set(eta_new_int))

    # -- bernoulli: implicit Helmholtz solve for dphi -------------------------
    rhs = -p.dt * (eta[1:-1, :] + 0.5 * p.alpha * grad_sq(phi, p.dx))
    dphi0 = jnp.zeros_like(phi)

    if comm is None:
        # serial: plain Jacobi to convergence with the SAME kernel
        def cond(carry):
            dphi, prev, it = carry
            diff = jnp.sum((dphi - prev) ** 2)
            den = jnp.maximum(jnp.sum(dphi ** 2), 1e-30)
            return jnp.logical_and(it < p.schwarz_max_iter,
                                   jnp.logical_or(it < 2,
                                                  diff / den > p.schwarz_threshold))

        def body(carry):
            dphi, _, it = carry
            prev = dphi
            dphi = apply_physical_bc(dphi, None)
            dphi = jacobi_sweeps(dphi, rhs, p.c, p.dx, p.jacobi_sweeps)
            return dphi, prev, it + 1

        dphi, _, iters = jax.lax.while_loop(
            cond, body, (dphi0, dphi0, jnp.asarray(0, jnp.int32)))
    else:
        dphi, iters, _ = additive_schwarz_iterations(
            subdomain_solve=lambda d: jacobi_sweeps(d, rhs, p.c, p.dx,
                                                    p.jacobi_sweeps),
            communicate=lambda d: _communicate(d, comm),
            set_bc=lambda d: apply_physical_bc(d, comm),
            max_iter=p.schwarz_max_iter,
            threshold=p.schwarz_threshold,
            solution=dphi0, comm=comm)

    phi = phi + dphi
    return eta, phi, iters


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def initial_condition(p: BoussinesqParams, *, k_mode: int = 1):
    """Standing wave: eta = A cos(k x), phi = 0 (global, no ghosts)."""
    x = (jnp.arange(p.nx) + 0.5) * p.dx
    Lx = p.nx * p.dx
    eta = 0.05 * jnp.cos(k_mode * jnp.pi * x / Lx)
    return jnp.tile(eta, (p.ny, 1)), jnp.zeros((p.ny, p.nx))


def _with_ghosts(f):
    return jnp.pad(f, ((1, 1), (0, 0)))


def run_serial(p: BoussinesqParams, steps: int, *, k_mode: int = 1):
    eta, phi = initial_condition(p, k_mode=k_mode)
    eta, phi = _with_ghosts(eta), _with_ghosts(phi)

    def body(carry, _):
        eta, phi = carry
        eta, phi, iters = timestep(eta, phi, p, None)
        probe = eta[1 + p.ny // 4, p.nx // 4]
        return (eta, phi), {"mass": eta[1:-1].sum(), "probe": probe,
                            "iters": iters}

    (eta, phi), hist = jax.lax.scan(body, (eta, phi), None, length=steps)
    return eta[1:-1], phi[1:-1], hist


def run(p: BoussinesqParams, steps: int, *, k_mode: int = 1,
        executor: Executor | str = "serial", **executor_kwargs):
    """Executor-selecting driver: a :class:`MeshExecutor` runs the
    row-decomposed Schwarz solve over its mesh axis; a
    :class:`SerialExecutor` runs the single-domain serial solve (same kernel
    either way — Schwarz is domain decomposition, so only a mesh changes the
    layout).  Other executors are rejected rather than silently degraded.
    """
    executor = make_executor(executor, **executor_kwargs)
    if isinstance(executor, MeshExecutor):
        return run_parallel(executor.mesh, p, steps, k_mode=k_mode,
                            axis=executor.axis)
    if not isinstance(executor, SerialExecutor):
        raise TypeError(
            f"boussinesq.run supports 'serial' or 'mesh' executors, not "
            f"{type(executor).__name__}: the Schwarz solve is domain "
            f"decomposition, so only a mesh changes the layout")
    return run_serial(p, steps, k_mode=k_mode)


def run_parallel(mesh, p: BoussinesqParams, steps: int, *, k_mode: int = 1,
                 axis: str = "data"):
    """Row-decomposed Schwarz run; one jitted scan over time."""
    n = mesh.shape[axis]
    assert p.ny % n == 0, (p.ny, n)
    eta0, phi0 = initial_condition(p, k_mode=k_mode)

    def per_shard(eta_l, phi_l):
        comm = Comm(axis)
        eta = jnp.pad(eta_l, ((1, 1), (0, 0)))
        phi = jnp.pad(phi_l, ((1, 1), (0, 0)))

        def body(carry, _):
            eta, phi = carry
            eta, phi, iters = timestep(eta, phi, p, comm)
            mass = comm.all_reduce_sum(eta[1:-1].sum())
            return (eta, phi), {"mass": mass, "iters": iters}

        (eta, phi), hist = jax.lax.scan(body, (eta, phi), None, length=steps)
        return eta[1:-1], phi[1:-1], hist

    run = shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=(P(axis, None), P(axis, None),
                   {"mass": P(), "iters": P()}),
        check_vma=False)
    eta, phi, hist = jax.jit(run)(eta0, phi0)
    return eta, phi, hist
