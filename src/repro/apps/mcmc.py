"""Ideal-point MCMC (paper §4.1 / Appendix A) — the task-farm application.

The Clinton–Jackman–Rivers hierarchical probit model:

    P(y_ij = 1) = Phi(beta_j x_i - alpha_j)

estimated by Gibbs sampling with truncated-normal data augmentation:

  (i)   y*_ij | params  ~ N(beta_j x_i - alpha_j, 1) truncated by the vote
  (ii)  (beta_j, alpha_j) | x, y*  ~ 2x2 Bayesian regression per vote
  (iii) x_i | beta, alpha, y*      ~ 1D Bayesian regression per legislator

The paper farms *chains* out as independent ``func`` evaluations (its R
``ideal`` calls); here each chain is one task handed to any
:class:`repro.core.runtime.Executor` — serial, vmap, mesh, or thread farm —
the replacement of the paper's rpy-wrapped engine by a JAX-native one, with
the same initialize/func/finalize decomposition.

Class :class:`IdealPointProblem` mirrors the paper's ``PIPE`` class: the
constructor holds the data, and ``initialize`` / ``func`` / ``finalize`` have
exactly the generic signatures the executors demand.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.runtime import (Executor, SerialExecutor, VmapExecutor,
                                make_executor)


def make_synthetic_votes(key, n_leg: int, n_votes: int):
    """Roll-call data from known ideal points (ground truth returned)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (n_leg,))
    beta = jax.random.normal(k2, (n_votes,)) * 1.5
    alpha = jax.random.normal(k3, (n_votes,)) * 0.5
    p = jax.scipy.stats.norm.cdf(beta[None, :] * x[:, None] - alpha[None, :])
    y = (jax.random.uniform(k4, p.shape) < p).astype(jnp.float32)
    return y, {"x": x, "beta": beta, "alpha": alpha}


def _trunc_normal(key, mu, positive):
    """Sample N(mu,1) truncated to >0 (positive=True) or <0, via inverse CDF."""
    u = jax.random.uniform(key, mu.shape, minval=1e-6, maxval=1 - 1e-6)
    lo = jax.scipy.stats.norm.cdf(-mu)            # P(z < -mu) i.e. y* < 0
    u_pos = lo + u * (1 - lo)                     # map into (lo, 1)
    u_neg = u * lo                                # map into (0, lo)
    uu = jnp.where(positive, u_pos, u_neg)
    return mu + jax.scipy.special.ndtri(jnp.clip(uu, 1e-7, 1 - 1e-7))


@partial(jax.jit, static_argnames=("n_iter", "burn", "thin"))
def run_chain(key, y, *, n_iter: int = 200, burn: int = 100, thin: int = 2,
              tau2: float = 25.0):
    """One Gibbs chain.  y: (n, m) in {0,1}.  Returns posterior-mean summary
    and kept draws of x."""
    n, m = y.shape
    pos = y > 0.5

    def gibbs(carry, key):
        x, beta, alpha = carry
        k1, k2, k3 = jax.random.split(key, 3)
        mu = beta[None, :] * x[:, None] - alpha[None, :]
        ystar = _trunc_normal(k1, mu, pos)                        # (n, m)

        # (beta_j, alpha_j): design X = [x, -1] (n x 2), ridge prior tau2
        X = jnp.stack([x, -jnp.ones_like(x)], axis=1)             # (n, 2)
        XtX = X.T @ X + jnp.eye(2) / tau2                         # (2, 2)
        Xty = X.T @ ystar                                         # (2, m)
        chol = jnp.linalg.cholesky(XtX)
        mean = jax.scipy.linalg.cho_solve((chol, True), Xty)      # (2, m)
        eps = jax.random.normal(k2, (2, m))
        draw = mean + jax.scipy.linalg.solve_triangular(
            chol.T, eps, lower=False)
        beta, alpha = draw[0], draw[1]

        # x_i: regression of (y*_i + alpha) on beta
        prec = beta @ beta + 1.0 / tau2
        mean_x = (ystar + alpha[None, :]) @ beta / prec
        x = mean_x + jax.random.normal(k3, (n,)) / jnp.sqrt(prec)
        # identification: anchor location/scale
        x = (x - x.mean()) / jnp.maximum(x.std(), 1e-6)
        return (x, beta, alpha), x

    k0, kscan = jax.random.split(key)
    x0 = jax.random.normal(k0, (n,)) * 0.1
    init = (x0, jnp.zeros((m,)), jnp.zeros((m,)))
    _, draws = jax.lax.scan(gibbs, init, jax.random.split(kscan, n_iter))
    kept = draws[burn::thin]                                      # (K, n)
    return {"x_mean": kept.mean(0), "x_draws": kept}


@dataclasses.dataclass
class IdealPointProblem:
    """The paper's ``PIPE`` class, JAX edition (initialize/func/finalize)."""
    y: jnp.ndarray
    n_chains: int = 4
    n_iter: int = 200
    burn: int = 100
    seed: int = 0

    def initialize(self):
        keys = jax.random.split(jax.random.PRNGKey(self.seed), self.n_chains)
        # stacked task pytree (leading axis = tasks), vmap/shard-ready
        return {"key": keys}

    def func(self, task):
        return run_chain(task["key"], self.y, n_iter=self.n_iter,
                         burn=self.burn)

    def finalize(self, output):
        """Combine chains: posterior mean + split-R-hat convergence check."""
        draws = output["x_draws"]                 # (chains, K, n)
        x_mean = draws.mean(axis=(0, 1))
        # align chain signs (reflection invariance) before R-hat
        ref = draws[0].mean(0)
        sign = jnp.sign(jnp.einsum("ckn,n->c", draws, ref))
        draws = draws * sign[:, None, None]
        W = draws.var(axis=1).mean(0)             # within-chain
        B = draws.mean(axis=1).var(0)             # between-chain
        K = draws.shape[1]
        rhat = jnp.sqrt((W * (K - 1) / K + B) / jnp.maximum(W, 1e-12))
        self.result = {"x_mean": x_mean, "rhat": rhat}
        return self.result


def solve(problem: IdealPointProblem, executor: Executor | str = "vmap",
          **executor_kwargs):
    """Run the problem on any executor (spec string or instance).

    The application selects an executor instead of hand-wiring a tier — the
    same three problem functions drive every backend.
    """
    executor = make_executor(executor, **executor_kwargs)
    return executor.run(problem.initialize, problem.func, problem.finalize)


def solve_serial(problem: IdealPointProblem):
    """Paper's serial ``solve_problem`` driving the same three functions."""
    return solve(problem, SerialExecutor())


def solve_vmap(problem: IdealPointProblem):
    """Single-device data-parallel chains (VPU/MXU inner parallelism)."""
    return solve(problem, VmapExecutor())
