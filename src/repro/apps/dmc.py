"""Diffusion Monte Carlo (paper §4.2 / Appendix B) — the dynamic-population
application with load balancing.

Physics: N non-interacting bosons in a 3D harmonic trap,
H = -(1/2)∇² + (1/2) r² (ħ=m=ω=1).  Ground state energy E0 = 3/2 per
particle — the assertion target of the tests/benchmark.

Walkers diffuse with step N(0, sqrt(tau)) (D = 1/2) and branch with

    G_B = exp(-((V(R) + V(R'))/2 - E_T) tau),   marker = floor(G_B + u)

TPU adaptation of the paper's ``class Walkers``: the
population lives in a fixed-capacity array with a live ``count``; delete/clone
(the paper's ``delete``/``append``) are realized as a prefix-sum *compaction*
— the static-shape equivalent of list surgery.  E_T population control is the
paper's ``finalize_timestep``.

Two drivers:
* :func:`run_serial` — the paper's ``time_integration`` with a Walkers class.
* :func:`make_parallel_step` — SPMD step for ``shard_map``: each shard owns a
  sub-population; :func:`repro.core.load_balance.dynamic_load_balancing`
  (count-driven) re-balances shards exactly like the paper's
  ``redistribute_work`` moved walkers between MPI ranks.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.comm import Comm, SerialComm, make_comm, shard_map
from repro.core.load_balance import dynamic_load_balancing
from repro.core.runtime import Executor, make_executor
from repro.core.time_integration import time_integration


def potential(pos):
    """V(r) = r^2 / 2 per walker.  pos: (cap, 3)."""
    return 0.5 * jnp.sum(pos * pos, axis=-1)


# ---------------------------------------------------------------------------
# Pure-array population step (shared by serial class and SPMD step)
# ---------------------------------------------------------------------------

def walker_step(key, pos, count, e_trial, *, tau: float, max_clone: int = 2):
    """One DMC step on a fixed-capacity population.

    pos: (cap, 3); count: live prefix length; e_trial: current E_T.
    Returns (new_pos, new_count, obs) with obs = dict of estimators.
    """
    cap = pos.shape[0]
    k_move, k_branch = jax.random.split(key)
    alive = jnp.arange(cap) < count

    # -- diffusion ----------------------------------------------------------
    xi = jax.random.normal(k_move, pos.shape) * jnp.sqrt(tau)
    new_pos = pos + xi
    v_old = potential(pos)
    v_new = potential(new_pos)

    # -- branching ----------------------------------------------------------
    gb = jnp.exp(-((v_old + v_new) / 2.0 - e_trial) * tau)
    u = jax.random.uniform(k_branch, (cap,))
    marker = jnp.floor(gb + u).astype(jnp.int32)
    marker = jnp.clip(marker, 0, max_clone)
    marker = jnp.where(alive, marker, 0)

    # -- compaction (delete + clone in one scatter) --------------------------
    # new slot s takes the walker r(s) with offsets[r] <= s < offsets[r+1]
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(marker)])            # (cap+1,)
    new_count = jnp.minimum(offsets[-1], cap)
    s = jnp.arange(cap, dtype=jnp.int32)
    r = jnp.clip(jnp.searchsorted(offsets, s, side="right") - 1, 0, cap - 1)
    valid = s < new_count
    out_pos = jnp.where(valid[:, None], new_pos[r], 0.0)

    # -- observables ---------------------------------------------------------
    w = jnp.where(alive, 1.0, 0.0)
    pot_mean = jnp.sum(v_new * w) / jnp.maximum(count, 1)
    obs = {"pot": pot_mean, "count_before": count, "count_after": new_count}
    return out_pos, new_count.astype(jnp.int32), obs


def adjust_e_trial(e_trial, old_count, new_count, target, *, tau: float,
                   kappa: float = 0.1):
    """Population control (paper's ``finalize_timestep``): growth estimator
    plus a weak pull towards the target population."""
    growth = -jnp.log(jnp.maximum(new_count, 1).astype(jnp.float32)
                      / jnp.maximum(old_count, 1)) / tau
    pull = kappa * jnp.log(target / jnp.maximum(new_count, 1)
                           .astype(jnp.float32))
    return e_trial + tau * growth + pull


# ---------------------------------------------------------------------------
# Paper-faithful serial driver (class Walkers + time_integration)
# ---------------------------------------------------------------------------

class Walkers:
    """The paper's Walkers contract: __len__, move, get_marker, append/delete
    (fused into the compaction), sample_observables, finalize_timestep."""

    def __init__(self, n: int, capacity: int, *, tau: float = 0.01, seed=0):
        self.capacity = capacity
        self.tau = tau
        self.target = n
        key = jax.random.PRNGKey(seed)
        self.key, k0 = jax.random.split(key)
        pos = jax.random.normal(k0, (capacity, 3))
        self.pos = jnp.where((jnp.arange(capacity) < n)[:, None], pos, 0.0)
        self.count = jnp.asarray(n, jnp.int32)
        self.e_trial = jnp.asarray(1.5, jnp.float32)
        self._last_obs = None

    def __len__(self):
        return int(self.count)

    def move(self):
        self.key, k = jax.random.split(self.key)
        self.pos, self.count, self._last_obs = walker_step(
            k, self.pos, self.count, self.e_trial, tau=self.tau)

    def sample_observables(self):
        return {"e_trial": self.e_trial, **self._last_obs}

    def finalize_timestep(self, old_size, new_size):
        self.e_trial = adjust_e_trial(self.e_trial, old_size, new_size,
                                      self.target, tau=self.tau)


def run_serial(n_walkers: int = 500, timesteps: int = 400, *,
               capacity: int | None = None, tau: float = 0.01, seed: int = 0):
    """Paper §3.2 serial loop, verbatim structure."""
    capacity = capacity or 4 * n_walkers

    def initialize():
        return Walkers(n_walkers, capacity, tau=tau, seed=seed), timesteps

    def do_timestep(walkers):
        walkers.move()
        return walkers.sample_observables()

    def finalize(output):
        e = jnp.stack([o["e_trial"] for o in output])
        counts = jnp.stack([o["count_after"] for o in output])
        return {"e_trial": e, "counts": counts,
                "e0_estimate": e[len(e) // 2:].mean()}

    return time_integration(initialize, do_timestep, finalize)


def run_replicas(n_replicas: int = 4, executor: Executor | str = "thread",
                 n_walkers: int = 300, timesteps: int = 300, *,
                 tau: float = 0.02, seed: int = 0, **executor_kwargs):
    """Independent-replica DMC through the function-centric runtime.

    Each replica is one full serial DMC run with its own seed — a
    heavyweight *host* task (a separately-jitted program), exactly the
    paper's original task-farm scope.  The executor must therefore be a
    host tier (``serial`` or ``thread``); the thread farm overlaps replicas
    because the device computation releases the GIL.  ``finalize`` averages
    the per-replica energies and reports their spread (the standard
    independent-population error bar).
    """
    executor = make_executor(executor, **executor_kwargs)

    def initialize():
        return [((), {"n_walkers": n_walkers, "timesteps": timesteps,
                      "tau": tau, "seed": seed + i})
                for i in range(n_replicas)]

    def finalize(outputs):
        e0s = jnp.stack([o["e0_estimate"] for o in outputs])
        return {"e0_estimate": e0s.mean(), "e0_std": e0s.std(),
                "replicas": outputs}

    return executor.run(initialize, run_serial, finalize)


# ---------------------------------------------------------------------------
# SPMD step (shard_map body) with dynamic load balancing
# ---------------------------------------------------------------------------

def make_parallel_step(*, tau: float = 0.01, target: int,
                       threshold_factor: float = 1.1, axis: str = "data"):
    """Returns ``step(carry) -> (carry, obs)`` to run INSIDE shard_map.

    carry = (key, pos, count, e_trial); each shard owns its slice.  After the
    local move/branch, counts are rebalanced across the axis when skew exceeds
    ``threshold_factor`` — the paper's dynamic_load_balancing on the torus.
    """
    def step(carry):
        key, pos, count, e_trial = carry
        comm = make_comm(axis)
        key, k = jax.random.split(key)
        k = jax.random.fold_in(k, comm.rank())
        pos, count, obs = walker_step(k, pos, count, e_trial, tau=tau)

        pos, count, counts_all, rebalanced = dynamic_load_balancing(
            pos, count, comm, threshold_factor=threshold_factor)

        old_total = comm.all_reduce_sum(obs["count_before"])
        new_total = counts_all.sum()
        e_trial = adjust_e_trial(e_trial, old_total, new_total, target,
                                 tau=tau)
        pot_global = comm.all_reduce_sum(
            obs["pot"] * obs["count_before"]) / jnp.maximum(old_total, 1)
        obs = {"e_trial": e_trial, "count_after": new_total,
               "pot": pot_global, "rebalanced": rebalanced,
               "local_count": count}
        return (key, pos, count, e_trial), obs

    return step


def run_parallel(mesh, n_walkers: int = 512, timesteps: int = 200, *,
                 capacity_factor: int = 4, tau: float = 0.01, seed: int = 0,
                 axis: str = "data"):
    """Full SPMD DMC: one jitted scan over timesteps, population sharded over
    ``axis``, load-balanced every step."""
    from jax.sharding import PartitionSpec as P

    n_shards = mesh.shape[axis]
    cap_local = capacity_factor * n_walkers // n_shards
    step = make_parallel_step(tau=tau, target=n_walkers, axis=axis)

    def body(carry, _):
        return step(carry)

    def run(key):
        def per_shard(key):
            rank = jax.lax.axis_index(axis)
            k0 = jax.random.fold_in(key, rank)
            pos = jax.random.normal(k0, (cap_local, 3))
            n_local = n_walkers // n_shards
            pos = jnp.where((jnp.arange(cap_local) < n_local)[:, None],
                            pos, 0.0)
            carry = (key, pos, jnp.asarray(n_local, jnp.int32),
                     jnp.asarray(1.5, jnp.float32))
            carry, obs = jax.lax.scan(body, carry, None, length=timesteps)
            obs["local_count"] = obs["local_count"][:, None]    # (T, 1)
            return obs

        return shard_map(
            per_shard, mesh=mesh, in_specs=P(),
            out_specs={"e_trial": P(), "count_after": P(), "pot": P(),
                       "rebalanced": P(), "local_count": P(None, axis)},
            check_vma=False,
        )(key)

    obs = jax.jit(run)(jax.random.PRNGKey(seed))
    e = obs["e_trial"]
    return {"e_trial": e, "counts": obs["count_after"],
            "local_counts": obs["local_count"],
            "rebalances": obs["rebalanced"].sum(),
            "e0_estimate": e[e.shape[0] // 2:].mean()}
