"""Decoder-only LM (dense + MoE variants) with scan-over-layers.

Distribution scheme: batch->data(+pod), sequence->model
(context parallelism; KV all-gathered, cheap under GQA), MLP/vocab/experts
TP over model, weights FSDP-stored over data.  All sharding is expressed
through logical ``constrain`` calls so the same code runs single-device
(rules=None) and on the production meshes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import SerialComm
from repro.mesh.axes import AxisRules, constrain
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models.module import Param

BIG_WINDOW = 1 << 30

# Serving-TP transport default: the serial transport makes every collective
# the identity, so the single-device paged path below is byte-for-byte the
# code that ran before the mesh existed (the paper's serial/parallel duality).
_SERIAL = SerialComm()


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def stack_defs(defs, n: int):
    """Prepend a stacked ``layers`` axis to every Param in a layer def tree."""
    def stack(p: Param) -> Param:
        return Param((n,) + tuple(p.shape), P("layers", *p.spec), init=p.init,
                     scale=p.scale, dtype=p.dtype)
    return jax.tree_util.tree_map(stack, defs, is_leaf=lambda x: isinstance(x, Param))


def block_defs(cfg) -> dict:
    d = {
        "ln1": L.rmsnorm_def(cfg.d_model),
        "attn": A.attention_def(cfg),
        "ln2": L.rmsnorm_def(cfg.d_model),
    }
    if cfg.n_experts:
        d["moe"] = M.moe_def(cfg)
        if cfg.dense_residual:
            d["mlp"] = L.mlp_def(cfg.d_model, cfg.d_ff)
    else:
        d["mlp"] = L.mlp_def(cfg.d_model, cfg.d_ff)
    return d


def transformer_defs(cfg) -> dict:
    return {
        "embed": {"table": Param((cfg.padded_vocab, cfg.d_model),
                                 P("vocab", "embed_w"), init="small")},
        "blocks": stack_defs(block_defs(cfg), cfg.n_layers),
        "final_norm": L.rmsnorm_def(cfg.d_model),
        "unembed": {"w": Param((cfg.d_model, cfg.padded_vocab),
                               P("embed_w", "vocab"), init="small")},
    }


def layer_windows(cfg) -> jnp.ndarray:
    """(L,) per-layer attention window (BIG_WINDOW = global)."""
    return jnp.asarray(
        [cfg.window_for_layer(i) or BIG_WINDOW for i in range(cfg.n_layers)],
        jnp.int32)


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def block_apply(params, x, cfg, rules, *, positions, window,
                cache_k=None, cache_v=None, cache_pos=None):
    """Pre-norm block.  Returns (x, new_k, new_v) where new_k/new_v are the
    (possibly cache-updated) K/V for this layer (train: fresh; decode: cache).
    """
    h = L.rmsnorm(params["ln1"], x, use_pallas=cfg.use_pallas)
    h = constrain(h, P("batch", "seq", None), rules)
    q, k, v = A.qkv_project(params["attn"], h, cfg, positions,
                            rules=rules)

    if cache_k is not None:
        # decode: write new k/v at cache_pos, attend over the full cache.
        # cache_pos may be scalar (aligned decode) or (B,) (ragged slots —
        # continuous batching: every slot sits at its own length).
        if jnp.ndim(cache_pos) == 1:
            upd = jax.vmap(
                lambda c, x, p: jax.lax.dynamic_update_slice_in_dim(
                    c, x, p, axis=0))
            new_k = upd(cache_k, k, cache_pos)
            new_v = upd(cache_v, v, cache_pos)
        else:
            new_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, cache_pos,
                                                        axis=1)
            new_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, cache_pos,
                                                        axis=1)
        new_k = constrain(new_k, P("batch", "kv_seq", None, None), rules)
        new_v = constrain(new_v, P("batch", "kv_seq", None, None), rules)
        kv_len = cache_pos + q.shape[1]
        o = A.gqa_attention(q, new_k, new_v, causal=True, window=window,
                            q_offset=cache_pos, kv_valid_len=kv_len,
                            kv_chunk=max(cache_k.shape[1], 1),
                            use_pallas=False)
    else:
        # train/prefill: q is sequence-sharded; gather K/V across model axis
        new_k = constrain(k, P("batch", None, None, None), rules)
        new_v = constrain(v, P("batch", None, None, None), rules)
        o = A.gqa_attention(q, new_k, new_v, causal=True, window=window,
                            kv_chunk=cfg.kv_chunk, use_pallas=cfg.use_pallas)

    o = constrain(o, P("batch", "seq", None, None), rules)
    x = x + A.out_project(params["attn"], o)

    h = L.rmsnorm(params["ln2"], x, use_pallas=cfg.use_pallas)
    h = constrain(h, P("batch", "seq", None), rules)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        y, aux = M.moe_apply(params["moe"], h, cfg, rules)
        if cfg.dense_residual:
            y = y + L.mlp(params["mlp"], h)
    else:
        y = L.mlp(params["mlp"], h)
    y = constrain(y, P("batch", "seq", None), rules)
    return x + y, new_k, new_v, aux


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg, rules):
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    return constrain(x, P("batch", "seq", None), rules)


def forward(params, cfg, rules, tokens=None, inputs_embeds=None):
    """Training/scoring forward (no cache).  Returns (hidden, aux_loss)."""
    x = inputs_embeds if inputs_embeds is not None \
        else embed_tokens(params, tokens, cfg, rules)
    S = x.shape[1]
    positions = jnp.arange(S)
    windows = layer_windows(cfg)

    def body(carry, xs):
        x, aux = carry
        p, w = xs
        x, _, _, a = block_apply(p, x, cfg, rules, positions=positions,
                                 window=w)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(_remat(body, cfg),
                               (x, jnp.zeros((), jnp.float32)),
                               (params["blocks"], windows))
    x = L.rmsnorm(params["final_norm"], x, use_pallas=cfg.use_pallas)
    return x, aux / max(cfg.n_layers, 1)


def lm_logits(params, hidden, cfg, rules):
    logits = jnp.einsum("bsd,dv->bsv", hidden,
                        params["unembed"]["w"].astype(hidden.dtype),
                        preferred_element_type=jnp.float32)
    return constrain(logits, P("batch", None, "vocab"), rules)


def loss_from_hidden(unembed_w, hidden, labels, cfg, rules,
                     loss_chunks: int = 8):
    """Cross-entropy from final hidden states with sequence-chunked,
    rematerialized logits.  Shared by every architecture family.

    The loss region is vocab-parallel (Megatron-style): hidden is resharded
    to (batch: data, seq: full) so logits shard over vocab ("model") and the
    softmax reductions psum across it; the seq dim is free for chunking."""
    hidden = constrain(hidden, P("batch", None, None), rules)
    labels = constrain(labels, P("batch", None), rules)
    S = hidden.shape[1]
    chunks = loss_chunks if S % loss_chunks == 0 and S >= loss_chunks else 1
    c = S // chunks

    def chunk_loss(h_c, l_c):
        logits = jnp.einsum("bsd,dv->bsv", h_c, unembed_w.astype(h_c.dtype),
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, P("batch", None, "vocab"), rules)
        return _masked_ce_sums(logits, l_c, cfg)

    chunk_loss = jax.checkpoint(chunk_loss)

    hs = hidden.reshape(hidden.shape[0], chunks, c, -1).swapaxes(0, 1)
    ls = labels.reshape(labels.shape[0], chunks, c).swapaxes(0, 1)

    def body(carry, xs):
        tot, cnt = carry
        t, n = chunk_loss(*xs)
        return (tot + t, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),) * 2,
                                 (hs, ls))
    return tot / jnp.maximum(cnt, 1.0), cnt


def lm_loss(params, cfg, rules, tokens=None, labels=None, inputs_embeds=None,
            loss_chunks: int = 8):
    hidden, aux = forward(params, cfg, rules, tokens=tokens,
                          inputs_embeds=inputs_embeds)
    ce, cnt = loss_from_hidden(params["unembed"]["w"], hidden, labels, cfg,
                               rules, loss_chunks)
    loss = ce
    if cfg.n_experts:
        loss = loss + cfg.moe_aux_weight * aux
    return loss, {"ce": ce, "aux": aux, "tokens": cnt}


def _masked_ce_sums(logits, labels, cfg):
    """(sum nll, count) with padded-vocab masking, TP-safe (one-hot gold)."""
    v = logits.shape[-1]
    if cfg.padded_vocab > cfg.vocab:
        pad = jnp.arange(v) >= cfg.vocab
        logits = jnp.where(pad, -1e30, logits)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), v, dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = lse - gold
    w = (labels >= 0).astype(jnp.float32)
    if cfg.z_loss:
        nll = nll + cfg.z_loss * lse ** 2
    return jnp.sum(nll * w), jnp.sum(w)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def uses_window_cache(cfg) -> bool:
    """Sliding-window archs (gemma3 5:1) keep ring caches of size `window`
    for local layers — at 500k context that is a ~7x cache cut (29 of 34
    layers hold 1024 entries instead of 524288)."""
    return bool(cfg.local_window and cfg.global_every)


def layer_groups(cfg):
    """(global layer indices, local layer indices)."""
    glob = [i for i in range(cfg.n_layers) if cfg.window_for_layer(i) is None]
    loc = [i for i in range(cfg.n_layers) if cfg.window_for_layer(i) is not None]
    return glob, loc


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    hkv, hd = cfg.padded_kv_heads, cfg.head_dim
    if not uses_window_cache(cfg):
        shape = (cfg.n_layers, batch, max_len, hkv, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    glob, loc = layer_groups(cfg)
    W = min(cfg.local_window, max_len)
    return {
        "k": jnp.zeros((len(glob), batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((len(glob), batch, max_len, hkv, hd), dtype),
        # ring buffers: slot W-1 always holds the newest position
        "k_loc": jnp.zeros((len(loc), batch, W, hkv, hd), dtype),
        "v_loc": jnp.zeros((len(loc), batch, W, hkv, hd), dtype),
    }


def cache_specs(cfg):
    s = P("layers", "batch", "kv_seq", None, None)
    if not uses_window_cache(cfg):
        return {"k": s, "v": s}
    return {"k": s, "v": s, "k_loc": s, "v_loc": s}


def prefill(params, cfg, rules, tokens=None, inputs_embeds=None,
            max_len: Optional[int] = None):
    """Run the prompt, build the cache.  Returns (cache, hidden (B,S,d)).

    The full hidden sequence is returned (not just the last position) so
    callers with right-padded prompts can read the hidden state at their own
    valid length (the serving engine's bucketed prefill does)."""
    x = inputs_embeds if inputs_embeds is not None \
        else embed_tokens(params, tokens, cfg, rules)
    B, S = x.shape[0], x.shape[1]
    max_len = max_len or S
    positions = jnp.arange(S)
    windows = layer_windows(cfg)

    def body(x, xs):
        p, w = xs
        x, k, v, _ = block_apply(p, x, cfg, rules, positions=positions,
                                 window=w)
        if max_len > S:
            pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        k = constrain(k, P("batch", "kv_seq", None, None), rules)
        v = constrain(v, P("batch", "kv_seq", None, None), rules)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(_remat(body, cfg), x,
                               (params["blocks"], windows))
    x = L.rmsnorm(params["final_norm"], x, use_pallas=cfg.use_pallas)
    if not uses_window_cache(cfg):
        return {"k": ks, "v": vs}, x
    # compress local layers to their ring windows (right-aligned: slot W-1
    # = newest position; short prompts left-pad with masked zeros)
    glob, loc = layer_groups(cfg)
    W = min(cfg.local_window, max_len)
    take = min(W, S)
    k_loc = ks[jnp.asarray(loc)][:, :, S - take:S]
    v_loc = vs[jnp.asarray(loc)][:, :, S - take:S]
    pad = [(0, 0), (0, 0), (W - take, 0), (0, 0), (0, 0)]
    return {"k": ks[jnp.asarray(glob)], "v": vs[jnp.asarray(glob)],
            "k_loc": jnp.pad(k_loc, pad), "v_loc": jnp.pad(v_loc, pad)}, x


# ---------------------------------------------------------------------------
# Serving: paged KV cache (pool storage instead of per-slot dense buffers)
# ---------------------------------------------------------------------------

def _write_kv(kv, k, v, quant, scatter):
    """Commit fresh K/V into one layer's page storage through a pure
    ``scatter(leaf_storage, vals) -> leaf_storage`` op.

    With a ``quant`` policy the values are quantized first and the
    per-row scales scatter through the SAME op into their sibling
    ``k_scale``/``v_scale`` leaves — the policy supplies the numerics,
    this helper only routes blocks to leaves (the function-centric split),
    so prefill chunks, decode tokens and verify windows all write
    quantized pages with one code path.
    """
    if quant is None:
        return dict(kv, k=scatter(kv["k"], k), v=scatter(kv["v"], v))
    qk, sk = quant.quantize(k)
    qv, sv = quant.quantize(v)
    return dict(kv, k=scatter(kv["k"], qk), v=scatter(kv["v"], qv),
                k_scale=scatter(kv["k_scale"], sk),
                v_scale=scatter(kv["v_scale"], sv))


def _paged_block(p, x, cfg, rules, *, positions, kv, tables,
                 q_offset, write, use_pallas=False, comm=_SERIAL,
                 ep_comm=None, placement=None):
    """One decoder block against paged KV storage (per-layer page slices).

    ``kv`` is this layer's slice of the pool storage tree — ``{"k", "v"}``
    pages, plus ``{"k_scale", "v_scale"}`` per-row scale leaves when the
    cache is quantized.  ``write(kv, k, v) -> kv`` commits the fresh K/V
    into pages — a whole-chunk scatter during prefill, a per-slot token
    scatter during decode, a per-slot window scatter during verify — so
    this block stays agnostic of which phase it runs in.  Attention is one
    call for all three phases:
    :func:`repro.models.attention.paged_window_attention` with ``q_offset``
    tokens cached before the query window, fused Pallas kernel or jnp
    gather fallback per ``use_pallas`` (both dequantize scale leaves when
    present: the kernel in its VMEM tile, the fallback after its gather).

    ``comm`` is the serving-TP transport (Megatron attention/MLP TP inside a
    ``shard_map`` body): the block then sees its local head / ff / expert
    shard of the weights and the KV pages, computes attention entirely on
    local heads, and reassembles the residual stream with one ``psum`` after
    each of the two projections back to d_model.  The serial transport makes
    both psums the identity, so this is one code path for both worlds.

    ``ep_comm`` is the expert-parallel transport: expert weights arrive
    partitioned E/ep per rank over that axis and the MoE block exchanges
    its dispatch buffer through ``all_to_all`` (see
    :func:`repro.models.moe.moe_apply_expert_parallel`); ``placement`` is
    the (3, E) expert→slot dispatch map.  Returns ``(x, kv, moe_stats)``
    where ``moe_stats`` is the per-expert token/drop telemetry (zeros for
    dense blocks).
    """
    h = L.rmsnorm(p["ln1"], x, use_pallas=cfg.use_pallas)
    q, k, v = A.qkv_project(p["attn"], h, cfg, positions, rules=rules)
    kv = write(kv, k, v)
    o = A.paged_window_attention(q, kv["k"], kv["v"], tables, q_offset,
                                 k_scale=kv.get("k_scale"),
                                 v_scale=kv.get("v_scale"),
                                 use_pallas=use_pallas)
    x = x + comm.all_reduce_sum(A.out_project(p["attn"], o))

    h = L.rmsnorm(p["ln2"], x, use_pallas=cfg.use_pallas)
    moe_stats = M.empty_expert_stats(cfg.n_experts)
    if cfg.n_experts:
        if comm.axis is None and ep_comm is None and rules is not None:
            # training-style rules path: moe_apply owns its own shard_map
            y, _ = M.moe_apply(p["moe"], h, cfg, rules)
        else:
            # serving: expert-sharded (ep axis) and/or GEMM-sharded (tp
            # axis), replicated activations; output already combined
            y, _, moe_stats = M.moe_apply_expert_parallel(
                p["moe"], h, cfg, _SERIAL if ep_comm is None else ep_comm,
                shard_comm=comm if comm.axis is not None else None,
                placement=placement)
        if cfg.dense_residual:
            y = y + comm.all_reduce_sum(L.mlp(p["mlp"], h))
    else:
        y = comm.all_reduce_sum(L.mlp(p["mlp"], h))
    return x + y, kv, moe_stats


def paged_prefill_chunk(params, cfg, rules, storage, table_row, pages_chunk,
                        start, tokens, use_pallas=False, comm=None,
                        quant=None, ep_comm=None, placement=None,
                        embeds=None):
    """Prefill one page-aligned prompt chunk into paged storage.

    storage: {"k","v"} of (L, N, page_size, Hkv, D) — plus per-row
    {"k_scale","v_scale"} (L, N, page_size, Hkv) leaves when ``quant`` is
    set;  table_row: (P,) the slot's page table;  pages_chunk:
    (C // page_size,) pages covering positions [start, start + C);
    tokens: (1, C) (right-padded — the validity length masks pad garbage,
    exactly like bucketed dense prefill).  Returns (storage, hidden
    (1, C, d), telemetry) where telemetry is the layer-summed per-expert
    ``{"expert_tokens", "expert_dropped"}`` int32 counts ((0,)-shaped for
    dense models).  Chunks attend causally to every previously prefilled
    page, which is what lets long prompts prefill incrementally between
    decode ticks.  ``use_pallas`` routes attention through the fused
    multi-query kernel (W = C window, per-row causal offsets) instead of
    the jnp gather fallback.

    ``quant`` is the KV quantization policy (quantize-on-write; attention
    dequantizes through the scale leaves) — prefilled pages hold the SAME
    int8 content a decode write would produce, which is what keeps
    prefix-cache sharing exact under quantization.

    With a mesh ``comm`` (inside ``shard_map``): params/storage arrive
    head-sharded, hidden stays replicated (see :func:`_paged_block`).

    ``embeds`` opens the encoder-attached (VLM) path: a (1, C, d) buffer of
    precomputed embeddings spliced in wherever ``tokens`` is negative (the
    scheduler's image pseudo-tokens).  Real token positions still read the
    embedding table, so a chunk can mix image-prefix and text positions;
    with ``embeds=None`` the function is byte-identical to the text-only
    path — the zero-special-cases contract the multimodal tier rides on.
    """
    from repro.serve import pages as PG
    assert not uses_window_cache(cfg), "paged decode is global-attention only"
    comm = _SERIAL if comm is None else comm
    page_size = storage["k"].shape[2]
    if embeds is None:
        x = embed_tokens(params, tokens, cfg, rules)
    else:
        x = embed_tokens(params, jnp.maximum(tokens, 0), cfg, rules)
        x = jnp.where((tokens < 0)[..., None], embeds.astype(x.dtype), x)
    C = x.shape[1]
    positions = start + jnp.arange(C)
    tables = table_row[None]                                    # (1, P)

    def write(kv, k, v):
        return _write_kv(
            kv, k[0], v[0], quant,
            lambda st, val: PG.scatter_chunk(st, pages_chunk, val,
                                             page_size=page_size))

    def body(carry, xs):
        x, tok, drp = carry
        p, kv = xs
        x, kv, ms = _paged_block(p, x, cfg, rules, positions=positions,
                                 kv=kv, tables=tables,
                                 q_offset=start, write=write,
                                 use_pallas=use_pallas, comm=comm,
                                 ep_comm=ep_comm, placement=placement)
        return (x, tok + ms["tokens"], drp + ms["dropped"]), kv

    z = jnp.zeros((cfg.n_experts,), jnp.int32)
    (x, tok, drp), storage = jax.lax.scan(body, (x, z, z),
                                          (params["blocks"], storage))
    x = L.rmsnorm(params["final_norm"], x, use_pallas=cfg.use_pallas)
    return storage, x, {"expert_tokens": tok, "expert_dropped": drp}


def paged_decode_step(params, cfg, rules, storage, tables, lengths, tokens,
                      write_pages, write_offs, use_pallas=False,
                      comm=None, quant=None, ep_comm=None, placement=None):
    """One token for every slot against paged storage.

    tokens: (B, 1);  tables: (B, P);  lengths: (B,) tokens already cached
    (= the current token's position);  write_pages/write_offs: (B,) where
    each slot's new K/V lands (dead slots point at the pool's trash page).
    Returns (storage, logits (B, 1, V), telemetry) — telemetry as in
    :func:`paged_prefill_chunk`.  ``quant`` quantizes each token's K/V on
    write (scales land in the storage's scale leaves).

    With a mesh ``comm`` (inside ``shard_map``) the unembed arrives
    vocab-sharded and the local logits are reassembled with a single tiled
    ``all_gather`` — the one collective at the logits head.
    """
    from repro.serve import pages as PG
    assert not uses_window_cache(cfg), "paged decode is global-attention only"
    comm = _SERIAL if comm is None else comm
    x = embed_tokens(params, tokens, cfg, rules)
    positions = lengths[:, None]                                # (B, 1)

    def write(kv, k, v):
        return _write_kv(
            kv, k[:, 0], v[:, 0], quant,
            lambda st, val: PG.scatter_token(st, write_pages, write_offs,
                                             val))

    def body(carry, xs):
        x, tok, drp = carry
        p, kv = xs
        x, kv, ms = _paged_block(p, x, cfg, rules, positions=positions,
                                 kv=kv, tables=tables,
                                 q_offset=lengths, write=write,
                                 use_pallas=use_pallas, comm=comm,
                                 ep_comm=ep_comm, placement=placement)
        return (x, tok + ms["tokens"], drp + ms["dropped"]), kv

    z = jnp.zeros((cfg.n_experts,), jnp.int32)
    (x, tok, drp), storage = jax.lax.scan(body, (x, z, z),
                                          (params["blocks"], storage))
    x = L.rmsnorm(params["final_norm"], x, use_pallas=cfg.use_pallas)
    logits = comm.all_gather(lm_logits(params, x, cfg, rules),
                             axis=-1, tiled=True)
    return storage, logits, {"expert_tokens": tok, "expert_dropped": drp}


def paged_verify_chunk(params, cfg, rules, storage, tables, lengths, tokens,
                       write_pages, write_offs, use_pallas=False, comm=None,
                       quant=None, ep_comm=None, placement=None):
    """Score a per-slot window of candidate tokens in ONE batched forward —
    the speculative-decode verify step.

    tokens: (B, C) — position 0 is each slot's next input token (its K/V is
    not yet cached), positions 1..C-1 are draft continuations (right-padded
    for slots with shorter windows); tables: (B, P); lengths: (B,) tokens
    already cached (= the absolute position of tokens[:, 0]);
    write_pages/write_offs: (B, C) per-position K/V targets — pad and
    dead-slot positions point at the pool's trash page, so the SPMD call
    keeps static shapes while rejected/padded K/V never lands in a live
    page it wasn't meant for.

    Returns (storage, logits (B, C, V), telemetry — as in
    :func:`paged_prefill_chunk`): logits[:, i] is the target
    distribution for the token FOLLOWING tokens[:, i] — what the
    speculative acceptance rule scores draft i+1 against (and the
    correction/bonus is sampled from).  C == 1 is exactly a decode step.

    Causality makes padding safe: query i attends keys <= lengths + i, and
    every real position's K/V is written (to its real page) before
    attention runs, while pad positions can only influence pad logits.
    ``use_pallas`` scores the whole window with the fused multi-query
    kernel (same per-row causal rule), keeping spec-on/spec-off greedy
    bit-parity intact.

    With a mesh ``comm`` (inside ``shard_map``) this is sharded exactly
    like :func:`paged_decode_step`: params/storage head-sharded, one psum
    after each residual projection, one tiled all_gather at the logits
    head.
    """
    from repro.serve import pages as PG
    assert not uses_window_cache(cfg), "paged decode is global-attention only"
    comm = _SERIAL if comm is None else comm
    x = embed_tokens(params, tokens, cfg, rules)
    C = x.shape[1]
    positions = lengths[:, None] + jnp.arange(C)                # (B, C)

    def write(kv, k, v):
        return _write_kv(
            kv, k, v, quant,
            lambda st, val: PG.scatter_window(st, write_pages, write_offs,
                                              val))

    def body(carry, xs):
        x, tok, drp = carry
        p, kv = xs
        x, kv, ms = _paged_block(p, x, cfg, rules, positions=positions,
                                 kv=kv, tables=tables,
                                 q_offset=lengths, write=write,
                                 use_pallas=use_pallas, comm=comm,
                                 ep_comm=ep_comm, placement=placement)
        return (x, tok + ms["tokens"], drp + ms["dropped"]), kv

    z = jnp.zeros((cfg.n_experts,), jnp.int32)
    (x, tok, drp), storage = jax.lax.scan(body, (x, z, z),
                                          (params["blocks"], storage))
    x = L.rmsnorm(params["final_norm"], x, use_pallas=cfg.use_pallas)
    logits = comm.all_gather(lm_logits(params, x, cfg, rules),
                             axis=-1, tiled=True)
    return storage, logits, {"expert_tokens": tok, "expert_dropped": drp}


def _window_decode_step(params, cfg, rules, cache, tokens, pos):
    """Decode with mixed caches: full KV for global layers, ring buffers of
    size W for sliding-window layers (aligned decode only: scalar ``pos``)."""
    assert jnp.ndim(pos) == 0, "window-cache decode is aligned-only"
    glob, loc = layer_groups(cfg)
    g_of = {i: glob.index(i) for i in glob}
    l_of = {i: loc.index(i) for i in loc}
    W = cache["k_loc"].shape[2]

    x = embed_tokens(params, tokens, cfg, rules)
    x = constrain(x, P("batch", None, None), rules)
    positions = jnp.asarray(pos)[..., None] + jnp.arange(1)

    new_g_k, new_g_v = list(range(len(glob))), list(range(len(glob)))
    new_l_k, new_l_v = list(range(len(loc))), list(range(len(loc)))
    for i in range(cfg.n_layers):
        p = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
        h = L.rmsnorm(p["ln1"], x)
        q, k, v = A.qkv_project(p["attn"], h, cfg, positions, rules=rules)
        if i in g_of:                                  # global: normal cache
            g = g_of[i]
            nk = jax.lax.dynamic_update_slice_in_dim(cache["k"][g], k, pos,
                                                     axis=1)
            nv = jax.lax.dynamic_update_slice_in_dim(cache["v"][g], v, pos,
                                                     axis=1)
            nk = constrain(nk, P("batch", "kv_seq", None, None), rules)
            nv = constrain(nv, P("batch", "kv_seq", None, None), rules)
            o = A.gqa_attention(q, nk, nv, causal=True, q_offset=pos,
                                kv_valid_len=pos + 1,
                                kv_chunk=max(nk.shape[1], 1))
            new_g_k[g], new_g_v[g] = nk, nv
        else:                                          # local: ring buffer
            l = l_of[i]
            nk = jnp.concatenate([cache["k_loc"][l][:, 1:], k], axis=1)
            nv = jnp.concatenate([cache["v_loc"][l][:, 1:], v], axis=1)
            nk = constrain(nk, P("batch", "kv_seq", None, None), rules)
            nv = constrain(nv, P("batch", "kv_seq", None, None), rules)
            o = A.gqa_attention(q, nk, nv, causal=True,
                                window=cfg.local_window, q_offset=pos,
                                k_start=pos - W + 1, kv_chunk=W)
            new_l_k[l], new_l_v[l] = nk, nv
        x = x + A.out_project(p["attn"], o)
        h = L.rmsnorm(p["ln2"], x)
        x = x + L.mlp(p["mlp"], h)

    x = L.rmsnorm(params["final_norm"], x, use_pallas=cfg.use_pallas)
    logits = lm_logits(params, x, cfg, rules)
    new_cache = {"k": jnp.stack(new_g_k), "v": jnp.stack(new_g_v),
                 "k_loc": jnp.stack(new_l_k), "v_loc": jnp.stack(new_l_v)}
    return new_cache, logits


def decode_step(params, cfg, rules, cache, tokens, pos):
    """One token for every sequence.  tokens: (B, 1); pos: scalar int32
    (aligned) or (B,) int32 (ragged slots).  Returns (cache, logits)."""
    if uses_window_cache(cfg):
        return _window_decode_step(params, cfg, rules, cache, tokens, pos)
    x = embed_tokens(params, tokens, cfg, rules)
    x = constrain(x, P("batch", None, None), rules)
    positions = jnp.asarray(pos)[..., None] + jnp.arange(1)
    windows = layer_windows(cfg)

    def body(x, xs):
        p, w, ck, cv = xs
        x, nk, nv, _ = block_apply(p, x, cfg, rules, positions=positions,
                                   window=w, cache_k=ck, cache_v=cv,
                                   cache_pos=pos)
        return x, (nk, nv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], windows,
                                         cache["k"], cache["v"]))
    x = L.rmsnorm(params["final_norm"], x, use_pallas=cfg.use_pallas)
    logits = lm_logits(params, x, cfg, rules)
    return {"k": ks, "v": vs}, logits
