"""Mamba-2 (SSD) block — chunked state-space dual form.

TPU adaptation: the CUDA SSD kernel's warp-level scan is
re-blocked as *chunked* SSD — intra-chunk quadratic attention-like GEMMs that
feed the MXU, plus an inter-chunk state recurrence carried by ``lax.scan``.
Heads (d_inner/head_dim = 112 for zamba2-7b) are TP-sharded over ``model``
(divisible by 16); the sequence stays unsharded inside the recurrence.

The Pallas kernel (:mod:`repro.kernels.ssd_scan`) implements the same chunking
with the state resident in VMEM; this module is its jnp oracle-equivalent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.mesh.axes import constrain
from repro.models import layers as L
from repro.models.module import Param


def mamba2_def(cfg) -> dict:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    K = cfg.conv_kernel
    return {
        "w_z": Param((d, di), P("embed_w", "inner")),
        "w_x": Param((d, di), P("embed_w", "inner")),
        "w_B": Param((d, N), P("embed_w", None)),
        "w_C": Param((d, N), P("embed_w", None)),
        "w_dt": Param((d, H), P("embed_w", "ssm_heads")),
        "conv_x": Param((K, di), P("conv_k", "inner"), init="small"),
        "conv_B": Param((K, N), P("conv_k", None), init="small"),
        "conv_C": Param((K, N), P("conv_k", None), init="small"),
        "A_log": Param((H,), P("ssm_heads"), init="zeros"),
        "D": Param((H,), P("ssm_heads"), init="ones"),
        "dt_bias": Param((H,), P("ssm_heads"), init="zeros"),
        "out_norm": L.rmsnorm_def(di),
        "w_out": Param((di, d), P("inner", "embed_w")),
    }


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv.  x: (B,S,C), w: (K,C).
    With ``conv_state`` (B,K-1,C) the history is prepended (decode)."""
    K = w.shape[0]
    if conv_state is not None:
        x_pad = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    else:
        x_pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + x_pad[:, i:i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out).astype(x.dtype)


def _ssd_chunked(xh, dt, a, Bm, Cm, chunk: int, state0=None):
    """Chunked SSD.

    xh: (B,S,H,Pd)  head inputs
    dt: (B,S,H)     post-softplus step sizes
    a:  (B,S,H)     per-step decay in (0,1]
    Bm, Cm: (B,S,N) input/output projections (single group)
    Returns (y (B,S,H,Pd), final_state (B,H,N,Pd)).
    """
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    xdt = (xh * dt[..., None]).astype(jnp.float32)
    la = jnp.log(jnp.maximum(a, 1e-20)).astype(jnp.float32)      # (B,S,H)

    def rs(t, extra=()):  # (B,S,...) -> (nc, B, Q, ...)
        return t.reshape((Bsz, nc, Q) + t.shape[2:]).swapaxes(0, 1)

    xdt_c, la_c = rs(xdt), rs(la)
    B_c, C_c = rs(Bm.astype(jnp.float32)), rs(Cm.astype(jnp.float32))

    if state0 is None:
        state0 = jnp.zeros((Bsz, H, N, Pd), jnp.float32)

    def body(state, xs):
        xdt_k, la_k, B_k, C_k = xs                 # (B,Q,H,P),(B,Q,H),(B,Q,N)
        cs = jnp.cumsum(la_k, axis=1)              # (B,Q,H) inclusive
        total = cs[:, -1:]                         # (B,1,H)
        # intra-chunk: y_i += C_i . B_j * exp(cs_i - cs_j) * xdt_j (j<=i)
        G = jnp.einsum("bqn,bkn->bqk", C_k, B_k)   # (B,Q,Q)
        Ldec = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])      # (B,Q,K,H)
        mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
        M = G[..., None] * jnp.where(mask[None, :, :, None], Ldec, 0.0)
        y = jnp.einsum("bqkh,bkhp->bqhp", M, xdt_k)
        # inter-chunk: y_i += C_i . state * exp(cs_i)
        y = y + jnp.einsum("bqn,bhnp,bqh->bqhp", C_k, state, jnp.exp(cs))
        # state update: state = exp(total) * state + sum_j exp(total - cs_j) B_j xdt_j
        wj = jnp.exp(total - cs)                   # (B,Q,H)
        new_state = state * jnp.exp(total).transpose(0, 2, 1)[..., None]
        new_state = new_state + jnp.einsum("bqn,bqh,bqhp->bhnp", B_k, wj, xdt_k)
        return new_state, y

    state, ys = jax.lax.scan(body, state0, (xdt_c, la_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(Bsz, S, H, Pd)
    return y.astype(xh.dtype), state


def mamba2_block(params, x, cfg, rules, *, ssm_state=None, conv_state=None,
                 chunk: int = 256):
    """x: (B,S,d).  Training: states None.  Decode (S small): pass and
    receive (ssm_state (B,H,N,Pd) f32, conv_state dict of (B,K-1,C)).

    Returns (y (B,S,d), new_ssm_state, new_conv_state).
    """
    H, Pd, N, K = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.conv_kernel
    z = x @ params["w_z"].astype(x.dtype)
    xc = x @ params["w_x"].astype(x.dtype)
    Bm = x @ params["w_B"].astype(x.dtype)
    Cm = x @ params["w_C"].astype(x.dtype)
    dt = x @ params["w_dt"].astype(x.dtype)

    new_conv = None
    if conv_state is not None:
        cat = lambda old, new: jnp.concatenate(
            [old, new.astype(old.dtype)], axis=1)[:, -(K - 1):]
        new_conv = {"x": cat(conv_state["x"], xc),
                    "B": cat(conv_state["B"], Bm),
                    "C": cat(conv_state["C"], Cm)}
        xc = _causal_conv(xc, params["conv_x"], conv_state["x"])
        Bm = _causal_conv(Bm, params["conv_B"], conv_state["B"])
        Cm = _causal_conv(Cm, params["conv_C"], conv_state["C"])
    else:
        xc = _causal_conv(xc, params["conv_x"])
        Bm = _causal_conv(Bm, params["conv_B"])
        Cm = _causal_conv(Cm, params["conv_C"])

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-jnp.exp(params["A_log"].astype(jnp.float32)) * dt)  # (B,S,H)

    xh = xc.reshape(xc.shape[0], xc.shape[1], H, Pd)
    xh = constrain(xh, P("batch", None, "ssm_heads", None), rules)
    y, new_state = _ssd_chunked(xh, dt, a, Bm, Cm, chunk, state0=ssm_state)
    y = y + xh.astype(jnp.float32) * params["D"].astype(jnp.float32)[:, None]
    y = y.reshape(xc.shape)

    y = L.rmsnorm(params["out_norm"], y.astype(x.dtype) * jax.nn.silu(z))
    out = y @ params["w_out"].astype(y.dtype)
    return out, new_state, new_conv


def init_mamba_state(cfg, batch: int):
    H, Pd, N, K = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.conv_kernel
    return {
        "ssm": jnp.zeros((batch, H, N, Pd), jnp.float32),
        "conv": {"x": jnp.zeros((batch, K - 1, cfg.d_inner), jnp.float32),
                 "B": jnp.zeros((batch, K - 1, N), jnp.float32),
                 "C": jnp.zeros((batch, K - 1, N), jnp.float32)},
    }
