"""Whisper-style encoder-decoder transformer (conv frontend stubbed).

Per the assignment brief the audio frontend is a STUB: inputs are precomputed
frame embeddings ``(B, n_audio_frames, d_model)`` (what the two conv layers
would produce), fed straight into the bidirectional encoder.  The decoder has
causal self-attention plus cross-attention over the encoder output, LayerNorm
(not RMSNorm) and biased GELU MLPs, matching the published architecture.

Serving: the cross-attention K/V are computed ONCE from the encoder output at
prefill and reused every decode step (standard enc-dec serving split); the
self-attention cache grows like a decoder-only LM's.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.mesh.axes import constrain
from repro.models import attention as A
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.module import Param


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def _attn_def(cfg, *, cross: bool = False) -> dict:
    d, hd, h = cfg.d_model, cfg.head_dim, cfg.n_heads
    defs = {
        "wq": Param((d, h, hd), P("embed_w", "q_heads", "head_dim")),
        "wk": Param((d, h, hd), P("embed_w", "kv_heads", "head_dim")),
        "wv": Param((d, h, hd), P("embed_w", "kv_heads", "head_dim")),
        "wo": Param((h, hd, d), P("q_heads", "head_dim", "embed_w")),
        "bq": Param((h, hd), P("q_heads", "head_dim"), init="zeros"),
        "bv": Param((h, hd), P("kv_heads", "head_dim"), init="zeros"),
        "bo": Param((d,), P(None), init="zeros"),
    }
    return defs


def _enc_block_def(cfg) -> dict:
    return {
        "ln1": L.layernorm_def(cfg.d_model),
        "attn": _attn_def(cfg),
        "ln2": L.layernorm_def(cfg.d_model),
        "mlp": L.mlp_plain_def(cfg.d_model, cfg.d_ff),
    }


def _dec_block_def(cfg) -> dict:
    return {
        "ln1": L.layernorm_def(cfg.d_model),
        "self_attn": _attn_def(cfg),
        "ln_x": L.layernorm_def(cfg.d_model),
        "cross_attn": _attn_def(cfg, cross=True),
        "ln2": L.layernorm_def(cfg.d_model),
        "mlp": L.mlp_plain_def(cfg.d_model, cfg.d_ff),
    }


def whisper_defs(cfg) -> dict:
    return {
        "enc_blocks": T.stack_defs(_enc_block_def(cfg), cfg.n_layers),
        "enc_norm": L.layernorm_def(cfg.d_model),
        "embed": {"table": Param((cfg.padded_vocab, cfg.d_model),
                                 P("vocab", "embed_w"), init="small")},
        "dec_blocks": T.stack_defs(_dec_block_def(cfg), cfg.decoder_layers),
        "dec_norm": L.layernorm_def(cfg.d_model),
        # whisper ties the unembedding to the token embedding; we keep a
        # separate head for TP-friendly vocab sharding symmetry with the LMs.
        "unembed": {"w": Param((cfg.d_model, cfg.padded_vocab),
                               P("embed_w", "vocab"), init="small")},
    }


# ---------------------------------------------------------------------------
# Attention helpers (MHA with q/v biases, whisper style: no k bias)
# ---------------------------------------------------------------------------

def _project_qkv(p, xq, xkv, dtype):
    q = jnp.einsum("bsd,dhe->bshe", xq, p["wq"].astype(dtype)) + p["bq"].astype(dtype)
    k = jnp.einsum("bsd,dhe->bshe", xkv, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhe->bshe", xkv, p["wv"].astype(dtype)) + p["bv"].astype(dtype)
    return q, k, v


def _out(p, o):
    return jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(o.dtype)) \
        + p["bo"].astype(o.dtype)


def _mha(p, xq, xkv, cfg, *, causal, q_offset=0, kv_valid_len=None,
         cache_k=None, cache_v=None, cache_pos=None):
    """Self- or cross-attention.  Returns (out, new_k, new_v)."""
    q, k, v = _project_qkv(p, xq, xkv, xq.dtype)
    if cache_k is not None:
        k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, cache_pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, cache_pos, axis=1)
    o = A.gqa_attention(q, k, v, causal=causal, q_offset=q_offset,
                        kv_valid_len=kv_valid_len, kv_chunk=cfg.kv_chunk,
                        use_pallas=cfg.use_pallas and cache_k is None
                        and kv_valid_len is None)
    return _out(p, o), k, v


def _cross(p, xq, enc_k, enc_v, cfg):
    """Cross-attention against precomputed encoder K/V."""
    dtype = xq.dtype
    q = jnp.einsum("bsd,dhe->bshe", xq, p["wq"].astype(dtype)) + p["bq"].astype(dtype)
    o = A.gqa_attention(q, enc_k, enc_v, causal=False, kv_chunk=cfg.kv_chunk,
                        use_pallas=False)
    return _out(p, o)


def _cross_kv(p, enc_out):
    dtype = enc_out.dtype
    k = jnp.einsum("bsd,dhe->bshe", enc_out, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhe->bshe", enc_out, p["wv"].astype(dtype)) \
        + p["bv"].astype(dtype)
    return k, v


# ---------------------------------------------------------------------------
# Encoder / decoder stacks
# ---------------------------------------------------------------------------

def encode(params, cfg, rules, frames):
    """frames: (B, F, d) precomputed frame embeddings (stub frontend)."""
    pos = L.sinusoidal_pos(jnp.arange(frames.shape[1]), cfg.d_model)
    x = frames + pos.astype(frames.dtype)
    x = constrain(x, P("batch", "frames", None), rules)

    def body(x, p):
        h = L.layernorm(p["ln1"], x)
        o, _, _ = _mha(p["attn"], h, h, cfg, causal=False)
        x = x + o
        h = L.layernorm(p["ln2"], x)
        return x + L.mlp_plain(p["mlp"], h), None

    x, _ = jax.lax.scan(T._remat(body, cfg), x, params["enc_blocks"])
    return L.layernorm(params["enc_norm"], x)


def decode_train(params, cfg, rules, tokens, enc_out):
    """Teacher-forced decoder forward -> final hidden."""
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = x + L.sinusoidal_pos(jnp.arange(x.shape[1]),
                             cfg.d_model).astype(x.dtype)
    x = constrain(x, P("batch", "seq", None), rules)

    def body(x, p):
        h = L.layernorm(p["ln1"], x)
        o, _, _ = _mha(p["self_attn"], h, h, cfg, causal=True)
        x = x + o
        h = L.layernorm(p["ln_x"], x)
        ek, ev = _cross_kv(p["cross_attn"], enc_out)
        x = x + _cross(p["cross_attn"], h, ek, ev, cfg)
        h = L.layernorm(p["ln2"], x)
        return x + L.mlp_plain(p["mlp"], h), None

    x, _ = jax.lax.scan(T._remat(body, cfg), x, params["dec_blocks"])
    return L.layernorm(params["dec_norm"], x)


def loss(params, cfg, rules, frames, tokens, labels, loss_chunks: int = 8):
    enc_out = encode(params, cfg, rules, frames)
    hidden = decode_train(params, cfg, rules, tokens, enc_out)
    ce, cnt = T.loss_from_hidden(params["unembed"]["w"], hidden, labels, cfg,
                                 rules, loss_chunks)
    return ce, {"ce": ce, "tokens": cnt}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_state(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    h, hd, Ld = cfg.n_heads, cfg.head_dim, cfg.decoder_layers
    F = cfg.n_audio_frames
    return {
        "self_k": jnp.zeros((Ld, batch, max_len, h, hd), dtype),
        "self_v": jnp.zeros((Ld, batch, max_len, h, hd), dtype),
        "cross_k": jnp.zeros((Ld, batch, F, h, hd), dtype),
        "cross_v": jnp.zeros((Ld, batch, F, h, hd), dtype),
    }


def state_specs(cfg):
    s = P(None, "batch", "kv_seq", None, None)
    c = P(None, "batch", "frames", None, None)
    return {"self_k": s, "self_v": s, "cross_k": c, "cross_v": c}


def prefill(params, cfg, rules, frames, tokens, max_len: int):
    """Encode audio, precompute cross K/V, run the decoder prompt."""
    B, S = tokens.shape
    enc_out = encode(params, cfg, rules, frames)
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = x + L.sinusoidal_pos(jnp.arange(S), cfg.d_model).astype(x.dtype)

    sks, svs, cks, cvs = [], [], [], []
    Ld = cfg.decoder_layers
    for i in range(Ld):
        p = jax.tree_util.tree_map(lambda a: a[i], params["dec_blocks"])
        h = L.layernorm(p["ln1"], x)
        cache_k = jnp.zeros((B, max_len, cfg.n_heads, cfg.head_dim),
                            jnp.dtype(cfg.dtype))
        o, k, v = _mha(p["self_attn"], h, h, cfg, causal=True,
                       kv_valid_len=S, cache_k=cache_k,
                       cache_v=jnp.zeros_like(cache_k),
                       cache_pos=jnp.asarray(0, jnp.int32))
        x = x + o
        ek, ev = _cross_kv(p["cross_attn"], enc_out)
        h = L.layernorm(p["ln_x"], x)
        x = x + _cross(p["cross_attn"], h, ek, ev, cfg)
        h = L.layernorm(p["ln2"], x)
        x = x + L.mlp_plain(p["mlp"], h)
        sks.append(k); svs.append(v); cks.append(ek); cvs.append(ev)
    x = L.layernorm(params["dec_norm"], x)
    state = {"self_k": jnp.stack(sks), "self_v": jnp.stack(svs),
             "cross_k": jnp.stack(cks), "cross_v": jnp.stack(cvs)}
    return state, x


def _pos_embed(positions, dim: int):
    """Sinusoidal embeddings for batched position arrays: (B,) or (B, C)
    -> positions.shape + (dim,).  Whisper has no rope — absolute positions
    enter the decoder only through these additive embeddings, which is what
    lets the paged path reuse the page-table machinery unchanged."""
    flat = L.sinusoidal_pos(positions.reshape(-1), dim)
    return flat.reshape(positions.shape + (dim,))


def encode_chunk(params, cfg, rules, frames, start, n_valid):
    """Encode ONE audio chunk — the streaming unit of chunked encode.

    frames: (1, Cf, d) right-padded frame embeddings covering absolute
    positions [start, start + Cf); ``n_valid`` masks the right-pad.
    Attention is confined to the chunk (block-diagonal streaming
    approximation — exact whenever the whole clip fits one chunk, which the
    SMOKE configs guarantee and the parity tests rely on).  Returns the
    encoder output for the chunk, (1, Cf, d), ready for
    :func:`cross_kv_chunk`.
    """
    Cf = frames.shape[1]
    pos = L.sinusoidal_pos(start + jnp.arange(Cf), cfg.d_model)
    x = frames + pos.astype(frames.dtype)

    def body(x, p):
        h = L.layernorm(p["ln1"], x)
        o, _, _ = _mha(p["attn"], h, h, cfg, causal=False,
                       kv_valid_len=n_valid)
        x = x + o
        h = L.layernorm(p["ln2"], x)
        return x + L.mlp_plain(p["mlp"], h), None

    x, _ = jax.lax.scan(T._remat(body, cfg), x, params["enc_blocks"])
    return L.layernorm(params["enc_norm"], x)


def cross_kv_chunk(params, cfg, enc_chunk):
    """Cross-attention K/V for one encoder-output chunk, all layers at once.

    Cross K/V is a per-position linear map of the encoder output (wk, wv
    only — whisper has no k bias), so chunk-wise computation is EXACT
    regardless of chunking.  enc_chunk: (1, Cf, d) -> k, v: (Ld, Cf, h, hd)
    — shaped for a page scatter with ``n_prefix=1``.
    """
    k, v = jax.vmap(lambda p: _cross_kv(p, enc_chunk))(
        params["dec_blocks"]["cross_attn"])
    return k[:, 0], v[:, 0]


def scatter_cross(storage, pages, k, v, *, page_size: int, quant=None):
    """Commit one chunk's cross K/V into its cross pages (write-once).

    storage: {"cross_k","cross_v"} of (Ld, N, page_size, h, hd) — plus
    per-row {"cross_k_scale","cross_v_scale"} leaves when ``quant`` is set;
    pages: (n,) int32;  k/v: (Ld, n * page_size, h, hd) right-padded.
    Quantize-on-write mirrors the self-attention pools, so int8 cross pages
    compose with the same scale-leaf machinery.
    """
    from repro.serve import pages as PG

    def sc(st, val):
        return PG.scatter_chunk(st, pages, val, page_size=page_size,
                                n_prefix=1)

    if quant is None:
        return dict(storage, cross_k=sc(storage["cross_k"], k),
                    cross_v=sc(storage["cross_v"], v))
    qk, sk = quant.quantize(k)
    qv, sv = quant.quantize(v)
    return dict(storage, cross_k=sc(storage["cross_k"], qk),
                cross_v=sc(storage["cross_v"], qv),
                cross_k_scale=sc(storage["cross_k_scale"], sk),
                cross_v_scale=sc(storage["cross_v_scale"], sv))


def _paged_dec_block(p, x, cfg, *, kv, tables, q_offset, write,
                     cross_kv, cross_tables, frames_len, use_pallas=False):
    """One whisper decoder block against paged storage.

    Self-attention mirrors :func:`repro.models.transformer._paged_block`
    (write fresh K/V through ``write``, attend through
    :func:`paged_window_attention`); between it and the MLP sits the
    cross-attention read: gather this layer's cross-KV pages (read-only —
    written once by the encode path), dequantize scale leaves when present,
    and run non-causal attention masked to each slot's ``frames_len``
    (0 frames -> a zero contribution, which is what keeps dead decode slots
    safe against the trash page).
    """
    from repro.optim.compress import int8_decompress
    from repro.serve import pages as PG
    dtype = x.dtype
    h = L.layernorm(p["ln1"], x)
    q, k, v = _project_qkv(p["self_attn"], h, h, dtype)
    kv = write(kv, k, v)
    o = A.paged_window_attention(q, kv["k"], kv["v"], tables, q_offset,
                                 k_scale=kv.get("k_scale"),
                                 v_scale=kv.get("v_scale"),
                                 use_pallas=use_pallas)
    x = x + _out(p["self_attn"], o)

    h = L.layernorm(p["ln_x"], x)
    cq = jnp.einsum("bsd,dhe->bshe", h,
                    p["cross_attn"]["wq"].astype(dtype)) \
        + p["cross_attn"]["bq"].astype(dtype)
    ck = PG.gather_pages(cross_kv["cross_k"], cross_tables)
    cv = PG.gather_pages(cross_kv["cross_v"], cross_tables)
    if "cross_k_scale" in cross_kv:
        ck = int8_decompress(ck, PG.gather_pages(cross_kv["cross_k_scale"],
                                                 cross_tables),
                             axis=-1, dtype=dtype)
        cv = int8_decompress(cv, PG.gather_pages(cross_kv["cross_v_scale"],
                                                 cross_tables),
                             axis=-1, dtype=dtype)
    o = A.gqa_attention(cq, ck, cv, causal=False, kv_valid_len=frames_len,
                        kv_chunk=max(ck.shape[1], 1), use_pallas=False)
    x = x + _out(p["cross_attn"], o)

    h = L.layernorm(p["ln2"], x)
    return x + L.mlp_plain(p["mlp"], h), kv


def _no_moe():
    return {"expert_tokens": jnp.zeros((0,), jnp.int32),
            "expert_dropped": jnp.zeros((0,), jnp.int32)}


def paged_prefill_chunk(params, cfg, rules, storage, table_row, pages_chunk,
                        start, tokens, cross_storage, cross_row, frames_len,
                        use_pallas=False, quant=None):
    """Prefill one decoder-prompt chunk against paged self + cross storage.

    Same contract as :func:`repro.models.transformer.paged_prefill_chunk`
    (tokens (1, C) right-padded, pages_chunk covering [start, start + C)),
    plus the read-only cross side: ``cross_storage`` {"cross_k","cross_v"}
    pages, ``cross_row`` (Pc,) the slot's cross page table, ``frames_len``
    scalar valid frames.  Positions are sinusoidal at absolute offsets (no
    rope), so chunked prefill matches the dense decoder bit-for-bit.
    Returns (self_storage, hidden (1, C, d), telemetry).
    """
    from repro.serve import pages as PG
    page_size = storage["k"].shape[2]
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    C = x.shape[1]
    positions = start + jnp.arange(C)
    x = x + L.sinusoidal_pos(positions, cfg.d_model).astype(x.dtype)
    tables = table_row[None]                                    # (1, P)
    cross_tables = cross_row[None]                              # (1, Pc)
    flen = jnp.asarray(frames_len)[None]                        # (1,)

    def write(kv, k, v):
        return T._write_kv(
            kv, k[0], v[0], quant,
            lambda st, val: PG.scatter_chunk(st, pages_chunk, val,
                                             page_size=page_size))

    def body(x, xs):
        p, kv, ckv = xs
        x, kv = _paged_dec_block(p, x, cfg, kv=kv, tables=tables,
                                 q_offset=start, write=write,
                                 cross_kv=ckv, cross_tables=cross_tables,
                                 frames_len=flen, use_pallas=use_pallas)
        return x, kv

    x, storage = jax.lax.scan(body, x, (params["dec_blocks"], storage,
                                        cross_storage))
    x = L.layernorm(params["dec_norm"], x)
    return storage, x, _no_moe()


def paged_decode_step(params, cfg, rules, storage, tables, lengths, tokens,
                      write_pages, write_offs, cross_storage, cross_tables,
                      frames_len, use_pallas=False, quant=None):
    """One decode token per slot with a cross-attention read.

    Self side matches :func:`repro.models.transformer.paged_decode_step`;
    ``cross_tables`` (B, Pc) and ``frames_len`` (B,) add the per-slot cross
    read (dead slots: trash page + 0 frames).  Returns (storage, logits
    (B, 1, V), telemetry).
    """
    from repro.serve import pages as PG
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = x + _pos_embed(lengths[:, None], cfg.d_model).astype(x.dtype)

    def write(kv, k, v):
        return T._write_kv(
            kv, k[:, 0], v[:, 0], quant,
            lambda st, val: PG.scatter_token(st, write_pages, write_offs,
                                             val))

    def body(x, xs):
        p, kv, ckv = xs
        x, kv = _paged_dec_block(p, x, cfg, kv=kv, tables=tables,
                                 q_offset=lengths, write=write,
                                 cross_kv=ckv, cross_tables=cross_tables,
                                 frames_len=frames_len,
                                 use_pallas=use_pallas)
        return x, kv

    x, storage = jax.lax.scan(body, x, (params["dec_blocks"], storage,
                                        cross_storage))
    x = L.layernorm(params["dec_norm"], x)
    logits = T.lm_logits(params, x, cfg, rules)
    return storage, logits, _no_moe()


def paged_verify_chunk(params, cfg, rules, storage, tables, lengths, tokens,
                       write_pages, write_offs, cross_storage, cross_tables,
                       frames_len, use_pallas=False, quant=None):
    """Score a (B, C) candidate window in one forward (speculative verify)
    — :func:`repro.models.transformer.paged_verify_chunk` plus the cross
    read.  C == 1 is exactly a decode step."""
    from repro.serve import pages as PG
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    C = x.shape[1]
    positions = lengths[:, None] + jnp.arange(C)                # (B, C)
    x = x + _pos_embed(positions, cfg.d_model).astype(x.dtype)

    def write(kv, k, v):
        return T._write_kv(
            kv, k, v, quant,
            lambda st, val: PG.scatter_window(st, write_pages, write_offs,
                                              val))

    def body(x, xs):
        p, kv, ckv = xs
        x, kv = _paged_dec_block(p, x, cfg, kv=kv, tables=tables,
                                 q_offset=lengths, write=write,
                                 cross_kv=ckv, cross_tables=cross_tables,
                                 frames_len=frames_len,
                                 use_pallas=use_pallas)
        return x, kv

    x, storage = jax.lax.scan(body, x, (params["dec_blocks"], storage,
                                        cross_storage))
    x = L.layernorm(params["dec_norm"], x)
    logits = T.lm_logits(params, x, cfg, rules)
    return storage, logits, _no_moe()


def decode_step(params, cfg, rules, state, tokens, pos):
    """One new token against the self cache + fixed cross K/V."""
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = x + L.sinusoidal_pos(pos + jnp.arange(1), cfg.d_model).astype(x.dtype)
    x = constrain(x, P("batch", None, None), rules)

    def body(x, xs):
        p, sk, sv, ck, cv = xs
        h = L.layernorm(p["ln1"], x)
        o, nk, nv = _mha(p["self_attn"], h, h, cfg, causal=True,
                         q_offset=pos, kv_valid_len=pos + 1,
                         cache_k=sk, cache_v=sv, cache_pos=pos)
        x = x + o
        h = L.layernorm(p["ln_x"], x)
        x = x + _cross(p["cross_attn"], h, ck, cv, cfg)
        h = L.layernorm(p["ln2"], x)
        return x + L.mlp_plain(p["mlp"], h), (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_blocks"], state["self_k"], state["self_v"],
                  state["cross_k"], state["cross_v"]))
    x = L.layernorm(params["dec_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["unembed"]["w"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, P("batch", None, "vocab"), rules)
    new_state = {"self_k": nk, "self_v": nv,
                 "cross_k": state["cross_k"], "cross_v": state["cross_v"]}
    return new_state, logits
