"""Grouped-query attention with KV-chunked online softmax (jnp "flash").

Memory never exceeds O(Sq x kv_chunk) per head group, which is what makes the
32k-prefill and 500k shapes lowerable; the Pallas kernel
(:mod:`repro.kernels.flash_attention`) implements the same blocking for real
TPUs, and this function is its oracle-equivalent fallback (``use_pallas``
selects the kernel on TPU runtimes).

Supports: GQA (grouped KV heads without materializing repeats), causal and
sliding-window masks (gemma3's 5:1 local:global via per-layer ``window``),
QK-norm (qwen3/gemma3), additive QKV bias (qwen2), decode against a
fixed-capacity KV cache with a validity length.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.module import Param
from repro.models import layers as L

NEG_INF = -1e30


def attention_def(cfg) -> dict:
    """Parameter tree for one attention block (padded head counts)."""
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.padded_q_heads, cfg.padded_kv_heads
    defs = {
        "wq": Param((d, hq, hd), P("embed_w", "q_heads", "head_dim")),
        "wk": Param((d, hkv, hd), P("embed_w", "kv_heads", "head_dim")),
        "wv": Param((d, hkv, hd), P("embed_w", "kv_heads", "head_dim")),
        "wo": Param((hq, hd, d), P("q_heads", "head_dim", "embed_w")),
    }
    if cfg.qkv_bias:
        defs["bq"] = Param((hq, hd), P("q_heads", "head_dim"), init="zeros")
        defs["bk"] = Param((hkv, hd), P("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = Param((hkv, hd), P("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = L.rmsnorm_def(hd)
        defs["k_norm"] = L.rmsnorm_def(hd)
    return defs


def qkv_project(params, x, cfg, positions, rules=None):
    """x: (B,S,d) -> q (B,S,Hq,D), k/v (B,S,Hkv,D), rotary applied.

    q/k/v are pinned seq-sharded right after the projection: without the pin,
    GSPMD may satisfy the downstream gathered-KV constraint by all-gathering
    ``x`` (d_model wide) instead of the 2·Hkv·hd-wide K/V — a 16-32x larger
    transfer under GQA (observed on qwen3-moe: 4 GB vs 268 MB per layer)."""
    from repro.mesh.axes import constrain as _c
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"].astype(x.dtype))
    q = _c(q, P("batch", "seq", None, None), rules)
    k = _c(k, P("batch", "seq", None, None), rules)
    v = _c(v, P("batch", "seq", None, None), rules)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q)
        k = L.rmsnorm(params["k_norm"], k)
    if cfg.rope_theta:
        q = L.rope(q, positions, theta=cfg.rope_theta)
        k = L.rope(k, positions, theta=cfg.rope_theta)
    return q, k, v


def out_project(params, o):
    return jnp.einsum("bshe,hed->bsd", o, params["wo"].astype(o.dtype))


def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int],
               kv_valid_len=None):
    """Additive mask in f32, broadcastable against scores (B,Hkv,G,Sq,Sk).

    ``q_pos``: (Sq,) or (B, Sq) — per-batch offsets enable ragged decode
    (continuous batching: every slot at a different position).
    ``kv_valid_len``: None, scalar, or (B,).
    Returns (Sq, Sk) or (B, 1, 1, Sq, Sk).
    """
    qp = q_pos[..., :, None]                       # (..., Sq, 1)
    kp = k_pos[None, :]                            # (1, Sk)
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= qp >= kp
    if window is not None:
        ok &= (qp - kp) < window
    ok &= kp >= 0                                  # ring caches: unfilled slots
    if kv_valid_len is not None:
        kv = jnp.asarray(kv_valid_len)
        kv = kv[..., None, None]                   # (..., 1, 1)
        ok &= kp < kv
    mask = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    if mask.ndim == 3:                             # (B, Sq, Sk) -> broadcast
        mask = mask[:, None, None]
    return mask


def paged_window_attention(q, k_pages, v_pages, tables, n_cached, *,
                           k_scale=None, v_scale=None,
                           use_pallas: bool = False):
    """Attention for a window of queries against paged KV storage — the ONE
    model-side paged-attention path (decode W=1, speculative verify, and
    page-aligned chunked prefill all route here).

    q: (B, W, Hq, D); k_pages/v_pages: (N, page_size, Hkv, D);
    tables: (B, P) int32 page ids; ``n_cached``: scalar or (B,) int32 tokens
    cached BEFORE the window (= window position 0's absolute position).
    Window position w attends to cached positions plus window positions
    <= w; every window token's K/V must be written to its page before the
    call.  Returns (B, W, Hq, D).

    ``use_pallas`` routes through the fused multi-query Pallas kernel
    (:mod:`repro.kernels.paged_attention`), which gathers pages on-chip via
    scalar-prefetched index maps and applies the per-row causal offset in
    VMEM; the fallback materializes the gather with jnp advanced indexing
    and reuses :func:`gqa_attention`'s masked path — identical math, the
    kernel-parity oracle on the model side.

    Head counts are whatever the caller holds: under tensor-parallel serving
    this runs inside a ``shard_map`` body where Hq/Hkv are the LOCAL shard
    (Hq_global/tp, Hkv_global/tp) and the pages carry only local KV heads —
    attention is embarrassingly parallel across the head axis, so no
    collective appears here.

    ``k_scale``/``v_scale``: optional (N, page_size, Hkv) per-(row, head)
    dequantization scales for int8 pages.  The kernel path fuses the
    multiply into the VMEM page tile (the page stream stays int8 in HBM);
    this fallback dequantizes right after the gather — same math, the
    quantized kernel's parity oracle.
    """
    Hq, Hkv = q.shape[2], k_pages.shape[2]
    if Hkv == 0 or Hq % Hkv:
        raise ValueError(
            f"Hq={Hq} must be a positive multiple of Hkv={Hkv}; under "
            "serving TP both must divide by tp so each shard keeps whole "
            "GQA groups")
    W = q.shape[1]
    if use_pallas:
        from repro.kernels import ops as kops
        lengths = jnp.broadcast_to(
            jnp.asarray(n_cached, jnp.int32) + 1, (q.shape[0],))
        return kops.paged_attention_mq(q, k_pages, v_pages, tables, lengths,
                                       k_scale, v_scale)
    from repro.optim.compress import int8_decompress
    from repro.serve import pages as PG
    k = PG.gather_pages(k_pages, tables)            # (B, P*page_size, Hkv, D)
    v = PG.gather_pages(v_pages, tables)
    if k_scale is not None:
        k = int8_decompress(k, PG.gather_pages(k_scale, tables),
                            axis=-1, dtype=q.dtype)
        v = int8_decompress(v, PG.gather_pages(v_scale, tables),
                            axis=-1, dtype=q.dtype)
    return gqa_attention(q, k, v, causal=True, q_offset=n_cached,
                         kv_valid_len=n_cached + W,
                         kv_chunk=max(k.shape[1], 1))


def paged_decode_attention(q, k_pages, v_pages, tables, lengths, *,
                           k_scale=None, v_scale=None,
                           use_pallas: bool = False):
    """Decode attention against paged KV storage (one query per sequence):
    the W=1 window of :func:`paged_window_attention`.

    q: (B, 1, Hq, D); ``lengths``: (B,) int32 valid-KV counts *including*
    the current token (already written to its page).
    """
    return paged_window_attention(q, k_pages, v_pages, tables, lengths - 1,
                                  k_scale=k_scale, v_scale=v_scale,
                                  use_pallas=use_pallas)


def gqa_attention(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  q_offset=0,
                  kv_valid_len=None,
                  k_start=None,
                  kv_chunk: int = 1024,
                  use_pallas: bool = False):
    """Online-softmax GQA.

    q: (B, Sq, Hq, D);  k, v: (B, Sk, Hkv, D), Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (decode: current length).
    ``kv_valid_len``: live prefix of the KV buffers (decode caches).
    ``k_start``: absolute position of k[0] (sliding-window ring caches hold
    the LAST Sk positions; entries with negative positions are masked).
    Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5

    if use_pallas and Sq == Sk and kv_valid_len is None:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)

    qg = q.reshape(B, Sq, Hkv, G, D) * scale
    q_pos = jnp.asarray(q_offset)[..., None] + jnp.arange(Sq)

    def block(acc_m_l, kc, vc, k_pos):
        acc, m, l = acc_m_l
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc,
                       preferred_element_type=jnp.float32)
        s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window,
                           kv_valid_len=kv_valid_len)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(kc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l)

    acc0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)

    k0 = 0 if k_start is None else k_start
    if Sk <= kv_chunk or Sk % kv_chunk != 0:
        acc, m, l = block((acc0, m0, l0), k, v, k0 + jnp.arange(Sk))
    elif k_start is not None:
        raise NotImplementedError("k_start with chunked KV not needed: "
                                  "window caches fit one chunk")
    else:
        n_chunks = Sk // kv_chunk
        ks = k.reshape(B, n_chunks, kv_chunk, Hkv, D).swapaxes(0, 1)
        vs = v.reshape(B, n_chunks, kv_chunk, Hkv, D).swapaxes(0, 1)
        offs = jnp.arange(n_chunks) * kv_chunk

        def body(carry, xs):
            kc, vc, off = xs
            k_pos = off + jnp.arange(kv_chunk)
            return block(carry, kc, vc, k_pos), None

        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (ks, vs, offs))

    out = acc / jnp.maximum(l[..., None], 1e-30)          # (B,Hkv,G,Sq,D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)
