"""Minimal functional param-definition system.

Models declare parameters as trees of :class:`Param` (shape + logical
PartitionSpec + initializer).  From one declaration we derive:

* concrete initialization (``init_params``) — jitted, with on-device sharding;
* abstract ``ShapeDtypeStruct`` trees with shardings for the dry-run
  (``abstract_params``) — no allocation ever happens for the 480B configs;
* the sharding tree (``sharding_tree``) used as ``in_shardings`` for
  ``train_step``/``serve_step``.

This mirrors the paper's philosophy: the *declaration* is user code, the
*distribution* (partitioning, placement) is generic machinery.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.mesh.axes import AxisRules, logical_to_sharding


@dataclasses.dataclass(frozen=True)
class Param:
    """Declaration of one parameter tensor."""

    shape: tuple
    spec: P                       # logical axes, same length as shape
    init: str = "normal"          # normal | zeros | ones | embed | small
    scale: float | None = None    # stddev override
    dtype: Any = None             # override model dtype

    def stddev(self) -> float:
        if self.scale is not None:
            return self.scale
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        return 1.0 / math.sqrt(max(fan_in, 1))


def is_param(x) -> bool:
    return isinstance(x, Param)


def _tree_map(fn, defs):
    return jax.tree_util.tree_map(fn, defs, is_leaf=is_param)


def init_params(defs, key, dtype=jnp.float32):
    """Materialize parameters (host/device per surrounding jit)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_param)
    keys = jax.random.split(key, len(leaves))

    def make(p: Param, k):
        dt = p.dtype or dtype
        if p.init == "zeros":
            return jnp.zeros(p.shape, dt)
        if p.init == "ones":
            return jnp.ones(p.shape, dt)
        std = p.stddev() if p.init != "embed" else 1.0
        if p.init == "small":
            std = 0.02
        return (jax.random.normal(k, p.shape, jnp.float32) * std).astype(dt)

    return jax.tree_util.tree_unflatten(
        treedef, [make(p, k) for p, k in zip(leaves, keys)])


def abstract_params(defs, mesh: Mesh, rules: AxisRules, dtype=jnp.float32):
    """ShapeDtypeStruct tree with shardings — dry-run stand-in, no allocation."""
    def make(p: Param):
        dt = p.dtype or dtype
        return jax.ShapeDtypeStruct(
            p.shape, dt, sharding=logical_to_sharding(p.spec, mesh, rules))

    return _tree_map(make, defs)


def sharding_tree(defs, mesh: Mesh, rules: AxisRules):
    return _tree_map(lambda p: logical_to_sharding(p.spec, mesh, rules), defs)


def spec_tree(defs):
    return _tree_map(lambda p: p.spec, defs)


def param_count(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_param)
    return int(sum(int(np.prod(p.shape)) for p in leaves))


def param_bytes(defs, dtype=jnp.float32) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_param)
    return int(sum(int(np.prod(p.shape)) * jnp.dtype(p.dtype or dtype).itemsize
                   for p in leaves))
