"""Unified model API — the function-centric face of every architecture.

``build_model(cfg)`` returns a :class:`Model` whose members are plain
functions (loss / prefill / decode_step), so the generic machinery
(:mod:`repro.train`, :mod:`repro.serve`, :mod:`repro.launch.dryrun`) composes
them exactly the way the paper's ``solve_problem`` composes ``initialize`` /
``func`` / ``finalize``: the framework never looks inside the model, it only
calls the supplied functions.

Batch conventions per family (assignment brief: modality frontends are stubs,
``input_specs`` provides precomputed embeddings):

  dense/moe:  {tokens (B,S) i32, labels (B,S) i32}
  vlm:        {tokens (B,S-I) i32, image_embeds (B,I,d) act-dtype, labels (B,S)}
  hybrid/ssm: {tokens (B,S) i32, labels (B,S) i32}
  audio:      {frames (B,F,d) act-dtype, tokens (B,S) i32, labels (B,S) i32}
"""

from __future__ import annotations

__all__ = ["ArraySpec", "DecoderLM", "HybridLM",
           "Model", "RwkvLM", "VLM",
           "Whisper", "build_model"]

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.mesh.axes import AxisRules, logical_to_sharding
from repro.models import transformer as T
from repro.models import rwkv_lm as RW
from repro.models import whisper as W
from repro.models import zamba as Z
from repro.models.module import Param, abstract_params, init_params, param_count


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Shape/dtype/logical-partition declaration of one input array."""
    shape: tuple
    dtype: Any
    spec: P

    def abstract(self, mesh=None, rules: AxisRules | None = None):
        """The matching ShapeDtypeStruct (sharded when ``mesh`` given)."""
        if mesh is None:
            return jax.ShapeDtypeStruct(self.shape, self.dtype)
        return jax.ShapeDtypeStruct(
            self.shape, self.dtype,
            sharding=logical_to_sharding(self.spec, mesh, rules))


# -- serving-mesh sharding rules (1-D ("model",) tensor-parallel mesh) -------
#
# The serving engine's device mesh has a single "model" axis.  Families with
# a per-token KV cache run Megatron-style TP: attention heads, MLP ff, the
# vocab and the experts shard over "model"; everything else (norm scales,
# router, the embedding table — its lookup needs every row) is replicated.
# Families without paged KV (recurrent / window caches) run slot-parallel
# instead: params replicated, decode-state batch axis sharded over "model".
SERVE_TP_AXES: dict = {
    "q_heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
}


def _map_param_spec(spec: P, table) -> P:
    return P(*(table.get(ax) for ax in spec))


def _is_param(x) -> bool:
    return isinstance(x, Param)


def _tokens(B, S):
    return ArraySpec((B, S), jnp.int32, P("batch", "seq"))


def _labels(B, S):
    return ArraySpec((B, S), jnp.int32, P("batch", "seq"))


class Model:
    """One architecture, bound to its family's functional implementation."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters ----------------------------------------------------------
    def param_defs(self) -> dict:
        """Pytree of (shape, logical partition) pairs for every weight."""
        raise NotImplementedError

    def init(self, key, dtype=jnp.float32):
        """Random weights matching :meth:`param_defs` (host-local)."""
        return init_params(self.param_defs(), key, dtype=dtype)

    def abstract_params(self, mesh, rules, dtype=jnp.float32):
        """ShapeDtypeStructs with shardings — for eval_shape / checkpoint
        restore without materialising weights."""
        return abstract_params(self.param_defs(), mesh, rules, dtype=dtype)

    def n_params(self) -> int:
        """Total scalar parameter count."""
        return param_count(self.param_defs())

    def n_active_params(self) -> int:
        """Params touched per token (= n_params except for MoE)."""
        return self.n_params()

    # -- training ------------------------------------------------------------
    def loss(self, params, batch: dict, rules) -> tuple[jax.Array, dict]:
        """Mean next-token loss on ``batch`` -> (scalar, metrics dict)."""
        raise NotImplementedError

    def train_batch_specs(self, shape: ShapeConfig) -> dict[str, ArraySpec]:
        """:class:`ArraySpec` per training-batch key (tokens, labels, …)."""
        raise NotImplementedError

    # -- serving -------------------------------------------------------------
    def prefill_batch_specs(self, shape: ShapeConfig) -> dict[str, ArraySpec]:
        """The training specs minus ``labels`` — what prefill consumes."""
        specs = dict(self.train_batch_specs(shape))
        specs.pop("labels")
        return specs

    def prefill(self, params, batch: dict, rules, max_len: int):
        """-> (decode_state, last_hidden)."""
        raise NotImplementedError

    def init_decode_state(self, batch: int, max_len: int):
        """Fresh (empty) per-slot decode state for a dense batch."""
        raise NotImplementedError

    def decode_state_specs(self, batch: int, max_len: int) -> Any:
        """Pytree of ArraySpec matching init_decode_state."""
        raise NotImplementedError

    def decode_step(self, params, state, tokens, pos, rules):
        """tokens (B,1) -> (new_state, logits (B,1,V))."""
        raise NotImplementedError

    # -- paged serving (stacked-cache families only) -------------------------
    # Recurrent-state families (rwkv6, mamba2/zamba) keep their O(1)
    # per-slot state path: there is no per-token KV to page.

    def supports_paged_decode(self) -> bool:
        """Whether the family has a per-token KV cache that can page."""
        return False

    def paged_leaf_specs(self, quant=None):
        """Pytree of :class:`repro.serve.pages.PagedLeafSpec` describing the
        per-token KV leaves around the pool's (num_pages, page_size) axes.
        With a ``quant`` policy the value leaves use its storage dtype and
        per-row scale leaves ride along (see :mod:`repro.serve.quant`)."""
        raise NotImplementedError(f"{self.cfg.family} has no paged KV cache")

    def paged_state_specs(self, num_pages: int, page_size: int, quant=None):
        """Pytree of ArraySpec matching the PagePool storage (incl. the
        trash page at index ``num_pages``).  Derived from
        :meth:`paged_leaf_specs` so the pool layout has one source of
        truth; unsupported families raise through it."""
        from repro.serve import pages as PG

        def leaf(s):
            shape = s.storage_shape(num_pages + PG.N_TRASH, page_size)
            return ArraySpec(shape, s.dtype, P(*([None] * len(shape))))

        return jax.tree_util.tree_map(
            leaf, self.paged_leaf_specs(quant),
            is_leaf=lambda x: isinstance(x, PG.PagedLeafSpec))

    def paged_prefill_chunk(self, params, storage, table_row, pages_chunk,
                            start, tokens, rules, *,
                            use_pallas: bool = False, comm=None, quant=None,
                            ep_comm=None, placement=None, embeds=None,
                            cross=None):
        """Prefill tokens (1, C) at positions [start, start+C) into pages.

        ``embeds``: optional (1, C, d) precomputed embeddings spliced in at
        negative-token positions (the VLM image-prefix path); ``cross``:
        optional ``{"storage", "tables", "frames_len"}`` read-only
        cross-attention pages (the enc-dec path).  Both default to None and
        change NOTHING for text-only families."""
        raise NotImplementedError(f"{self.cfg.family} has no paged KV cache")

    def paged_decode_step(self, params, storage, tables, lengths, tokens,
                          write_pages, write_offs, rules, *,
                          use_pallas: bool = False, comm=None, quant=None,
                          ep_comm=None, placement=None, cross=None):
        """tokens (B,1) -> (new_storage, logits (B,1,V), moe telemetry)."""
        raise NotImplementedError(f"{self.cfg.family} has no paged KV cache")

    def paged_verify(self, params, storage, tables, lengths, tokens,
                     write_pages, write_offs, rules, *,
                     use_pallas: bool = False, comm=None, quant=None,
                     ep_comm=None, placement=None, cross=None):
        """Speculative-decode verify: score a (B, C) window of candidate
        tokens per slot in one batched forward (position 0 = the next
        input, 1..C-1 = drafts).  ``write_pages``/``write_offs`` are
        (B, C) per-position K/V targets (pads -> trash page).  Returns
        (new_storage, logits (B, C, V)).  Families without a paged KV
        cache fall back to per-token decode (the engine never calls this
        for them)."""
        raise NotImplementedError(f"{self.cfg.family} has no paged KV cache")

    # -- serving-mesh sharding rules -----------------------------------------

    def serve_param_specs(self, ep: int = 1):
        """Pytree of mesh ``PartitionSpec`` for the params during
        tensor-parallel PAGED serving — part of the paged protocol, like
        :meth:`paged_leaf_specs`.  ``ep > 1`` targets a 2-D ("expert",
        "model") mesh: expert-stacked weights shard over BOTH axes (expert
        major).  Families without a paged KV cache never need this: the
        engine's slot-parallel fallback replicates params directly from the
        array tree."""
        raise NotImplementedError(
            f"{self.cfg.family} has no TP serving specs (engine "
            "slot-parallel mode replicates params instead)")

    def serve_state_specs(self, batch: int, max_len: int):
        """Mesh specs for the dense decode state under slot-parallel mesh
        serving: every leaf's logical "batch" axis shards over "model",
        everything else is replicated — each device decodes its own slots
        with the unchanged serial step function."""
        def leaf(a: ArraySpec) -> P:
            return P(*("model" if ax == "batch" else None for ax in a.spec))
        return jax.tree_util.tree_map(
            leaf, self.decode_state_specs(batch, max_len),
            is_leaf=lambda x: isinstance(x, ArraySpec))

    def paged_storage_specs(self, quant=None):
        """Mesh specs for the PagePool storage under TP serving: the leading
        suffix axis of every :meth:`paged_leaf_specs` leaf (the KV-head axis
        by convention — scale leaves included, their suffix is exactly
        (Hkv,)) shards over "model"."""
        from repro.serve import pages as PG

        def leaf(s: PG.PagedLeafSpec) -> P:
            n_pre = len(s.prefix)
            return P(*([None] * (n_pre + 2) + ["model"]
                       + [None] * (len(s.suffix) - 1)))
        return jax.tree_util.tree_map(
            leaf, self.paged_leaf_specs(quant),
            is_leaf=lambda x: isinstance(x, PG.PagedLeafSpec))

    def validate_serve_mesh(self, tp: int = 1, ep: int = 1) -> None:
        """Raise with EVERY indivisible dimension named for a (tp, ep)
        serving mesh.  ``tp`` shards heads / ff / vocab (and, combined with
        ``ep``, the expert stack); ``ep`` partitions whole experts, so a
        dense family with ep > 1 is refused outright."""
        cfg = self.cfg
        if ep > 1 and not cfg.n_experts:
            raise ValueError(
                f"{cfg.name} ({cfg.family}) is a dense family with no "
                f"experts: expert-parallel ep={ep} cannot apply — drop the "
                "expert axis (--mesh tp=N)")
        if tp <= 1 and ep <= 1:
            return
        bad = []
        if self.supports_paged_decode():
            if tp > 1:
                dims = {"padded_q_heads": cfg.padded_q_heads,
                        "padded_kv_heads": cfg.padded_kv_heads,
                        "padded_vocab": cfg.padded_vocab}
                if not cfg.n_experts or cfg.dense_residual:
                    dims["d_ff"] = cfg.d_ff
                bad += [f"{k}={v} (tp={tp})" for k, v in dims.items()
                        if v % tp]
            if cfg.n_experts and cfg.n_experts % (ep * tp):
                # experts shard over BOTH axes (tp slices expert rows even
                # on a 1-D mesh), so the product must divide the stack
                shards = (f"ep*tp={ep * tp}" if ep > 1 else f"tp={tp}")
                bad.append(f"n_experts={cfg.n_experts} ({shards})")
        elif ep > 1:
            bad.append(f"family={cfg.family} has no paged expert path "
                       f"(ep={ep})")
        if bad:
            raise ValueError(
                f"{cfg.name}: serving mesh (tp={tp}, ep={ep}) does not "
                "divide " + ", ".join(bad))

    def validate_serve_tp(self, tp: int) -> None:
        """Back-compat alias for :meth:`validate_serve_mesh` (1-D mesh)."""
        self.validate_serve_mesh(tp=tp)

    def validate_serve_encoder(self, *, page_size: int, max_len: int,
                               prefix_cache: bool = False) -> None:
        """Raise (with the fix spelled out) when the family's encoder
        geometry cannot serve under the given paged layout — the
        construction-time twin of :meth:`validate_serve_mesh` for the
        encoder-attached families (VLM image prefixes, whisper audio
        frames).  Text-only families have no encoder: no-op."""

    def lm_head(self, params, hidden, rules):
        """Project final hidden states to vocab logits."""
        return T.lm_logits(params, hidden, self.cfg, rules)


# ---------------------------------------------------------------------------
# Dense / MoE decoder-only LMs (also base for VLM)
# ---------------------------------------------------------------------------

class DecoderLM(Model):
    """Decoder-only transformer LM (dense or MoE): the full paged-serving
    protocol — prefill chunks, single-token decode, spec-decode verify."""

    def param_defs(self):
        return T.transformer_defs(self.cfg)

    def n_active_params(self):
        cfg = self.cfg
        if not cfg.n_experts:
            return self.n_params()
        expert_p = 3 * cfg.d_model * cfg.expert_d_ff
        inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert_p
        return self.n_params() - inactive

    def loss(self, params, batch, rules):
        return T.lm_loss(params, self.cfg, rules, tokens=batch["tokens"],
                         labels=batch["labels"])

    def train_batch_specs(self, shape):
        B, S = shape.global_batch, shape.seq_len
        return {"tokens": _tokens(B, S), "labels": _labels(B, S)}

    def prefill(self, params, batch, rules, max_len):
        return T.prefill(params, self.cfg, rules, tokens=batch["tokens"],
                         max_len=max_len)

    def init_decode_state(self, batch, max_len):
        return T.init_cache(self.cfg, batch, max_len,
                            dtype=jnp.dtype(self.cfg.dtype))

    def decode_state_specs(self, batch, max_len):
        cfg = self.cfg
        spec = P(None, "batch", "kv_seq", None, None)
        hkv, hd = cfg.padded_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.dtype)
        if not T.uses_window_cache(cfg):
            a = ArraySpec((cfg.n_layers, batch, max_len, hkv, hd), dt, spec)
            return {"k": a, "v": a}
        glob, loc = T.layer_groups(cfg)
        W = min(cfg.local_window, max_len)
        g = ArraySpec((len(glob), batch, max_len, hkv, hd), dt, spec)
        l = ArraySpec((len(loc), batch, W, hkv, hd), dt, spec)
        return {"k": g, "v": g, "k_loc": l, "v_loc": l}

    def decode_step(self, params, state, tokens, pos, rules):
        return T.decode_step(params, self.cfg, rules, state, tokens, pos)

    # -- paged serving -------------------------------------------------------
    # Shared by dense, MoE and (token-prompt) VLM: the stacked (L, ·, ·,
    # Hkv, D) cache pages identically; only gemma3-style mixed window/ring
    # caches stay on the dense path.

    def supports_paged_decode(self) -> bool:
        return not T.uses_window_cache(self.cfg)

    def paged_leaf_specs(self, quant=None):
        from repro.serve.pages import PagedLeafSpec
        from repro.serve.quant import quantize_leaf_specs
        cfg = self.cfg
        leaf = PagedLeafSpec((cfg.n_layers,),
                             (cfg.padded_kv_heads, cfg.head_dim),
                             jnp.dtype(cfg.dtype))
        return quantize_leaf_specs({"k": leaf, "v": leaf}, quant)

    def paged_prefill_chunk(self, params, storage, table_row, pages_chunk,
                            start, tokens, rules, *,
                            use_pallas: bool = False, comm=None, quant=None,
                            ep_comm=None, placement=None, embeds=None,
                            cross=None):
        assert cross is None, "decoder-only families have no cross-KV pages"
        return T.paged_prefill_chunk(params, self.cfg, rules, storage,
                                     table_row, pages_chunk, start, tokens,
                                     use_pallas=use_pallas, comm=comm,
                                     quant=quant, ep_comm=ep_comm,
                                     placement=placement, embeds=embeds)

    def paged_decode_step(self, params, storage, tables, lengths, tokens,
                          write_pages, write_offs, rules, *,
                          use_pallas: bool = False, comm=None, quant=None,
                          ep_comm=None, placement=None, cross=None):
        assert cross is None, "decoder-only families have no cross-KV pages"
        return T.paged_decode_step(params, self.cfg, rules, storage, tables,
                                   lengths, tokens, write_pages, write_offs,
                                   use_pallas=use_pallas, comm=comm,
                                   quant=quant, ep_comm=ep_comm,
                                   placement=placement)

    def paged_verify(self, params, storage, tables, lengths, tokens,
                     write_pages, write_offs, rules, *,
                     use_pallas: bool = False, comm=None, quant=None,
                     ep_comm=None, placement=None, cross=None):
        assert cross is None, "decoder-only families have no cross-KV pages"
        return T.paged_verify_chunk(params, self.cfg, rules, storage, tables,
                                    lengths, tokens, write_pages, write_offs,
                                    use_pallas=use_pallas, comm=comm,
                                    quant=quant, ep_comm=ep_comm,
                                    placement=placement)

    def serve_param_specs(self, ep: int = 1):
        """Megatron TP over the serving mesh: attention heads, MLP ff,
        experts and the unembed vocab shard over "model"; norms, router and
        the embedding table (gathered row lookup) stay replicated.  With
        ``ep > 1`` the mesh is 2-D ("expert", "model") and the expert stack
        shards over both axes, expert-major — each rank holds
        E/(ep*tp) whole experts' weight rows."""
        table = dict(SERVE_TP_AXES)
        if ep > 1:
            table["experts"] = ("expert", "model")
        specs = jax.tree_util.tree_map(
            lambda p: _map_param_spec(p.spec, table),
            self.param_defs(), is_leaf=_is_param)
        specs["embed"]["table"] = P(None, None)
        return specs


class VLM(DecoderLM):
    """LLaVA-style: precomputed anyres patch embeddings prepended to text."""

    def _embeds(self, params, batch, rules):
        txt = T.embed_tokens(params, batch["tokens"], self.cfg, rules)
        img = batch["image_embeds"].astype(txt.dtype)
        return jnp.concatenate([img, txt], axis=1)

    def loss(self, params, batch, rules):
        x = self._embeds(params, batch, rules)
        return T.lm_loss(params, self.cfg, rules, inputs_embeds=x,
                         labels=batch["labels"])

    def train_batch_specs(self, shape):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        I = cfg.n_image_tokens
        assert S > I, (S, I)
        return {
            "tokens": _tokens(B, S - I),
            "image_embeds": ArraySpec((B, I, cfg.d_model),
                                      jnp.dtype(cfg.dtype),
                                      P("batch", "seq", None)),
            "labels": _labels(B, S),
        }

    def prefill(self, params, batch, rules, max_len):
        x = self._embeds(params, batch, rules)
        return T.prefill(params, self.cfg, rules, inputs_embeds=x,
                         max_len=max_len)

    def validate_serve_encoder(self, *, page_size: int, max_len: int,
                               prefix_cache: bool = False) -> None:
        """The image prefix occupies ``n_image_tokens`` leading positions of
        every image request, so it must (a) leave room for text + at least
        one generated token inside ``max_len`` and (b) — when the prefix
        cache shares image pages between requests — tile exactly into
        pages, or the boundary page would mix image and per-request text
        content and never be sharable."""
        cfg = self.cfg
        I = cfg.n_image_tokens
        if I + 1 >= max_len:
            raise ValueError(
                f"{cfg.name}: n_image_tokens={I} leaves no room inside "
                f"max_len={max_len} for a text prompt plus one generated "
                f"token; raise max_len to at least {I + 2} (--max-len)")
        if prefix_cache and I % page_size:
            fix = max(d for d in range(1, page_size + 1) if I % d == 0)
            raise ValueError(
                f"{cfg.name}: n_image_tokens={I} is not a multiple of "
                f"page_size={page_size}, so image-prefix pages can never be "
                "shared through the prefix cache (the boundary page would "
                "mix image and text content).  Fix: pass a page size that "
                f"divides {I} — e.g. --page-size {fix} — or disable "
                "--prefix-cache")


# ---------------------------------------------------------------------------
# Hybrid (zamba2), SSM (rwkv6), audio (whisper)
# ---------------------------------------------------------------------------

class HybridLM(Model):
    """Mamba/attention hybrid (zamba-style): recurrent per-slot state, so
    it serves on the dense path only (no per-token KV to page)."""

    def param_defs(self):
        return Z.zamba_defs(self.cfg)

    def loss(self, params, batch, rules):
        return Z.lm_loss(params, self.cfg, rules, batch["tokens"],
                         batch["labels"])

    def train_batch_specs(self, shape):
        B, S = shape.global_batch, shape.seq_len
        return {"tokens": _tokens(B, S), "labels": _labels(B, S)}

    def prefill(self, params, batch, rules, max_len):
        return Z.prefill(params, self.cfg, rules, batch["tokens"], max_len)

    def init_decode_state(self, batch, max_len):
        return Z.init_state(self.cfg, batch, max_len,
                            dtype=jnp.dtype(self.cfg.dtype))

    def decode_state_specs(self, batch, max_len):
        cfg = self.cfg
        specs = Z.state_specs(cfg)
        seg = cfg.n_layers // cfg.shared_attn_every
        k = cfg.shared_attn_every
        H, Pd, N, K = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                       cfg.conv_kernel)
        hkv, hd = cfg.padded_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.dtype)
        return {
            "mamba": {
                "ssm": ArraySpec((seg, k, batch, H, N, Pd), jnp.float32,
                                 specs["mamba"]["ssm"]),
                "conv": {
                    "x": ArraySpec((seg, k, batch, K - 1, cfg.d_inner),
                                   jnp.float32, specs["mamba"]["conv"]["x"]),
                    "B": ArraySpec((seg, k, batch, K - 1, N), jnp.float32,
                                   specs["mamba"]["conv"]["B"]),
                    "C": ArraySpec((seg, k, batch, K - 1, N), jnp.float32,
                                   specs["mamba"]["conv"]["C"]),
                },
            },
            "attn_cache": {
                "k": ArraySpec((seg, batch, max_len, hkv, hd), dt,
                               specs["attn_cache"]["k"]),
                "v": ArraySpec((seg, batch, max_len, hkv, hd), dt,
                               specs["attn_cache"]["v"]),
            },
        }

    def decode_step(self, params, state, tokens, pos, rules):
        return Z.decode_step(params, self.cfg, rules, state, tokens, pos)


class RwkvLM(Model):
    """RWKV-style linear-attention LM: O(1) recurrent decode state, dense
    serving path only."""

    def param_defs(self):
        return RW.rwkv_lm_defs(self.cfg)

    def loss(self, params, batch, rules):
        return RW.lm_loss(params, self.cfg, rules, batch["tokens"],
                          batch["labels"])

    def train_batch_specs(self, shape):
        B, S = shape.global_batch, shape.seq_len
        return {"tokens": _tokens(B, S), "labels": _labels(B, S)}

    def prefill(self, params, batch, rules, max_len):
        return RW.prefill(params, self.cfg, rules, batch["tokens"])

    def init_decode_state(self, batch, max_len):
        return RW.init_state(self.cfg, batch)

    def decode_state_specs(self, batch, max_len):
        cfg = self.cfg
        Lh, H, hd, d = cfg.n_layers, cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.d_model
        sp = RW.state_specs(cfg)
        return {
            "wkv": ArraySpec((Lh, batch, H, hd, hd), jnp.float32, sp["wkv"]),
            "tm_prev": ArraySpec((Lh, batch, 1, d), jnp.float32, sp["tm_prev"]),
            "cm_prev": ArraySpec((Lh, batch, 1, d), jnp.float32, sp["cm_prev"]),
        }

    def decode_step(self, params, state, tokens, pos, rules):
        return RW.decode_step(params, self.cfg, rules, state, tokens, pos)


class Whisper(Model):
    """Encoder-decoder audio model: bidirectional frame encoder + causal
    token decoder with cross-attention.  Serves paged-only — the decoder's
    self-KV pages normally while cross-K/V (computed once per clip via
    :meth:`encode_chunk` / :meth:`cross_kv_chunk`) lives in a read-only
    :class:`repro.serve.pages.CrossKVPool`."""

    def param_defs(self):
        return W.whisper_defs(self.cfg)

    def loss(self, params, batch, rules):
        return W.loss(params, self.cfg, rules, batch["frames"],
                      batch["tokens"], batch["labels"])

    def train_batch_specs(self, shape):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        return {
            "frames": ArraySpec((B, cfg.n_audio_frames, cfg.d_model),
                                jnp.dtype(cfg.dtype),
                                P("batch", "frames", None)),
            "tokens": _tokens(B, S),
            "labels": _labels(B, S),
        }

    def prefill(self, params, batch, rules, max_len):
        return W.prefill(params, self.cfg, rules, batch["frames"],
                         batch["tokens"], max_len)

    def init_decode_state(self, batch, max_len):
        return W.init_state(self.cfg, batch, max_len,
                            dtype=jnp.dtype(self.cfg.dtype))

    def decode_state_specs(self, batch, max_len):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        h, hd, Ld, F = cfg.n_heads, cfg.head_dim, cfg.decoder_layers, cfg.n_audio_frames
        sp = W.state_specs(cfg)
        return {
            "self_k": ArraySpec((Ld, batch, max_len, h, hd), dt, sp["self_k"]),
            "self_v": ArraySpec((Ld, batch, max_len, h, hd), dt, sp["self_v"]),
            "cross_k": ArraySpec((Ld, batch, F, h, hd), dt, sp["cross_k"]),
            "cross_v": ArraySpec((Ld, batch, F, h, hd), dt, sp["cross_v"]),
        }

    def decode_step(self, params, state, tokens, pos, rules):
        return W.decode_step(params, self.cfg, rules, state, tokens, pos)

    def lm_head(self, params, hidden, rules):
        return T.lm_logits(params, hidden, self.cfg, rules)

    # -- paged serving (enc-dec: self-KV pages + read-only cross-KV pages) ---
    # The decoder's self-attention cache pages exactly like a decoder-only
    # LM's; the cross-attention K/V (one linear map of the encoder output
    # per layer, computed once) lives in a separate read-only
    # :class:`repro.serve.pages.CrossKVPool` and every paged call takes a
    # ``cross={"storage", "tables", "frames_len"}`` bundle.

    def supports_paged_decode(self) -> bool:
        return True

    def paged_leaf_specs(self, quant=None):
        from repro.serve.pages import PagedLeafSpec
        from repro.serve.quant import quantize_leaf_specs
        cfg = self.cfg
        leaf = PagedLeafSpec((cfg.decoder_layers,),
                             (cfg.n_heads, cfg.head_dim),
                             jnp.dtype(cfg.dtype))
        return quantize_leaf_specs({"k": leaf, "v": leaf}, quant)

    def cross_leaf_specs(self, quant=None):
        """Leaf specs for the cross-KV pool (pages over audio-frame rows
        instead of token rows; otherwise identical machinery — int8 scale
        leaves ride along the same way)."""
        from repro.serve.pages import PagedLeafSpec
        from repro.serve.quant import quantize_leaf_specs
        cfg = self.cfg
        leaf = PagedLeafSpec((cfg.decoder_layers,),
                             (cfg.n_heads, cfg.head_dim),
                             jnp.dtype(cfg.dtype))
        return quantize_leaf_specs({"cross_k": leaf, "cross_v": leaf}, quant)

    def encode_chunk(self, params, frames, start, n_valid, rules):
        """Run the bidirectional encoder over ONE audio chunk (streaming
        chunked encode; see :func:`repro.models.whisper.encode_chunk`)."""
        return W.encode_chunk(params, self.cfg, rules, frames, start, n_valid)

    def cross_kv_chunk(self, params, enc_chunk):
        """Encoder-output chunk (1, Cf, d) -> cross K/V (Ld, Cf, h, hd)."""
        return W.cross_kv_chunk(params, self.cfg, enc_chunk)

    def scatter_cross(self, storage, pages, k, v, *, page_size: int,
                      quant=None):
        """Write one chunk's cross K/V into its pages (quantize-on-write)."""
        return W.scatter_cross(storage, pages, k, v, page_size=page_size,
                               quant=quant)

    def paged_prefill_chunk(self, params, storage, table_row, pages_chunk,
                            start, tokens, rules, *,
                            use_pallas: bool = False, comm=None, quant=None,
                            ep_comm=None, placement=None, embeds=None,
                            cross=None):
        assert embeds is None, "whisper prompts are token-only"
        assert cross is not None, "enc-dec prefill needs cross-KV pages"
        return W.paged_prefill_chunk(
            params, self.cfg, rules, storage, table_row, pages_chunk, start,
            tokens, cross["storage"], cross["tables"], cross["frames_len"],
            use_pallas=use_pallas, quant=quant)

    def paged_decode_step(self, params, storage, tables, lengths, tokens,
                          write_pages, write_offs, rules, *,
                          use_pallas: bool = False, comm=None, quant=None,
                          ep_comm=None, placement=None, cross=None):
        assert cross is not None, "enc-dec decode needs cross-KV pages"
        return W.paged_decode_step(
            params, self.cfg, rules, storage, tables, lengths, tokens,
            write_pages, write_offs, cross["storage"], cross["tables"],
            cross["frames_len"], use_pallas=use_pallas, quant=quant)

    def paged_verify(self, params, storage, tables, lengths, tokens,
                     write_pages, write_offs, rules, *,
                     use_pallas: bool = False, comm=None, quant=None,
                     ep_comm=None, placement=None, cross=None):
        assert cross is not None, "enc-dec verify needs cross-KV pages"
        return W.paged_verify_chunk(
            params, self.cfg, rules, storage, tables, lengths, tokens,
            write_pages, write_offs, cross["storage"], cross["tables"],
            cross["frames_len"], use_pallas=use_pallas, quant=quant)

    def validate_serve_mesh(self, tp: int = 1, ep: int = 1) -> None:
        if tp > 1 or ep > 1:
            raise ValueError(
                f"{self.cfg.name} (audio/enc-dec) serves single-device only "
                f"in this release: mesh (tp={tp}, ep={ep}) is not wired for "
                "the cross-KV pool (see ROADMAP item 5 follow-ups) — drop "
                "--mesh")

    def validate_serve_encoder(self, *, page_size: int, max_len: int,
                               prefix_cache: bool = False) -> None:
        """Audio frames must fit the cross-KV page layout: at least one
        page of frames, and the decoder needs max_len >= 2 (prompt + one
        generated token).  The prefix cache never applies — decoder self-KV
        depends on the audio through cross-attention, so token-keyed
        sharing would alias different clips (the engine disables it)."""
        cfg = self.cfg
        if cfg.n_audio_frames < 1:
            raise ValueError(
                f"{cfg.name}: n_audio_frames={cfg.n_audio_frames} — an "
                "enc-dec request needs at least one audio frame")
        if max_len < 2:
            raise ValueError(
                f"{cfg.name}: max_len={max_len} cannot hold a decoder "
                "prompt plus one generated token; raise --max-len")


_FAMILIES: dict[str, type[Model]] = {
    "dense": DecoderLM,
    "moe": DecoderLM,
    "vlm": VLM,
    "hybrid": HybridLM,
    "ssm": RwkvLM,
    "audio": Whisper,
}


def build_model(cfg: ModelConfig) -> Model:
    """The family's :class:`Model` subclass bound to ``cfg``."""
    return _FAMILIES[cfg.family](cfg)
