"""Shared neural-net layers (functional; params are plain pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.module import Param


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_def(dim: int) -> dict:
    return {"scale": Param((dim,), P(None), init="ones")}

def rmsnorm(params, x, *, eps: float = 1e-6, use_pallas: bool = False):
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.rmsnorm(x, params["scale"], eps=eps)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_def(dim: int) -> dict:
    return {"scale": Param((dim,), P(None), init="ones"),
            "bias": Param((dim,), P(None), init="zeros")}

def layernorm(params, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_def(vocab: int, dim: int) -> dict:
    return {"table": Param((vocab, dim), P("vocab", "embed"), init="small")}

def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)

def unembed(params, x):
    """Tied unembedding: (B,S,d) @ (V,d)^T -> (B,S,V)."""
    return jnp.einsum("bsd,vd->bsv", x, params["table"],
                      preferred_element_type=jnp.float32)


def linear_def(d_in: int, d_out: int, spec: P, *, bias: bool = False,
               init: str = "normal") -> dict:
    d = {"w": Param((d_in, d_out), spec, init=init)}
    if bias:
        bias_axis = spec[-1] if len(spec) else None
        d["b"] = Param((d_out,), P(bias_axis), init="zeros")
    return d

def linear(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU family)
# ---------------------------------------------------------------------------

def mlp_def(d_model: int, d_ff: int) -> dict:
    return {
        "gate": Param((d_model, d_ff), P("embed_w", "mlp")),
        "up": Param((d_model, d_ff), P("embed_w", "mlp")),
        "down": Param((d_ff, d_model), P("mlp", "embed_w")),
    }

def mlp(params, x, *, activation=jax.nn.silu):
    g = x @ params["gate"].astype(x.dtype)
    u = x @ params["up"].astype(x.dtype)
    return (activation(g) * u) @ params["down"].astype(x.dtype)


def mlp_plain_def(d_model: int, d_ff: int) -> dict:
    """Non-gated FFN with biases (whisper-style)."""
    return {
        "up": Param((d_model, d_ff), P("embed_w", "mlp")),
        "up_b": Param((d_ff,), P("mlp"), init="zeros"),
        "down": Param((d_ff, d_model), P("mlp", "embed_w")),
        "down_b": Param((d_model,), P(None), init="zeros"),
    }

def mlp_plain(params, x, *, activation=jax.nn.gelu):
    h = activation(x @ params["up"].astype(x.dtype)
                   + params["up_b"].astype(x.dtype))
    return h @ params["down"].astype(x.dtype) + params["down_b"].astype(x.dtype)


def sinusoidal_pos(positions, dim: int, *, base: float = 10000.0):
    """(S,) -> (S, dim) sinusoidal embeddings (whisper enc/dec)."""
    half = dim // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x, positions, *, theta: float = 10000.0):
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, *, true_vocab: int | None = None,
                  z_loss: float = 0.0):
    """Stable CE in f32.  ``labels < 0`` positions are masked out.

    ``true_vocab``: when the vocab axis is padded for TP divisibility, the
    padded tail is excluded from the partition function.
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    if true_vocab is not None and true_vocab < v:
        pad_mask = jnp.arange(v) >= true_vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse ** 2
    weights = (labels >= 0).astype(jnp.float32)
    total = jnp.sum(nll * weights)
    denom = jnp.maximum(jnp.sum(weights), 1.0)
    return total / denom
