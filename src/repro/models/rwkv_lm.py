"""RWKV-6 language model: embed -> [rwkv blocks] -> norm -> head.

Training runs the per-layer time recurrence with ``lax.scan`` over layers
(stacked params) and, inside each block, ``lax.scan`` over time (the jnp
oracle of the Pallas ``rwkv6_scan`` kernel).  Decode state is O(1) in the
sequence length: per layer a (B, H, K, V) f32 wkv state plus the 1-token
shift buffers — so the ``long_500k`` shape runs with the same state shapes as
``decode_32k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.mesh.axes import constrain
from repro.models import layers as L
from repro.models import rwkv6 as R
from repro.models import transformer as T
from repro.models.module import Param


def rwkv_lm_defs(cfg) -> dict:
    return {
        "embed": {"table": Param((cfg.padded_vocab, cfg.d_model),
                                 P("vocab", "embed_w"), init="small")},
        "ln_in": L.layernorm_def(cfg.d_model),
        "blocks": T.stack_defs(R.rwkv_block_def(cfg), cfg.n_layers),
        "final_norm": L.layernorm_def(cfg.d_model),
        "unembed": {"w": Param((cfg.d_model, cfg.padded_vocab),
                               P("embed_w", "vocab"), init="small")},
    }


def forward(params, cfg, rules, tokens):
    x = T.embed_tokens(params, tokens, cfg, rules)
    x = L.layernorm(params["ln_in"], x)

    def body(x, p):
        x, _, _, _ = R.rwkv_block(p, x, cfg, rules)
        return x, None

    x, _ = jax.lax.scan(T._remat(body, cfg), x, params["blocks"])
    return L.layernorm(params["final_norm"], x)


def lm_loss(params, cfg, rules, tokens, labels, loss_chunks: int = 8):
    hidden = forward(params, cfg, rules, tokens)
    ce, cnt = T.loss_from_hidden(params["unembed"]["w"], hidden, labels, cfg,
                                 rules, loss_chunks)
    return ce, {"ce": ce, "tokens": cnt}


# ---------------------------------------------------------------------------
# Serving (state is O(1) in sequence length)
# ---------------------------------------------------------------------------

def init_state(cfg, batch: int, max_len: int = 0, dtype=jnp.bfloat16):
    Lh, H, hd, d = cfg.n_layers, cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.d_model
    del max_len  # recurrent state: independent of context length
    return {
        "wkv": jnp.zeros((Lh, batch, H, hd, hd), jnp.float32),
        "tm_prev": jnp.zeros((Lh, batch, 1, d), jnp.float32),
        "cm_prev": jnp.zeros((Lh, batch, 1, d), jnp.float32),
    }


def state_specs(cfg):
    return {
        "wkv": P(None, "batch", None, None, "rwkv_v"),
        "tm_prev": P(None, "batch", None, None),
        "cm_prev": P(None, "batch", None, None),
    }


def _forward_with_state(params, cfg, rules, x, state):
    x = L.layernorm(params["ln_in"], x)

    def body(x, xs):
        p, wkv, tmp, cmp = xs
        x, nw, ntp, ncp = R.rwkv_block(p, x, cfg, rules, tm_state=wkv,
                                       tm_prev=tmp, cm_prev=cmp)
        return x, (nw, ntp.astype(jnp.float32), ncp.astype(jnp.float32))

    x, (nw, ntp, ncp) = jax.lax.scan(
        body, x, (params["blocks"], state["wkv"], state["tm_prev"],
                  state["cm_prev"]))
    x = L.layernorm(params["final_norm"], x)
    return x, {"wkv": nw, "tm_prev": ntp, "cm_prev": ncp}


def prefill(params, cfg, rules, tokens, max_len: int = 0):
    B = tokens.shape[0]
    state = init_state(cfg, B)
    x = T.embed_tokens(params, tokens, cfg, rules)
    x, state = _forward_with_state(params, cfg, rules, x, state)
    return state, x


def decode_step(params, cfg, rules, state, tokens, pos):
    del pos  # recurrent: position enters only through the state
    x = T.embed_tokens(params, tokens, cfg, rules)
    x, state = _forward_with_state(params, cfg, rules, x, state)
    logits = T.lm_logits(params, x, cfg, rules)
    return state, logits
