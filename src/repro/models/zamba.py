"""Zamba2-style hybrid LM: a Mamba2 backbone with one *shared* transformer
block applied every ``cfg.shared_attn_every`` layers.

Structure (zamba2-7b: 81 Mamba2 layers, shared block after every 27):

    [27 x mamba2] -> shared attn+mlp -> [27 x mamba2] -> shared ... -> norm

The shared block has ONE parameter copy (the zamba trick), but each of its
applications has its *own* KV cache during decode (activations differ even
though weights are shared).  Layers are grouped in segments of
``shared_attn_every`` so the whole network is (outer python loop over
segments) x (inner ``lax.scan`` over the segment's stacked Mamba params) —
no per-layer ``lax.cond`` needed, keeping the lowered HLO clean.

Decode state: per-layer Mamba (ssm f32 + conv tails) states, stacked along a
leading ``layers`` axis, plus per-application KV caches for the shared block.
Both are O(1) (Mamba) / O(seq) (attn) — the arch is sub-quadratic, so the
``long_500k`` shape runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.mesh.axes import constrain
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import transformer as T
from repro.models.module import Param


def _n_segments(cfg) -> int:
    k = cfg.shared_attn_every
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    return cfg.n_layers // k


def zamba_defs(cfg) -> dict:
    seg = _n_segments(cfg)
    k = cfg.shared_attn_every
    mamba_layer = {
        "ln": L.rmsnorm_def(cfg.d_model),
        "mamba": M2.mamba2_def(cfg),
    }
    return {
        "embed": {"table": Param((cfg.padded_vocab, cfg.d_model),
                                 P("vocab", "embed_w"), init="small")},
        # (segments, layers_per_segment, ...) stacked Mamba params
        "mamba_blocks": T.stack_defs(T.stack_defs(mamba_layer, k), seg),
        "shared": {
            "ln1": L.rmsnorm_def(cfg.d_model),
            "attn": A.attention_def(cfg),
            "ln2": L.rmsnorm_def(cfg.d_model),
            "mlp": L.mlp_def(cfg.d_model, cfg.d_ff),
        },
        "final_norm": L.rmsnorm_def(cfg.d_model),
        "unembed": {"w": Param((cfg.d_model, cfg.padded_vocab),
                               P("embed_w", "vocab"), init="small")},
    }


def _shared_block(params, x, cfg, rules, *, positions, cache_k=None,
                  cache_v=None, cache_pos=None):
    """One application of the shared attention+MLP block."""
    h = L.rmsnorm(params["ln1"], x, use_pallas=cfg.use_pallas)
    h = constrain(h, P("batch", "seq", None), rules)
    q, k, v = A.qkv_project(params["attn"], h, cfg, positions,
                            rules=rules)
    if cache_k is not None:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, cache_pos, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, cache_pos, axis=1)
        kv_len = cache_pos + q.shape[1]
        o = A.gqa_attention(q, new_k, new_v, causal=True,
                            q_offset=cache_pos, kv_valid_len=kv_len,
                            kv_chunk=max(cache_k.shape[1], 1), use_pallas=False)
    else:
        new_k, new_v = k, v
        o = A.gqa_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk,
                            use_pallas=cfg.use_pallas)
    x = x + A.out_project(params["attn"], o)
    h = L.rmsnorm(params["ln2"], x, use_pallas=cfg.use_pallas)
    return x + L.mlp(params["mlp"], h), new_k, new_v


def _mamba_segment(seg_params, x, cfg, rules, *, states=None):
    """Scan over one segment's stacked Mamba layers.

    ``states``: None (train) or stacked per-layer {"ssm","conv"} pytree.
    Returns (x, new_states or None).
    """
    def body(x, xs):
        if states is None:
            p = xs
            h = L.rmsnorm(p["ln"], x, use_pallas=cfg.use_pallas)
            h = constrain(h, P("batch", "seq", None), rules)
            y, _, _ = M2.mamba2_block(p["mamba"], h, cfg, rules)
            return x + y, None
        p, st = xs
        h = L.rmsnorm(p["ln"], x, use_pallas=cfg.use_pallas)
        y, new_ssm, new_conv = M2.mamba2_block(
            p["mamba"], h, cfg, rules, ssm_state=st["ssm"],
            conv_state=st["conv"])
        return x + y, {"ssm": new_ssm, "conv": new_conv}

    if states is None:
        fn = T._remat(lambda c, xs: body(c, xs), cfg)
        x, _ = jax.lax.scan(fn, x, seg_params)
        return x, None
    x, new_states = jax.lax.scan(body, x, (seg_params, states))
    return x, new_states


def forward(params, cfg, rules, tokens):
    """Training forward -> final hidden states."""
    x = T.embed_tokens(params, tokens, cfg, rules)
    S = x.shape[1]
    positions = jnp.arange(S)
    seg = _n_segments(cfg)
    for s in range(seg):
        seg_p = jax.tree_util.tree_map(lambda a: a[s], params["mamba_blocks"])
        x, _ = _mamba_segment(seg_p, x, cfg, rules)
        x, _, _ = _shared_block(params["shared"], x, cfg, rules,
                                positions=positions)
    return L.rmsnorm(params["final_norm"], x, use_pallas=cfg.use_pallas)


def lm_loss(params, cfg, rules, tokens, labels, loss_chunks: int = 8):
    hidden = forward(params, cfg, rules, tokens)
    ce, cnt = T.loss_from_hidden(params["unembed"]["w"], hidden, labels, cfg,
                                 rules, loss_chunks)
    return ce, {"ce": ce, "tokens": cnt}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_state(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    seg = _n_segments(cfg)
    k = cfg.shared_attn_every
    H, Pd, N, K = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.conv_kernel
    hkv, hd = cfg.padded_kv_heads, cfg.head_dim
    return {
        "mamba": {
            "ssm": jnp.zeros((seg, k, batch, H, N, Pd), jnp.float32),
            "conv": {
                "x": jnp.zeros((seg, k, batch, K - 1, cfg.d_inner), jnp.float32),
                "B": jnp.zeros((seg, k, batch, K - 1, N), jnp.float32),
                "C": jnp.zeros((seg, k, batch, K - 1, N), jnp.float32),
            },
        },
        "attn_cache": {
            "k": jnp.zeros((seg, batch, max_len, hkv, hd), dtype),
            "v": jnp.zeros((seg, batch, max_len, hkv, hd), dtype),
        },
    }


def state_specs(cfg):
    """Logical PartitionSpecs matching :func:`init_state`'s tree."""
    return {
        "mamba": {
            "ssm": P(None, None, "batch", "ssm_heads", None, None),
            "conv": {
                "x": P(None, None, "batch", None, "inner"),
                "B": P(None, None, "batch", None, None),
                "C": P(None, None, "batch", None, None),
            },
        },
        "attn_cache": {
            "k": P(None, "batch", "kv_seq", None, None),
            "v": P(None, "batch", "kv_seq", None, None),
        },
    }


def _forward_with_state(params, cfg, rules, x, state, pos):
    """Shared by prefill (S>=1) and decode (S==1)."""
    S = x.shape[1]
    positions = pos + jnp.arange(S)
    seg = _n_segments(cfg)
    new_ssm, new_conv_x, new_conv_B, new_conv_C = [], [], [], []
    new_ck, new_cv = [], []
    for s in range(seg):
        seg_p = jax.tree_util.tree_map(lambda a: a[s], params["mamba_blocks"])
        st = {"ssm": state["mamba"]["ssm"][s],
              "conv": {kk: state["mamba"]["conv"][kk][s] for kk in "xBC"}}
        x, ns = _mamba_segment(seg_p, x, cfg, rules, states=st)
        new_ssm.append(ns["ssm"])
        new_conv_x.append(ns["conv"]["x"])
        new_conv_B.append(ns["conv"]["B"])
        new_conv_C.append(ns["conv"]["C"])
        x, ck, cv = _shared_block(
            params["shared"], x, cfg, rules, positions=positions,
            cache_k=state["attn_cache"]["k"][s],
            cache_v=state["attn_cache"]["v"][s], cache_pos=pos)
        new_ck.append(ck)
        new_cv.append(cv)
    x = L.rmsnorm(params["final_norm"], x, use_pallas=cfg.use_pallas)
    new_state = {
        "mamba": {"ssm": jnp.stack(new_ssm),
                  "conv": {"x": jnp.stack(new_conv_x),
                           "B": jnp.stack(new_conv_B),
                           "C": jnp.stack(new_conv_C)}},
        "attn_cache": {"k": jnp.stack(new_ck), "v": jnp.stack(new_cv)},
    }
    return x, new_state


def prefill(params, cfg, rules, tokens, max_len: int):
    B, S = tokens.shape
    state = init_state(cfg, B, max_len, dtype=jnp.dtype(cfg.dtype))
    x = T.embed_tokens(params, tokens, cfg, rules)
    # attn caches need S <= max_len writes at pos 0
    x, state = _forward_with_state(params, cfg, rules, x, state,
                                   jnp.asarray(0, jnp.int32))
    return state, x


def decode_step(params, cfg, rules, state, tokens, pos):
    x = T.embed_tokens(params, tokens, cfg, rules)
    x, state = _forward_with_state(params, cfg, rules, x, state, pos)
    logits = T.lm_logits(params, x, cfg, rules)
    return state, logits
