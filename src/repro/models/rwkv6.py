"""RWKV-6 (Finch) — attention-free token mixing with data-dependent decay.

TPU adaptation: the per-head state recurrence
``S_t = diag(w_t) S_t-1 + k_t v_t^T`` runs as one ``lax.scan`` over time; the
per-head *value* channels (64) are TP-sharded over ``model`` (the head count
40 does not divide 16, value channels do), so the state (B,H,K,V/16) and the
output projection contraction are sharded with a single psum at the output.
The Pallas kernel (:mod:`repro.kernels.rwkv6_scan`) keeps the state in VMEM
scratch across grid steps; this module is its jnp oracle-equivalent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.mesh.axes import constrain
from repro.models import layers as L
from repro.models.module import Param


def rwkv_block_def(cfg) -> dict:
    d, hd = cfg.d_model, cfg.rwkv_head_dim
    H = cfg.rwkv_heads
    return {
        "ln1": L.layernorm_def(d),
        "ln2": L.layernorm_def(d),
        "tm": {  # time mix
            "mu_r": Param((d,), P(None), init="small"),
            "mu_k": Param((d,), P(None), init="small"),
            "mu_v": Param((d,), P(None), init="small"),
            "mu_g": Param((d,), P(None), init="small"),
            "mu_w": Param((d,), P(None), init="small"),
            "w_r": Param((d, H, hd), P("embed_w", None, None)),
            "w_k": Param((d, H, hd), P("embed_w", None, None)),
            "w_v": Param((d, H, hd), P("embed_w", None, "rwkv_v")),
            "w_g": Param((d, H, hd), P("embed_w", None, "rwkv_v")),
            "w_decay": Param((d, H, hd), P("embed_w", None, None), init="small"),
            "decay_base": Param((H, hd), P(None, None), init="zeros"),
            "u": Param((H, hd), P(None, None), init="small"),
            "ln_x": L.layernorm_def(H * hd),
            "w_o": Param((H, hd, d), P(None, "rwkv_v", "embed_w")),
        },
        "cm": {  # channel mix
            "mu_k": Param((d,), P(None), init="small"),
            "mu_r": Param((d,), P(None), init="small"),
            "w_k": Param((d, cfg.d_ff), P("embed_w", "mlp")),
            "w_v": Param((cfg.d_ff, d), P("mlp", "embed_w")),
            "w_r": Param((d, d), P("embed_w", None)),
        },
    }


def _token_shift(x, x_prev=None):
    """(B,S,d) -> previous token's activations (decode: x_prev (B,1,d))."""
    if x_prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([x_prev.astype(x.dtype), x], axis=1)[:, :-1]


def _lerp(x, shifted, mu):
    return x + (shifted - x) * mu.astype(x.dtype)


def _wkv_chunked(r, k, v, w, u, state, *, chunk: int):
    """Chunked matmul form of the wkv recurrence (the SSD trick applied to
    RWKV-6; mirrors the Pallas kernel's blocking in pure jnp).

    Per chunk of Q steps, with lw = log w and inclusive cumsum cs (per key
    channel):

        y_t = (r_t ∘ e^{cs_{t-1}}) · S_0              (inter-chunk)
            + Σ_{j<t} [(r_t ∘ e^{cs_{t-1}}) · (k_j ∘ e^{-cs_j})] v_j
            + ((r_t ∘ u) · k_t) v_t                   (bonus diagonal)
        S_Q  = diag(e^{cs_Q}) S_0 + Σ_j (k_j ∘ e^{cs_Q - cs_j}) v_j^T

    The state round-trips HBM once per CHUNK instead of once per step
    (the jnp scan's pathology — see EXPERIMENTS.md §Perf/rwkv), and the
    inner sums are (Q x Q) / (Q x V) GEMMs that feed the MXU.
    Numerics: f32 with Q <= 32 keeps |cs| ~< 32, inside f32 exp range.
    """
    B, S, H, K = r.shape
    Q = chunk
    nc = S // Q

    def to_chunks(t):                              # (B,S,H,C) -> (nc,B,Q,H,C)
        return t.reshape(B, nc, Q, H, t.shape[-1]).swapaxes(0, 1)

    rc, kc, vc = to_chunks(r), to_chunks(k), to_chunks(v)
    lw = to_chunks(jnp.log(jnp.maximum(w, 1e-30)))

    def body(S0, xs):
        r_, k_, v_, lw_ = xs                       # (B,Q,H,K/V)
        cs = jnp.cumsum(lw_, axis=1)               # inclusive (B,Q,H,K)
        cs_prev = cs - lw_                         # exclusive
        r_t = r_ * jnp.exp(cs_prev)
        k_t = k_ * jnp.exp(-cs)
        A = jnp.einsum("bqhk,bjhk->bhqj", r_t, k_t)
        mask = jnp.tril(jnp.ones((Q, Q), bool), -1)
        A = jnp.where(mask[None, None], A, 0.0)
        diag = jnp.einsum("bqhk,bqhk->bqh", r_ * u, k_)
        y = jnp.einsum("bhqj,bjhv->bqhv", A, v_)
        y = y + diag[..., None] * v_
        y = y + jnp.einsum("bqhk,bhkv->bqhv", r_t, S0)
        k_end = k_ * jnp.exp(cs[:, -1:] - cs)      # (B,Q,H,K)
        S_new = S0 * jnp.exp(cs[:, -1])[..., None] \
            + jnp.einsum("bqhk,bqhv->bhkv", k_end, v_)
        return S_new, y

    state, ys = jax.lax.scan(body, state, (rc, kc, vc, lw))
    y = ys.swapaxes(0, 1).reshape(B, S, H, -1)
    return y, state


def time_mix(p, x, cfg, rules, *, state=None, x_prev=None):
    """Returns (out, new_state, last_x).  state: (B,H,K,V) f32."""
    B, S, d = x.shape
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    sx = _token_shift(x, x_prev)
    r = jnp.einsum("bsd,dhk->bshk", _lerp(x, sx, p["mu_r"]), p["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", _lerp(x, sx, p["mu_k"]), p["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,dhv->bshv", _lerp(x, sx, p["mu_v"]), p["w_v"].astype(x.dtype))
    g = jnp.einsum("bsd,dhv->bshv", _lerp(x, sx, p["mu_g"]), p["w_g"].astype(x.dtype))
    wlog = jnp.einsum("bsd,dhk->bshk", _lerp(x, sx, p["mu_w"]),
                      p["w_decay"].astype(x.dtype))
    # data-dependent decay in (0,1): w = exp(-exp(base + wlog))
    w = jnp.exp(-jnp.exp(p["decay_base"].astype(jnp.float32)
                         + wlog.astype(jnp.float32)))          # (B,S,H,K)
    u = p["u"].astype(jnp.float32)

    v = constrain(v, P("batch", None, None, "rwkv_v"), rules)
    g = constrain(g, P("batch", None, None, "rwkv_v"), rules)

    if state is None:
        state = jnp.zeros((B, H, hd, v.shape[-1]), jnp.float32)
        state = constrain(state, P("batch", None, None, "rwkv_v"), rules)

    chunk = cfg.rwkv_time_chunk
    if chunk and S > 1 and S % chunk == 0:
        y, state = _wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), w, u, state,
                                chunk=chunk)
    else:
        def step(S_, xs):
            r_t, k_t, v_t, w_t = xs                             # (B,H,K),(B,H,V)
            kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,K,V)
            y = jnp.einsum("bhk,bhkv->bhv", r_t, S_ + u[..., None] * kv)
            S_ = w_t[..., None] * S_ + kv
            return S_, y

        xs = (r.astype(jnp.float32).swapaxes(0, 1),
              k.astype(jnp.float32).swapaxes(0, 1),
              v.astype(jnp.float32).swapaxes(0, 1),
              w.swapaxes(0, 1))
        state, ys = jax.lax.scan(step, state, xs)
        y = ys.swapaxes(0, 1)                                   # (B,S,H,V)
    y = y.reshape(B, S, -1)
    y = L.layernorm(p["ln_x"], y) if y.shape[-1] == H * hd else y
    y = y.reshape(B, S, H, -1) * jax.nn.silu(g.astype(y.dtype))
    out = jnp.einsum("bshv,hvd->bsd", y, p["w_o"].astype(y.dtype))
    return out.astype(x.dtype), state, x[:, -1:]


def channel_mix(p, x, *, x_prev=None):
    sx = _token_shift(x, x_prev)
    k = _lerp(x, sx, p["mu_k"]) @ p["w_k"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(k))
    kv = k @ p["w_v"].astype(x.dtype)
    r = jax.nn.sigmoid(_lerp(x, sx, p["mu_r"]) @ p["w_r"].astype(x.dtype))
    return r * kv, x[:, -1:]


def rwkv_block(params, x, cfg, rules, *, tm_state=None, tm_prev=None,
               cm_prev=None):
    h = L.layernorm(params["ln1"], x)
    o, new_state, new_tm_prev = time_mix(params["tm"], h, cfg, rules,
                                         state=tm_state, x_prev=tm_prev)
    x = x + o
    h = L.layernorm(params["ln2"], x)
    o, new_cm_prev = channel_mix(params["cm"], h, x_prev=cm_prev)
    return x + o, new_state, new_tm_prev, new_cm_prev
