"""Mixture-of-Experts with sort-based dispatch + expert-parallel all_to_all.

This is the paper's §3.2 dynamic load balancing transplanted to token routing:
tokens are the walkers, experts are the processors, the
capacity factor realizes ``find_optimal_workload``'s balanced target, and the
``all_to_all`` exchange is ``redistribute_work`` on the ICI torus.  The
auxiliary balancing loss *drives the router towards the balanced distribution*
that the paper's rebalancer would impose after the fact — the differentiable
version of the same idea.

Dispatch is sort-based (argsort by expert, capacity-bounded scatter), NOT a
one-hot einsum: HLO FLOPs then consist of the true expert GEMMs only, keeping
`cost_analysis()` (and the roofline) honest.

The block is written in the paper's explicit-communication style inside a
``shard_map``; with ``rules=None``/``SerialComm`` the identical code runs on
one device (serial/parallel duality, as in the paper).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import Comm, SerialComm
from repro.core.comm import shard_map as _comm_shard_map
from repro.mesh.axes import AxisRules, logical_to_mesh
from repro.models.module import Param


def moe_def(cfg) -> dict:
    d, E, eff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    return {
        "router": Param((d, E), P("embed", None), init="small"),
        "gate": Param((E, d, eff), P("experts", "expert_embed", "expert_mlp")),
        "up": Param((E, d, eff), P("experts", "expert_embed", "expert_mlp")),
        "down": Param((E, eff, d), P("experts", "expert_mlp", "expert_embed")),
    }


def capacity(tokens_local: int, top_k: int, n_experts: int, cf: float) -> int:
    """Per-shard, per-expert slot budget — ``find_optimal_workload`` with
    uniform timings becomes the balanced ±1 split scaled by the capacity
    factor."""
    c = math.ceil(tokens_local * top_k / n_experts * cf)
    return max(4, ((c + 3) // 4) * 4)


def _dispatch_compute_combine(x2d, wr, wg, wu, wd, cfg, comm, tp_comm=None,
                              shard_comm=None):
    """Core routed computation on one shard.  x2d: (T_l, d).

    ``tp_comm``: expert-TP mode — the expert ff dim is sharded over this
    axis; the down projection's partial sums are psum'd across it.

    ``shard_comm``: serving-TP mode (activations replicated, expert weights
    sharded over this axis).  Routing, capacity dropping and the combine all
    run replicated — identical to the serial path — and only the expert
    GEMMs are sharded: each rank computes its expert slice of the
    (replicated) dispatch buffer and one ``all_gather`` restores the full
    buffer, so each per-expert contraction happens on exactly one rank and
    the result is bitwise equal to the serial dispatch."""
    T_l, d = x2d.shape
    E, k = cfg.n_experts, cfg.top_k
    ep = comm.size()
    assert E % ep == 0, (E, ep)
    E_loc = E // ep
    C = capacity(T_l, k, E, cfg.capacity_factor)

    # --- route ------------------------------------------------------------
    logits = (x2d.astype(jnp.float32) @ wr.astype(jnp.float32))      # (T_l, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                           # (T_l, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # --- aux losses (global means via psum) ---------------------------------
    me = jnp.mean(probs, axis=0)                                     # (E,)
    ce_frac = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (T_l * k))
    me = comm.all_reduce_sum(me) / max(comm.size(), 1)
    ce_frac = comm.all_reduce_sum(ce_frac) / max(comm.size(), 1)
    aux = E * jnp.sum(me * ce_frac)
    zl = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = aux + cfg.router_z_weight * comm.all_reduce_sum(zl) / max(comm.size(), 1)

    # --- sort-based dispatch ------------------------------------------------
    flat_e = top_e.reshape(-1)                                       # (T_l*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    sorted_tok = order // k
    oh = jax.nn.one_hot(sorted_e, E, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1             # rank in expert
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)           # drop -> OOB
    buf = jnp.zeros((E * C + 1, d), x2d.dtype).at[slot].set(
        x2d[sorted_tok], mode="drop")
    buf = buf[:-1].reshape(E, C, d)

    # --- EP exchange: redistribute_work on the torus ------------------------
    buf = comm.all_to_all(buf, split_axis=0, concat_axis=1)          # (E_loc, C*ep, d)
    if shard_comm is not None:
        # serving TP: buf is replicated; take my expert rows only
        n_sh = shard_comm.size()
        assert wg.shape[0] * n_sh == E_loc, (wg.shape, n_sh, E_loc)
        buf = jax.lax.dynamic_slice_in_dim(
            buf, shard_comm.rank() * wg.shape[0], wg.shape[0], axis=0)

    # --- expert GEMMs (the only matmul FLOPs in the block) -------------------
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(buf.dtype))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, wd.astype(h.dtype))
    if tp_comm is not None:
        # expert-TP: ff dim sharded; sum the down-projection partials
        out = tp_comm.all_reduce_sum(out.astype(jnp.float32)).astype(out.dtype)
    if shard_comm is not None:
        # rank order == expert order, so the gather is the identity layout
        out = shard_comm.all_gather(out, axis=0, tiled=True)         # (E_loc, C, d)

    # --- return + combine ----------------------------------------------------
    out = comm.all_to_all(out, split_axis=1, concat_axis=0)          # (E, C, d)
    out = out.reshape(E * C, d)
    gathered = jnp.where(keep[:, None], out[jnp.clip(slot, 0, E * C - 1)], 0.0)
    w_sorted = top_p.reshape(-1)[order]
    contrib = gathered * w_sorted[:, None].astype(gathered.dtype)
    y = jnp.zeros((T_l, d), x2d.dtype).at[sorted_tok].add(contrib)
    return y, aux


def moe_apply_serve_tp(params, x, cfg, shard_comm: Comm):
    """MoE block INSIDE a serving-TP ``shard_map`` body.

    Activations are replicated over the ``model`` axis and the expert
    weights arrive expert-sharded (``gate``/``up``/``down``: (E/tp, ...) per
    rank; ``router`` replicated).  Routing and the capacity-bounded dispatch
    replicate the serial ``moe_apply`` math exactly; only the expert GEMMs
    run sharded (see ``shard_comm`` in :func:`_dispatch_compute_combine`),
    which keeps greedy token streams bit-identical to the tp=1 engine while
    cutting per-rank expert FLOPs by tp.
    """
    y2d, aux = _dispatch_compute_combine(
        x.reshape(-1, x.shape[-1]), params["router"], params["gate"],
        params["up"], params["down"], cfg, SerialComm(),
        shard_comm=shard_comm)
    return y2d.reshape(x.shape), aux


def moe_apply(params, x, cfg, rules: AxisRules | None):
    """x: (B, S, d) -> (y, aux_loss)."""
    wr, wg, wu, wd = (params["router"], params["gate"], params["up"],
                      params["down"])

    if rules is None or rules.mesh is None:
        y2d, aux = _dispatch_compute_combine(
            x.reshape(-1, x.shape[-1]), wr, wg, wu, wd, cfg, SerialComm())
        return y2d.reshape(x.shape), aux

    mesh = rules.mesh
    x_spec = logical_to_mesh(P("batch", "seq", None), rules)
    w_specs = {
        "router": logical_to_mesh(P("embed", None), rules),
        "gate": logical_to_mesh(P("experts", "expert_embed", "expert_mlp"),
                                rules),
        "up": logical_to_mesh(P("experts", "expert_embed", "expert_mlp"),
                              rules),
        "down": logical_to_mesh(P("experts", "expert_mlp", "expert_embed"),
                                rules),
    }
    fsdp_axes = rules.get("expert_embed")
    tp_axes = rules.get("expert_mlp")

    def _fsdp_gather(fs, w, dim):
        """All-gather a weight's FSDP-sharded ``dim`` (explicit ZeRO-3)."""
        g = fs.all_gather(w, tiled=False)             # (F, ...)
        g = jnp.moveaxis(g, 0, dim)                   # (..., F, d/F, ...)
        shape = list(w.shape)
        shape[dim] = -1
        return g.reshape(shape)

    def body(x_l, wr_l, wg_l, wu_l, wd_l):
        comm_ep = Comm("model")
        B_l, S_l, d = x_l.shape
        x2d = x_l.reshape(-1, d)
        if fsdp_axes is not None:
            # TRAIN mode (ZeRO-3): many tokens amortize a per-layer weight
            # gather; expert weights arrive d-sharded and are gathered.
            fs = Comm(fsdp_axes)
            wg_l = _fsdp_gather(fs, wg_l, 1)          # (E_loc, d, eff)
            wu_l = _fsdp_gather(fs, wu_l, 1)
            wd_l = _fsdp_gather(fs, wd_l, 2)          # (E_loc, eff, d)
            y, aux = _dispatch_compute_combine(
                x2d, wr_l, wg_l, wu_l, wd_l, cfg, comm_ep)
        elif tp_axes is not None:
            # DECODE mode (weight-stationary expert TP): the token batch is
            # tiny, the weights are 480B — so move the tokens, never the
            # weights.  Gather this axis's few tokens, compute against the
            # local ff slice, psum the down partials, slice my rows back.
            tpc = Comm(tp_axes)
            T_l = x2d.shape[0]
            x_all = tpc.all_gather(x2d, tiled=True)   # (T_l * n_tp, d)
            y_all, aux = _dispatch_compute_combine(
                x_all, wr_l, wg_l, wu_l, wd_l, cfg, comm_ep, tp_comm=tpc)
            y = jax.lax.dynamic_slice_in_dim(y_all, tpc.rank() * T_l, T_l, 0)
        else:
            y, aux = _dispatch_compute_combine(
                x2d, wr_l, wg_l, wu_l, wd_l, cfg, comm_ep)
        aux = Comm(mesh.axis_names).all_reduce_sum(aux) / mesh.size
        return y.reshape(B_l, S_l, d), aux

    y, aux = _comm_shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, w_specs["router"], w_specs["gate"], w_specs["up"],
                  w_specs["down"]),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, wr, wg, wu, wd)
    return y, aux
