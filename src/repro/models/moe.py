"""Mixture-of-Experts with sort-based dispatch + expert-parallel all_to_all.

This is the paper's §3.2 dynamic load balancing transplanted to token routing:
tokens are the walkers, experts are the processors, the
capacity factor realizes ``find_optimal_workload``'s balanced target, and the
``all_to_all`` exchange is ``redistribute_work`` on the ICI torus.  The
auxiliary balancing loss *drives the router towards the balanced distribution*
that the paper's rebalancer would impose after the fact — the differentiable
version of the same idea.

Dispatch is sort-based (argsort by expert, capacity-bounded scatter), NOT a
one-hot einsum: HLO FLOPs then consist of the true expert GEMMs only, keeping
`cost_analysis()` (and the roofline) honest.

The block is written in the paper's explicit-communication style inside a
``shard_map``; with ``rules=None``/``SerialComm`` the identical code runs on
one device (serial/parallel duality, as in the paper).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.comm import Comm, SerialComm
from repro.core.comm import shard_map as _comm_shard_map
from repro.mesh.axes import AxisRules, logical_to_mesh
from repro.models.module import Param

# placement split fractions are q8 fixed-point: a dispatch map entry of
# ``split_q`` sends the first ``split_q * C // PLACE_Q`` capacity positions
# of an expert to its first physical slot and the rest to its second —
# integer math, so the split is deterministic at every capacity C
PLACE_Q = 256


def moe_def(cfg) -> dict:
    d, E, eff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    return {
        "router": Param((d, E), P("embed", None), init="small"),
        "gate": Param((E, d, eff), P("experts", "expert_embed", "expert_mlp")),
        "up": Param((E, d, eff), P("experts", "expert_embed", "expert_mlp")),
        "down": Param((E, eff, d), P("experts", "expert_mlp", "expert_embed")),
    }


def capacity(tokens_local: int, top_k: int, n_experts: int, cf: float) -> int:
    """Per-shard, per-expert slot budget — ``find_optimal_workload`` with
    uniform timings becomes the balanced ±1 split scaled by the capacity
    factor.  ``cf < 1`` deliberately under-provisions (tokens beyond the
    budget are dropped and counted); ``top_k > n_experts`` can never route
    and is refused outright."""
    if top_k > n_experts:
        raise ValueError(
            f"top_k={top_k} > n_experts={n_experts}: every token would need "
            "more distinct experts than exist")
    c = math.ceil(tokens_local * top_k / n_experts * cf)
    return max(4, ((c + 3) // 4) * 4)


def identity_placement(n_experts: int) -> np.ndarray:
    """The (3, E) int32 dispatch map that reproduces the unplaced layout
    (expert e in physical slot e, no replicas): rows are [slot_a, slot_b,
    split_q] — see ``placement`` in :func:`_dispatch_compute_combine`."""
    e = np.arange(n_experts, dtype=np.int32)
    return np.stack([e, e, np.zeros(n_experts, np.int32)])


def empty_expert_stats(n_experts: int) -> dict:
    z = jnp.zeros((n_experts,), jnp.int32)
    return {"tokens": z, "dropped": z}


def _dispatch_compute_combine(x2d, wr, wg, wu, wd, cfg, comm, tp_comm=None,
                              shard_comm=None, placement=None):
    """Core routed computation on one shard.  x2d: (T_l, d).

    ``tp_comm``: expert-TP mode — the expert ff dim is sharded over this
    axis; the down projection's partial sums are psum'd across it.

    ``shard_comm``: serving-TP mode (activations replicated, expert weights
    sharded over this axis).  Routing, capacity dropping and the combine all
    run replicated — identical to the serial path — and only the expert
    GEMMs are sharded: each rank computes its expert slice of the
    (replicated) dispatch buffer and one ``all_gather`` restores the full
    buffer, so each per-expert contraction happens on exactly one rank and
    the result is bitwise equal to the serial dispatch.

    ``placement``: (3, E) int32 device array [slot_a, slot_b, split_q] from
    ``serve.placement`` — logical expert e's first ``split_q[e] * C //
    PLACE_Q`` capacity positions go to physical slot ``slot_a[e]``, the rest
    to ``slot_b[e]``; a slot of -1 means the expert holds no weights (its
    tokens are dropped and counted).  The weight leaves must already be
    permuted to match (``placement.apply_placement``).  ``None`` and the
    identity map produce the exact integer slot indices of the unplaced
    path, so streams are bitwise unchanged.

    Returns ``(y, aux, stats)`` with int32 per-logical-expert telemetry
    ``stats = {"tokens": routed assignments (top_k multiplicity), "dropped":
    assignments lost to capacity or eviction}`` — local to this shard's
    tokens (replicated = global in serving)."""
    T_l, d = x2d.shape
    E, k = cfg.n_experts, cfg.top_k
    ep = comm.size()
    assert E % ep == 0, (E, ep)
    E_loc = E // ep
    C = capacity(T_l, k, E, cfg.capacity_factor)

    # --- route ------------------------------------------------------------
    logits = (x2d.astype(jnp.float32) @ wr.astype(jnp.float32))      # (T_l, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                           # (T_l, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # --- aux losses (global means via psum) ---------------------------------
    me = jnp.mean(probs, axis=0)                                     # (E,)
    ce_frac = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (T_l * k))
    me = comm.all_reduce_sum(me) / max(comm.size(), 1)
    ce_frac = comm.all_reduce_sum(ce_frac) / max(comm.size(), 1)
    aux = E * jnp.sum(me * ce_frac)
    zl = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = aux + cfg.router_z_weight * comm.all_reduce_sum(zl) / max(comm.size(), 1)

    # --- sort-based dispatch ------------------------------------------------
    flat_e = top_e.reshape(-1)                                       # (T_l*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    sorted_tok = order // k
    oh = jax.nn.one_hot(sorted_e, E, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1             # rank in expert
    keep = pos_in_e < C
    if placement is None:
        slot_e, pos = sorted_e, pos_in_e
    else:
        slot_a, slot_b, split_q = placement[0], placement[1], placement[2]
        sp = (split_q[sorted_e] * C) // PLACE_Q          # per-assignment split
        use_b = pos_in_e >= sp
        slot_e = jnp.where(use_b, slot_b[sorted_e], slot_a[sorted_e])
        pos = jnp.where(use_b, pos_in_e - sp, pos_in_e)
        keep = keep & (slot_e >= 0)                      # evicted -> dropped
    slot = jnp.where(keep, slot_e * C + pos, E * C)      # drop -> OOB
    buf = jnp.zeros((E * C + 1, d), x2d.dtype).at[slot].set(
        x2d[sorted_tok], mode="drop")
    buf = buf[:-1].reshape(E, C, d)

    # --- telemetry: per-logical-expert routed / dropped assignments ---------
    routed = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    kept = jnp.zeros((E,), jnp.int32).at[sorted_e].add(keep.astype(jnp.int32))
    stats = {"tokens": routed, "dropped": routed - kept}

    # --- EP exchange: redistribute_work on the torus ------------------------
    buf = comm.all_to_all(buf, split_axis=0, concat_axis=1)          # (E_loc, C*ep, d)
    if shard_comm is not None:
        # serving TP: buf is replicated; take my expert rows only
        n_sh = shard_comm.size()
        assert wg.shape[0] * n_sh == E_loc, (wg.shape, n_sh, E_loc)
        buf = jax.lax.dynamic_slice_in_dim(
            buf, shard_comm.rank() * wg.shape[0], wg.shape[0], axis=0)

    # --- expert GEMMs (the only matmul FLOPs in the block) -------------------
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(buf.dtype))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, wd.astype(h.dtype))
    if tp_comm is not None:
        # expert-TP: ff dim sharded; sum the down-projection partials
        out = tp_comm.all_reduce_sum(out.astype(jnp.float32)).astype(out.dtype)
    if shard_comm is not None:
        # rank order == expert order, so the gather is the identity layout
        out = shard_comm.all_gather(out, axis=0, tiled=True)         # (E_loc, C, d)

    # --- return + combine ----------------------------------------------------
    out = comm.all_to_all(out, split_axis=1, concat_axis=0)          # (E, C, d)
    out = out.reshape(E * C, d)
    gathered = jnp.where(keep[:, None], out[jnp.clip(slot, 0, E * C - 1)], 0.0)
    w_sorted = top_p.reshape(-1)[order]
    contrib = gathered * w_sorted[:, None].astype(gathered.dtype)
    y = jnp.zeros((T_l, d), x2d.dtype).at[sorted_tok].add(contrib)
    return y, aux, stats


def moe_apply_expert_parallel(params, x, cfg, ep_comm: Comm,
                              shard_comm: Comm | None = None, placement=None):
    """MoE block with experts PARTITIONED over ``ep_comm``'s mesh axis.

    Serving-mode expert parallelism: activations (and therefore routing,
    the capacity drop rule and the combine) are replicated over every mesh
    axis; the (E, C, d) dispatch buffer is exchanged through
    ``ep_comm.all_to_all`` so each rank holds the capacity rows of its own
    E/ep experts, runs the expert GEMMs against its local expert weights,
    and the reverse ``all_to_all`` hands every rank back the full combined
    buffer.  Because the buffer is replicated before the exchange, each
    per-expert contraction happens with bit-identical inputs and weights to
    the serial path, on exactly one rank — so greedy token streams are
    bitwise equal to ``SerialComm`` / tp=1.

    Composes with Megatron serving TP as a 2-D ``(expert, model)`` mesh:
    pass the model-axis ``Comm`` as ``shard_comm`` and the per-rank expert
    weights arrive (E/(ep*tp), ...).  ``SerialComm()`` as ``ep_comm``
    recovers the single-device / pure-TP path.

    Returns ``(y, aux, stats)`` — see :func:`_dispatch_compute_combine` for
    ``placement`` and the telemetry dict.
    """
    y2d, aux, stats = _dispatch_compute_combine(
        x.reshape(-1, x.shape[-1]), params["router"], params["gate"],
        params["up"], params["down"], cfg, ep_comm,
        shard_comm=shard_comm, placement=placement)
    return y2d.reshape(x.shape), aux, stats


def moe_apply_serve_tp(params, x, cfg, shard_comm: Comm, placement=None):
    """MoE block INSIDE a serving-TP ``shard_map`` body.

    Activations are replicated over the ``model`` axis and the expert
    weights arrive expert-sharded (``gate``/``up``/``down``: (E/tp, ...) per
    rank; ``router`` replicated).  Routing and the capacity-bounded dispatch
    replicate the serial ``moe_apply`` math exactly; only the expert GEMMs
    run sharded (see ``shard_comm`` in :func:`_dispatch_compute_combine`),
    which keeps greedy token streams bit-identical to the tp=1 engine while
    cutting per-rank expert FLOPs by tp.  Returns ``(y, aux, stats)``.
    """
    return moe_apply_expert_parallel(params, x, cfg, SerialComm(),
                                     shard_comm=shard_comm,
                                     placement=placement)


def moe_apply(params, x, cfg, rules: AxisRules | None):
    """x: (B, S, d) -> (y, aux_loss)."""
    wr, wg, wu, wd = (params["router"], params["gate"], params["up"],
                      params["down"])

    if rules is None or rules.mesh is None:
        y2d, aux, _ = _dispatch_compute_combine(
            x.reshape(-1, x.shape[-1]), wr, wg, wu, wd, cfg, SerialComm())
        return y2d.reshape(x.shape), aux

    mesh = rules.mesh
    x_spec = logical_to_mesh(P("batch", "seq", None), rules)
    w_specs = {
        "router": logical_to_mesh(P("embed", None), rules),
        "gate": logical_to_mesh(P("experts", "expert_embed", "expert_mlp"),
                                rules),
        "up": logical_to_mesh(P("experts", "expert_embed", "expert_mlp"),
                              rules),
        "down": logical_to_mesh(P("experts", "expert_mlp", "expert_embed"),
                                rules),
    }
    fsdp_axes = rules.get("expert_embed")
    tp_axes = rules.get("expert_mlp")

    def _fsdp_gather(fs, w, dim):
        """All-gather a weight's FSDP-sharded ``dim`` (explicit ZeRO-3)."""
        g = fs.all_gather(w, tiled=False)             # (F, ...)
        g = jnp.moveaxis(g, 0, dim)                   # (..., F, d/F, ...)
        shape = list(w.shape)
        shape[dim] = -1
        return g.reshape(shape)

    def body(x_l, wr_l, wg_l, wu_l, wd_l):
        comm_ep = Comm("model")
        B_l, S_l, d = x_l.shape
        x2d = x_l.reshape(-1, d)
        if fsdp_axes is not None:
            # TRAIN mode (ZeRO-3): many tokens amortize a per-layer weight
            # gather; expert weights arrive d-sharded and are gathered.
            fs = Comm(fsdp_axes)
            wg_l = _fsdp_gather(fs, wg_l, 1)          # (E_loc, d, eff)
            wu_l = _fsdp_gather(fs, wu_l, 1)
            wd_l = _fsdp_gather(fs, wd_l, 2)          # (E_loc, eff, d)
            y, aux, _ = _dispatch_compute_combine(
                x2d, wr_l, wg_l, wu_l, wd_l, cfg, comm_ep)
        elif tp_axes is not None:
            # DECODE mode (weight-stationary expert TP): the token batch is
            # tiny, the weights are 480B — so move the tokens, never the
            # weights.  Gather this axis's few tokens, compute against the
            # local ff slice, psum the down partials, slice my rows back.
            tpc = Comm(tp_axes)
            T_l = x2d.shape[0]
            x_all = tpc.all_gather(x2d, tiled=True)   # (T_l * n_tp, d)
            y_all, aux, _ = _dispatch_compute_combine(
                x_all, wr_l, wg_l, wu_l, wd_l, cfg, comm_ep, tp_comm=tpc)
            y = jax.lax.dynamic_slice_in_dim(y_all, tpc.rank() * T_l, T_l, 0)
        else:
            y, aux, _ = _dispatch_compute_combine(
                x2d, wr_l, wg_l, wu_l, wd_l, cfg, comm_ep)
        aux = Comm(mesh.axis_names).all_reduce_sum(aux) / mesh.size
        return y.reshape(B_l, S_l, d), aux

    y, aux = _comm_shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, w_specs["router"], w_specs["gate"], w_specs["up"],
                  w_specs["down"]),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, wr, wg, wu, wd)
    return y, aux
