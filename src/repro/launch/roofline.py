"""Roofline report: turn results/dryrun.json into EXPERIMENTS.md tables.

Per (arch x shape x mesh) cell:
    compute_s    = HLO_FLOPs_per_device / 197e12        (bf16 peak, v5e)
    memory_s     = HLO_bytes_per_device / 819e9          (HBM bw)
    collective_s = ring-adjusted wire bytes / 50e9       (ICI link bw)
with loop-corrected HLO numbers from launch.hlo_analysis (XLA's own
cost_analysis counts while bodies once — see that module's docstring).

Usage::

    PYTHONPATH=src python -m repro.launch.roofline [--json results/dryrun.json]
"""
from __future__ import annotations

import argparse
import json
import os


def fmt_row(rec) -> str:
    if rec["status"] == "SKIP":
        return (f"| {rec['arch']} | {rec['shape']} | SKIP | — | — | — | — | — | "
                f"{rec['reason'][:60]} |")
    if rec["status"] != "OK":
        return (f"| {rec['arch']} | {rec['shape']} | FAIL | — | — | — | — | — | "
                f"{rec.get('error', '')[:60]} |")
    r = rec["roofline"]
    m = rec["memory"]
    note = f"useful={r['useful_flops_ratio']:.2f}"
    return ("| {arch} | {shape} | {bound} | {c:.3f} | {mem:.3f} | {coll:.3f} "
            "| {step:.3f} | {hbm:.1f} | {note} |").format(
        arch=rec["arch"], shape=rec["shape"], bound=r["bound"],
        c=r["compute_s"], mem=r["memory_s"], coll=r["collective_s"],
        step=r["step_s_estimate"], hbm=m["hbm_per_device"] / 1e9, note=note)


HEADER = ("| arch | shape | bound | compute_s | memory_s | collective_s "
          "| step_s | HBM GB/dev | notes |\n"
          "|---|---|---|---|---|---|---|---|---|")


def render(results: dict) -> str:
    out = []
    for mesh_name, title in (("single", "Single pod (16x16 = 256 chips)"),
                             ("multi", "Multi-pod (2x16x16 = 512 chips)")):
        rows = [r for k, r in sorted(results.items())
                if k.endswith(f"|{mesh_name}")]
        if not rows:
            continue
        out.append(f"\n### {title}\n")
        out.append(HEADER)
        for r in rows:
            out.append(fmt_row(r))
        n_ok = sum(1 for r in rows if r["status"] == "OK")
        n_skip = sum(1 for r in rows if r["status"] == "SKIP")
        n_fail = len(rows) - n_ok - n_skip
        out.append(f"\n{n_ok} OK / {n_skip} SKIP / {n_fail} FAIL\n")
    return "\n".join(out)


def interesting_cells(results: dict, mesh_name: str = "single"):
    """The three §Perf hillclimb picks: worst useful-flops fraction, most
    collective-bound, and the MoE cell most representative of the paper's
    load-balancing technique."""
    ok = [r for k, r in results.items()
          if k.endswith(f"|{mesh_name}") and r["status"] == "OK"]
    if not ok:
        return {}
    worst = min(ok, key=lambda r: r["roofline"]["useful_flops_ratio"]
                * min(1.0, r["roofline"]["compute_s"]
                      / max(r["roofline"]["step_s_estimate"], 1e-12)))
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
               / max(r["roofline"]["step_s_estimate"], 1e-12))
    moe = [r for r in ok if "moe" in r["arch"] or "arctic" in r["arch"]]
    rep = max(moe, key=lambda r: r["roofline"]["step_s_estimate"]) if moe else ok[0]
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": rep}


def main():
    ap = argparse.ArgumentParser()
    default = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun.json")
    ap.add_argument("--json", default=os.path.abspath(default))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    with open(args.json) as f:
        results = json.load(f)
    text = render(results)
    print(text)
    picks = interesting_cells(results)
    if picks:
        print("\n### Hillclimb picks\n")
        for why, r in picks.items():
            print(f"- **{why}**: {r['arch']} x {r['shape']} "
                  f"(bound={r['roofline']['bound']}, "
                  f"step≈{r['roofline']['step_s_estimate']:.3f}s)")
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)


if __name__ == "__main__":
    main()
