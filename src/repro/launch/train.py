"""Training launcher.

CPU container: ``--smoke`` (reduced config, 1 device) actually trains;
full configs are exercised through the dry-run.  On a real pod the same
command with ``--mesh single|multi`` builds the production mesh and runs the
identical code path (the mesh is the only difference — the paper's
serial/parallel duality).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, list_archs, smoke_config
from repro.data import SyntheticTask, make_data_iter
from repro.models.api import build_model
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "debug", "single", "multi"])
    # cross-pod int8 compressed sync is host-orchestrated; see
    # repro.train.pod_dp (exercised by tests/test_distributed.py)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)

    mesh = rules = None
    if args.mesh != "none":
        from repro.launch.mesh import make_debug_mesh, make_production_mesh
        from repro.mesh.axes import rules_for_mesh
        if args.mesh == "debug":
            mesh = make_debug_mesh()
        else:
            mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        rules = rules_for_mesh(mesh)

    task = SyntheticTask(cfg, batch=args.batch, seq_len=args.seq)
    specs = model.train_batch_specs(
        type("S", (), {"global_batch": args.batch, "seq_len": args.seq})())
    it = make_data_iter(task, mesh, rules, specs)

    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, accum_steps=args.accum)
    opt = AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      decay_steps=args.steps)
    trainer = Trainer(model, opt, tcfg, it, mesh=mesh, rules=rules)
    result = trainer.fit()
    h = result["history"]
    print(f"[train] {args.arch}: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} "
          f"over {len(h)} steps; stragglers={result['stragglers']}")


if __name__ == "__main__":
    main()
