import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell with ShapeDtypeStruct inputs (no allocation), record memory / cost /
collective analysis for §Dry-run and §Roofline.

The two lines above MUST run before any jax import (device count locks on
first init), which is why they sit above this docstring.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k --mesh multi
    PYTHONPATH=src python -m repro.launch.dryrun --list

Results append incrementally to results/dryrun.json (safe to re-run; done
cells are skipped unless --force).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeConfig, shape_applicable
from repro.launch import hlo_analysis
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS,
                               make_production_mesh)
from repro.mesh.axes import AxisRules, logical_to_sharding, rules_for_mesh
from repro.models.api import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.state import abstract_train_state
from repro.train.step import make_train_step

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun.json")


# ---------------------------------------------------------------------------
# Per-cell sharding rules (baseline scheme + shape-driven overrides)
# ---------------------------------------------------------------------------

def rules_for_cell(mesh, cfg: ModelConfig, shape: ShapeConfig) -> AxisRules:
    overrides = {}
    if cfg.family in ("ssm", "hybrid"):
        # recurrent time mixing needs the whole sequence on-device: seq
        # sharding would all-gather x per layer (measured 0.43 TB/step on
        # rwkv train_4k).  When the batch divides the whole mesh, run pure
        # 2D data parallelism (batch over data x model, 1 seq/device at
        # train_4k) with ZeRO weight sharding; TP dims are released to avoid
        # double-sharding conflicts with the batch axes.
        non_pod = tuple(a for a in mesh.axis_names if a != "pod")
        non_pod_size = 1
        for a in non_pod:
            non_pod_size *= mesh.shape[a]
        if (shape.kind in ("train", "prefill")
                and shape.global_batch % mesh.size == 0):
            overrides["seq"] = None
            overrides["batch"] = tuple(mesh.axis_names)
            overrides.update({"mlp": None, "inner": None, "ssm_heads": None,
                              "rwkv_v": None, "vocab": None})
        elif (shape.kind in ("train", "prefill")
                and shape.global_batch % non_pod_size == 0):
            # multi-pod with batch < mesh: batch over (data, model); the pod
            # axis takes a second ZeRO dimension instead of batch
            overrides["seq"] = None
            overrides["batch"] = non_pod
            overrides["embed_w"] = (("pod", "data") if "pod" in mesh.axis_names
                                    else "data")
            overrides["expert_embed"] = overrides["embed_w"]
            overrides.update({"mlp": None, "inner": None, "ssm_heads": None,
                              "rwkv_v": None, "vocab": None})
        elif cfg.family == "ssm" or shape.kind == "decode":
            # rwkv stays cheap with seq unsharded (chunked wkv); zamba's
            # wide d_inner cannot afford model-replicated activations, so
            # non-divisible hybrid prefill keeps the default seq sharding
            # (per-layer gathers are the lesser evil — measured 8.7 vs 11.5s
            # with 15x the HBM)
            overrides["seq"] = None
    if shape.kind == "decode":
        overrides["seq"] = None            # S=1: nothing to shard
        if cfg.n_experts:
            # weight-stationary expert TP: at one token per sequence, moving
            # 480B of expert weights per step is absurd — move tokens instead
            overrides["expert_embed"] = None
            overrides["expert_mlp"] = "data"
        if shape.global_batch == 1:        # long_500k: parallelism = seq only
            overrides["batch"] = None
            overrides["kv_seq"] = tuple(mesh.axis_names)
    return rules_for_mesh(mesh, overrides)


def opt_config(cfg: ModelConfig) -> AdamWConfig:
    return AdamWConfig(moment_dtype=jnp.dtype(cfg.moment_dtype))


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape_name: str, mesh=None, rules=None) -> dict:
    """Abstract inputs for the step that `shape` lowers (train_step for
    train shapes; prefill/serve_step inputs for inference shapes)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    rules = rules or (rules_for_cell(mesh, cfg, shape) if mesh else None)

    if shape.kind == "train":
        specs = model.train_batch_specs(shape)
        return {k: v.abstract(mesh, rules) for k, v in specs.items()}
    if shape.kind == "prefill":
        specs = model.prefill_batch_specs(shape)
        return {k: v.abstract(mesh, rules) for k, v in specs.items()}
    # decode: (state, tokens, pos)
    B = shape.global_batch
    state_specs = model.decode_state_specs(B, shape.seq_len)
    state = jax.tree_util.tree_map(
        lambda a: a.abstract(mesh, rules), state_specs,
        is_leaf=lambda x: hasattr(x, "abstract"))
    tok_sharding = (None if mesh is None else
                    logical_to_sharding(P("batch", None), mesh, rules))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_sharding)
    pos_sharding = (None if mesh is None else
                    logical_to_sharding(P(), mesh, rules))
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=pos_sharding)
    return {"state": state, "tokens": tokens, "pos": pos}


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, mesh, *, mesh_name: str,
               rules=None, cfg=None, do_compile: bool = True) -> dict:
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "n_devices": mesh.size}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec["status"] = "SKIP"
        rec["reason"] = reason
        return rec

    rules = rules or rules_for_cell(mesh, cfg, shape)
    model = build_model(cfg)
    pdtype = jnp.dtype(cfg.param_dtype)
    t0 = time.time()

    if shape.kind == "train":
        step = make_train_step(model, opt_config(cfg), mesh, rules)
        state = abstract_train_state(model, opt_config(cfg), mesh, rules,
                                     param_dtype=pdtype)
        batch = input_specs(arch, shape_name, mesh, rules)
        lowered = step.lower(state, batch)
        tokens_per_step = shape.global_batch * shape.seq_len
        mf_mult = 6
    elif shape.kind == "prefill":
        params = model.abstract_params(mesh, rules, dtype=pdtype)
        batch = input_specs(arch, shape_name, mesh, rules)

        def prefill_fn(p, b):
            return model.prefill(p, b, rules, shape.seq_len)

        lowered = jax.jit(prefill_fn).lower(params, batch)
        tokens_per_step = shape.global_batch * shape.seq_len
        mf_mult = 2
    else:  # decode
        params = model.abstract_params(mesh, rules, dtype=pdtype)
        ins = input_specs(arch, shape_name, mesh, rules)

        def serve_step(p, state, tokens, pos):
            return model.decode_step(p, state, tokens, pos, rules)

        lowered = jax.jit(serve_step, donate_argnums=(1,)).lower(
            params, ins["state"], ins["tokens"], ins["pos"])
        tokens_per_step = shape.global_batch
        mf_mult = 2

    rec["lower_s"] = round(time.time() - t0, 2)
    if not do_compile:
        rec["status"] = "LOWERED"
        return rec

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    # ---- memory ------------------------------------------------------------
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    # donated buffers alias in->out; live set ~ args + temps
    hbm = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
           + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    rec["memory"]["hbm_per_device"] = int(hbm)
    rec["memory"]["fits_16GB"] = bool(hbm < 16e9)

    # ---- XLA cost analysis (loop-UNcorrected; kept for reference) ----------
    ca = compiled.cost_analysis()
    rec["xla_cost"] = {"flops": float(ca.get("flops", -1)),
                       "bytes_accessed": float(ca.get("bytes accessed", -1))}

    # ---- loop-corrected HLO analysis ---------------------------------------
    t2 = time.time()
    score_dims = set()
    if cfg.n_heads and shape.kind != "decode":
        score_dims = {cfg.kv_chunk, shape.seq_len,
                      shape.seq_len // mesh.shape["model"]}
        if cfg.n_audio_frames:
            score_dims.add(cfg.n_audio_frames)
    stats = hlo_analysis.analyze(compiled.as_text(), n_devices=mesh.size,
                                 score_dims=score_dims)
    rec["analyze_s"] = round(time.time() - t2, 2)
    rec["hlo"] = {
        "flops_per_device": stats.flops,
        "bytes_per_device": stats.bytes_accessed,
        "collective_wire_bytes_per_device": stats.collective_bytes,
        "collective_by_type": stats.collective_by_type,
        "dot_count": stats.dot_count,
        "while_trips": stats.while_trips,
    }

    # ---- roofline terms ------------------------------------------------------
    n_active = model.n_active_params()
    model_flops = mf_mult * n_active * tokens_per_step
    compute_s = stats.flops / PEAK_FLOPS
    memory_s = stats.bytes_accessed / HBM_BW
    # what the Pallas flash-attention kernel leaves (scores stay in VMEM)
    memory_adj_s = (stats.bytes_accessed - stats.attn_score_bytes) / HBM_BW
    collective_s = stats.collective_bytes / ICI_BW
    bound = max((compute_s, "compute"), (memory_s, "memory"),
                (collective_s, "collective"))[1]
    rec["roofline"] = {
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / mesh.size,
        "useful_flops_ratio": (model_flops / mesh.size) / max(stats.flops, 1),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_kernel_adj_s": memory_adj_s,
        "attn_score_bytes": stats.attn_score_bytes,
        "collective_s": collective_s,
        "bound": bound,
        "step_s_estimate": max(compute_s, memory_s, collective_s),
    }
    rec["params_total"] = model.n_params()
    rec["params_active"] = n_active
    rec["status"] = "OK"
    return rec


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def _load_results(path):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def _save_results(path, results):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


def cell_key(arch, shape, mesh_name):
    return f"{arch}|{shape}|{mesh_name}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS))
    args = ap.parse_args()

    cells = []
    archs = list_archs() if args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = ["single", "multi"] if args.all else [args.mesh]
    for m in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, m))

    if args.list:
        for c in cells:
            print(*c)
        return

    results = _load_results(args.out)
    mesh_cache = {}
    for arch, shape, mesh_name in cells:
        key = cell_key(arch, shape, mesh_name)
        if key in results and not args.force \
                and results[key].get("status") in ("OK", "SKIP"):
            print(f"[dryrun] {key}: cached ({results[key]['status']})")
            continue
        if mesh_name not in mesh_cache:
            mesh_cache[mesh_name] = make_production_mesh(
                multi_pod=(mesh_name == "multi"))
        mesh = mesh_cache[mesh_name]
        print(f"[dryrun] {key}: lowering on {mesh.shape} ...", flush=True)
        try:
            rec = lower_cell(arch, shape, mesh, mesh_name=mesh_name)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        results[key] = rec
        _save_results(args.out, results)
        status = rec["status"]
        extra = ""
        if status == "OK":
            r = rec["roofline"]
            extra = (f" bound={r['bound']} step≈{r['step_s_estimate']:.4f}s "
                     f"useful={r['useful_flops_ratio']:.2f} "
                     f"hbm={rec['memory']['hbm_per_device']/1e9:.2f}GB "
                     f"(compile {rec['compile_s']}s)")
        elif status == "FAIL":
            extra = " " + rec["error"][:160]
        print(f"[dryrun] {key}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
