"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).

* single pod: (16, 16) = 256 chips, axes ("data", "model")
* multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model")

The "pod" axis is pure DP by default (batch shards over ("pod", "data"));
the compressed-gradient path (optim.compress) and pipeline configs target it
explicitly because inter-pod links are the slow tier.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh for multi-device CPU tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# TPU v5e hardware model (roofline constants, per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
