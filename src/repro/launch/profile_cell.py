"""Per-cell HLO profiler: top HBM ops and collectives for one
(arch x shape) cell — the working tool behind every EXPERIMENTS.md §Perf
iteration.

    PYTHONPATH=src python -m repro.launch.profile_cell <arch> <shape> [multi]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
import jax, jax.numpy as jnp, re
from collections import Counter
from repro.launch import dryrun as D, hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.configs import get_config, SHAPES
from repro.models.api import build_model
from repro.train.state import abstract_train_state
from repro.train.step import make_train_step

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-7b"
shape_name = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
multi = len(sys.argv) > 3 and sys.argv[3] == "multi"

mesh = make_production_mesh(multi_pod=multi)
cfg = get_config(arch)
shape = SHAPES[shape_name]
rules = D.rules_for_cell(mesh, cfg, shape)
model = build_model(cfg)

if shape.kind == "train":
    step = make_train_step(model, D.opt_config(cfg), mesh, rules)
    state = abstract_train_state(model, D.opt_config(cfg), mesh, rules,
                                 param_dtype=jnp.dtype(cfg.param_dtype))
    batch = D.input_specs(arch, shape_name, mesh, rules)
    compiled = step.lower(state, batch).compile()
elif shape.kind == "prefill":
    params = model.abstract_params(mesh, rules, dtype=jnp.dtype(cfg.param_dtype))
    batch = D.input_specs(arch, shape_name, mesh, rules)
    compiled = jax.jit(lambda p, b: model.prefill(p, b, rules, shape.seq_len)).lower(params, batch).compile()
else:
    params = model.abstract_params(mesh, rules, dtype=jnp.dtype(cfg.param_dtype))
    ins = D.input_specs(arch, shape_name, mesh, rules)
    compiled = jax.jit(lambda p, s, t, pos: model.decode_step(p, s, t, pos, rules),
                       donate_argnums=(1,)).lower(params, ins["state"], ins["tokens"], ins["pos"]).compile()

txt = compiled.as_text()
comps = H.parse_hlo(txt)
entry = H._find_entry(comps, txt)
mult, fused = H._multiplicities(comps, entry)
agg = Counter()
coll = Counter()
for comp in comps.values():
    m = mult.get(comp.name, 0)
    if m <= 0 or fused.get(comp.name, False):
        continue
    for op in comp.ops:
        base = op.op.replace("-start", "")
        if base in H.COLLECTIVES:
            g = H._group_size(op.line, mesh.size)
            rb = H._shape_bytes(op.type_str)
            wire = {"all-gather": (g-1)/g*rb, "reduce-scatter": (g-1)*rb,
                    "all-reduce": 2*(g-1)/g*rb, "all-to-all": (g-1)/g*rb,
                    "collective-permute": rb}[base]
            coll[(base, op.type_str[:48], g, comp.name[:30])] += m*wire
        if op.op in H._SKIP_BYTES:
            continue
        b = H.op_bytes(op, comp, comps)
        agg[(op.op, op.type_str[:56], f"{comp.name[:26]} m={m:.0f}")] += m*b

print("== top HBM ops (total %.3e) ==" % sum(agg.values()))
for (opn, t, cn), b in agg.most_common(14):
    print(f"{b:.3e}  {opn:20s} {t[:54]} in {cn}")
print("== top collectives (total wire %.3e) ==" % sum(coll.values()))
for (base, t, g, cn), b in coll.most_common(12):
    print(f"{b:.3e}  {base:18s} g={g:4d} {t[:46]} in {cn}")
