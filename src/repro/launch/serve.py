"""Serving launcher: continuous-batching engine demo.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
        --requests 16 --max-new 24

Tensor-parallel serving over a device mesh (shards attention heads, MLP ff,
experts, the vocab and the paged-KV head axis over ``tp`` devices; the
scheduler and page tables stay on the host).  MoE families can ALSO
partition whole experts over an ``ep``-sized "expert" axis (all-to-all
dispatch/combine, per-expert token telemetry, optional load-aware
re-placement with ``--expert-placement N``).  On CPU, prefix with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to fake the devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-235b-a22b \
        --smoke --mesh tp=2,ep=4 --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs, smoke_config
from repro.models.api import build_model
from repro.serve import DisaggServeEngine, ServeEngine, make_workload, \
    run_traffic


def parse_mesh(spec: str | None):
    """``"tp=N[,ep=M]"`` -> serving mesh (None -> no mesh).

    ``tp=N`` alone keeps the legacy 1-D ("model",) mesh; any spec naming
    ``ep`` builds the 2-D ("expert", "model") mesh of ep x tp devices
    (``ep=M`` alone means tp=1) — MoE experts partition over "expert",
    everything Megatron-ish over "model"."""
    if not spec:
        return None
    vals: dict[str, int] = {}
    for part in spec.split(","):
        key, _, val = part.partition("=")
        if key not in ("tp", "ep") or key in vals \
                or not val.isdigit() or int(val) < 1:
            raise SystemExit(
                f"--mesh expects tp=N[,ep=M] (each >= 1), got {spec!r}")
        vals[key] = int(val)
    tp, ep = vals.get("tp", 1), vals.get("ep")
    need = tp * (ep or 1)
    n = len(jax.devices())
    if need > n:
        raise SystemExit(f"--mesh {spec} needs {need} devices but only {n} "
                         "visible (set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N on CPU)")
    if ep is None:
        return jax.make_mesh((tp,), ("model",))
    return jax.make_mesh((ep, tp), ("expert", "model"))


def encoder_workload_kwargs(cfg, args) -> dict:
    """--image/--audio -> the multimodal band of :func:`make_workload`
    (empty dict when both flags are off, keeping the text schedule
    byte-identical)."""
    if getattr(args, "image", False):
        return dict(encoder="image",
                    encoder_shape=(cfg.n_image_tokens, cfg.d_model),
                    encoder_frac=args.encoder_frac, n_encoder_inputs=2)
    if getattr(args, "audio", False):
        # enc-dec rejects text-only submissions (nothing to cross-attend
        # into), so every request carries a clip
        return dict(encoder="audio",
                    encoder_shape=(cfg.n_audio_frames, cfg.d_model),
                    encoder_frac=1.0, n_encoder_inputs=2)
    return {}


def run_traffic_demo(eng, cfg, args) -> None:
    """Open-loop traffic run: seeded workload, event log, metric report."""
    slo = {}
    if args.slo_ttft is not None:
        slo["ttft"] = args.slo_ttft
    if args.slo_e2e is not None:
        slo["e2e"] = args.slo_e2e
    # cap prompt bands so prefix + tail + generation (and a VLM's image
    # pseudo-token prefix) fit in max_len
    enc_extra = cfg.n_image_tokens if args.image else 0
    hi = max(5, args.max_len - args.shared_prefix - args.max_new - 1
             - enc_extra)
    len_mix = ((3.0, 4, min(24, hi)), (1.0, min(32, hi), hi))
    wl = make_workload(kind=args.traffic, n_requests=args.requests,
                       rate=args.rate, vocab=cfg.vocab, seed=0,
                       max_new_tokens=args.max_new,
                       shared_prefix_len=args.shared_prefix, n_sessions=2,
                       len_mix=len_mix,
                       **encoder_workload_kwargs(cfg, args))
    t0 = time.perf_counter()
    res = run_traffic(eng, wl, clock=args.clock, slo=slo or None)
    dt = time.perf_counter() - t0
    eng.close()
    rep = res["report"]
    unit = "ticks" if args.clock == "virtual" else "s"
    print(f"[serve] traffic {args.traffic} rate={args.rate}: "
          f"{rep['n_measured']}/{rep['n_requests']} requests, "
          f"{rep['tokens']} tokens over {rep['span']:.1f} {unit} "
          f"({dt:.2f}s wall)"
          + (f" [disagg executor={args.executor}]" if args.disagg else ""))
    for name in ("ttft", "itl", "e2e"):
        p = rep[name]
        print(f"[serve] {name}: p50={p['p50']} p95={p['p95']} "
              f"p99={p['p99']} {unit} (n={p['n']})")
    g = rep["goodput"]
    per = "tick" if args.clock == "virtual" else "s"
    print(f"[serve] goodput: {g['tok_per_s']:.3f} tok/{per} "
          f"slo_attainment={g['slo_attainment']:.2f}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"args": {"traffic": args.traffic, "rate": args.rate,
                                "clock": args.clock, "disagg": args.disagg,
                                "requests": args.requests,
                                "max_new": args.max_new},
                       "wall_seconds": dt, "report": rep}, f, indent=2)
        print(f"[serve] metrics written to {args.metrics_out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--dense", action="store_true",
                    help="force the dense per-slot cache path")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool size (default: dense-equivalent budget)")
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--prefix-cache", choices=("on", "off"), default="on",
                    help="cross-request KV reuse: refcounted pages + radix "
                    "prefix index + copy-on-write (paged path only)")
    ap.add_argument("--shared-prefix", type=int, default=24, metavar="L",
                    help="prepend an L-token common prefix to every prompt "
                    "(a shared system prompt; 0 disables)")
    ap.add_argument("--spec-decode", default="off", metavar="ngram|self-K|off",
                    help="speculative multi-token decode: a drafter proposes "
                    "tokens, one batched verify accepts the prefix the "
                    "target agrees with (paged families only; 'ngram' = "
                    "prompt-lookup, 'self-2' = first-2-layer self-draft)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per verify window")
    ap.add_argument("--mesh", default=None, metavar="tp=N[,ep=M]",
                    help="serve tensor-parallel over an N-device "
                    "('model',) mesh; add ep=M for a 2-D "
                    "('expert', 'model') mesh partitioning whole MoE "
                    "experts over M devices (all-to-all dispatch/combine)")
    ap.add_argument("--expert-placement", type=int, default=0, metavar="N",
                    help="re-place experts every N ticks from measured "
                    "per-expert token counts (load_balance-driven, "
                    "hot-expert replication; 0 = off)")
    ap.add_argument("--pallas-attention", action="store_true",
                    help="route paged decode/verify/prefill attention "
                    "through the fused multi-query Pallas kernel "
                    "(interpret-mode off-TPU; paged families only)")
    ap.add_argument("--kv-quant", choices=("int8", "off"), default="off",
                    help="store KV pages as int8 with per-(token, head) "
                    "scale leaves, dequantized inside attention — halves "
                    "(bf16) or quarters (f32) KV bytes/token, so the same "
                    "HBM budget holds ~2-4x the concurrent slots")
    ap.add_argument("--weight-quant", choices=("int8", "off"), default="off",
                    help="store serve params as per-tensor int8, "
                    "dequantized on apply inside the jitted paged calls")
    ap.add_argument("--disagg", action="store_true",
                    help="split serving into a prefill-only engine and a "
                    "decode engine with KV page handoff between them")
    ap.add_argument("--executor", choices=("serial", "thread"),
                    default="serial",
                    help="disagg stage driver: deterministic serial order "
                    "or overlapped farm threads")
    ap.add_argument("--traffic", choices=("off", "poisson", "bursty"),
                    default="off",
                    help="drive the engine with an open-loop seeded arrival "
                    "process instead of submitting everything up front")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="traffic arrival rate (requests per clock unit)")
    ap.add_argument("--clock", choices=("virtual", "wall"), default="virtual",
                    help="virtual: 1 tick = 1 time unit, fully "
                    "deterministic; wall: real seconds")
    ap.add_argument("--slo-ttft", type=float, default=None, metavar="T",
                    help="goodput SLO: time-to-first-token bound "
                    "(clock units)")
    ap.add_argument("--slo-e2e", type=float, default=None, metavar="T",
                    help="goodput SLO: end-to-end latency bound "
                    "(clock units)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the traffic metric report as JSON")
    ap.add_argument("--image", action="store_true",
                    help="multimodal serving: attach precomputed image-patch "
                    "embeddings to a fraction of requests (VLM families; "
                    "the image prefix pages share through the prefix cache)")
    ap.add_argument("--audio", action="store_true",
                    help="multimodal serving: attach audio frames to every "
                    "request (enc-dec families; streaming chunked encode "
                    "into read-only cross-KV pages)")
    ap.add_argument("--encoder-frac", type=float, default=0.5,
                    help="fraction of requests carrying an image with "
                    "--image (audio is always 1.0 — enc-dec needs a clip)")
    args = ap.parse_args()
    if args.disagg and args.dense:
        raise SystemExit("--disagg needs the paged KV engine; drop --dense")

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("hybrid",):
        raise SystemExit("engine demo targets KV-cache families; "
                         "zamba uses aligned decode (see tests)")
    if args.image and cfg.family != "vlm":
        raise SystemExit(f"--image needs a VLM arch (family 'vlm'); "
                         f"{args.arch} is '{cfg.family}'")
    if args.audio and cfg.family != "audio":
        raise SystemExit(f"--audio needs an enc-dec arch (family 'audio'); "
                         f"{args.arch} is '{cfg.family}'")
    if args.audio and (args.disagg or args.dense):
        raise SystemExit("--audio serves monolithic and paged only (cross-KV "
                         "pages have no handoff or dense twin); drop "
                         "--disagg/--dense")
    if (args.image or args.audio) and args.disagg:
        raise SystemExit("--image/--audio are wired through the monolithic "
                         "engine; drop --disagg")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = parse_mesh(args.mesh)
    kw = dict(max_slots=args.slots, max_len=args.max_len,
              page_size=args.page_size, num_pages=args.num_pages,
              prefill_chunk=args.prefill_chunk,
              prefix_cache=args.prefix_cache == "on",
              spec_decode=None if args.spec_decode == "off"
              else args.spec_decode,
              spec_k=args.spec_k, mesh=mesh,
              use_pallas_attention=args.pallas_attention,
              kv_quant=None if args.kv_quant == "off" else args.kv_quant,
              weight_quant=None if args.weight_quant == "off"
              else args.weight_quant,
              placement_interval=args.expert_placement)
    if args.disagg:
        eng = DisaggServeEngine(model, params, executor=args.executor, **kw)
    else:
        eng = ServeEngine(model, params,
                          paged=False if args.dense else None, **kw)

    if args.traffic != "off":
        run_traffic_demo(eng, cfg, args)
        return

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab, args.shared_prefix)
    enc_pool = []
    if args.image or args.audio:
        n = cfg.n_image_tokens if args.image else cfg.n_audio_frames
        # two distinct payloads, alternated: repeated-image requests share
        # prefix pages, distinct images never alias
        enc_pool = [rng.standard_normal((n, cfg.d_model)).astype(np.float32)
                    for _ in range(2)]
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 48))
        prompt = np.concatenate([shared, rng.integers(0, cfg.vocab, plen)])
        kw = {"encoder_input": enc_pool[i % 2]} if enc_pool else {}
        eng.submit(prompt, max_new_tokens=args.max_new, **kw)
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    if args.disagg:
        s = eng.stats
        print(f"[serve] disagg: {len(done)} requests, {toks} tokens in "
              f"{dt:.2f}s ({toks/dt:.1f} tok/s); "
              f"prefill ticks={s['prefill']['ticks']} "
              f"handoffs={s['prefill']['kv_handoffs']} | "
              f"decode ticks={s['decode']['ticks']} "
              f"injections={s['decode']['kv_injections']} "
              f"preempt={s['decode']['preemptions']} "
              f"[executor={args.executor}]")
        eng.close()
        return
    ttfts = [r.first_token_at - r.submitted_at for r in done]
    mode = "dense" if not eng.paged else (
        f"paged(ps={eng.pool.page_size}, "
        f"hw={eng.stats['pages_high_water']}/{eng.pool.num_pages} pages, "
        f"prefix-cache {'on' if eng.prefix_cache else 'off'})")
    if eng.kv_quant is not None or eng.weight_quant:
        mode += (f" quant(kv={eng.stats['kv_quant']}, "
                 f"w={eng.stats['weight_quant']}, "
                 f"{eng.stats['kv_bytes_per_token']} KV B/tok)")
    if eng.drafter is not None:
        mode += f" spec={args.spec_decode}(k={eng.spec_k})"
    if mesh is not None:
        mode += f" tp={eng.tp}" + (f" ep={eng.ep}" if eng.ep > 1 else "")
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s); ticks={eng.stats['ticks']} "
          f"chunks={eng.stats['chunk_prefills']} "
          f"preempt={eng.stats['preemptions']} [{mode}] "
          f"mean TTFT {np.mean(ttfts)*1e3:.0f}ms")
    if eng.paged:
        s = eng.stats
        print(f"[serve] prefix cache: hits={s['prefix_hits']} "
              f"hit_tokens={s['prefix_hit_tokens']} "
              f"cow_copies={s['cow_copies']} evictions={s['evictions']} "
              f"cached_now={eng.pool.pages_cached} pages")
        if getattr(eng, "cross_pool", None) is not None:
            print(f"[serve] cross-KV: encode_chunks={s['encode_chunks']} "
                  f"pages_in_use={eng.cross_pool.pages_in_use}/"
                  f"{eng.cross_pool.num_pages}")
        if eng.drafter is not None:
            print(f"[serve] spec decode: proposed={s['draft_proposed']} "
                  f"accepted={s['draft_accepted']} "
                  f"acceptance_rate={s['acceptance_rate']:.2f}")
        if cfg.n_experts:
            # dropped = capacity-factor + placement-eviction losses, which
            # are silent in the token streams (the drop rule zeroes the
            # expert's contribution) — surface them here
            print(f"[serve] moe: routed={s['moe_tokens_routed']} "
                  f"dropped={s['moe_dropped_tokens']} "
                  f"rank_imbalance={s['expert_imbalance']:.2f} "
                  f"placements={s['placement_updates']}")


if __name__ == "__main__":
    main()
