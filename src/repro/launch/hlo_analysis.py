"""Static analyzer for compiled (SPMD-partitioned) HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts a ``while`` body
ONCE — a 94-layer scanned transformer reports ~1/94th of its FLOPs (verified
empirically; see EXPERIMENTS.md §Roofline).  The roofline needs loop-aware
totals, so this module parses the HLO text and

1. splits it into computations and builds the call graph
   (``calls=`` / ``to_apply=`` / ``condition=`` / ``body=`` / branches),
2. recovers each ``while`` trip count from the constant in its condition
   (scan lowers to ``i < constant``),
3. propagates execution **multiplicity** through the graph,
4. accumulates, weighted by multiplicity:
   * FLOPs: ``2 * prod(result_dims) * prod(contracting_dims)`` per dot,
   * HBM bytes: operand + result bytes of every *scheduled* op line (fusion
     bodies excluded — a fusion is one HBM pass; slicing ops count their
     slice, not the sliced operand),
   * collective wire bytes per device with ring adjustment:
     AG: (g-1)/g x result;  RS: (g-1) x result;  AR: 2(g-1)/g x size;
     A2A: (g-1)/g x size;   permute: size.

All numbers are PER DEVICE because the compiled SPMD module is the
per-partition program.

Known approximations (documented for §Roofline): non-dot FLOPs ignored
(matmuls dominate every assigned cell), conditional branches both counted
(upper bound), dynamic trip counts default to 1, fusion-internal reuse
assumed perfect.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[^\s=]+)\s*=\s*(?P<type>.*?)\s"
    r"(?P<op>[a-z][\w\-]*)\((?P<rest>.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s+\(.*\)\s+->")
_CALL_RE = re.compile(r"(calls|to_apply|condition|body)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _result_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Op:
    name: str
    op: str
    type_str: str
    line: str
    operands: list


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict                    # value name -> type_str
    is_fusion_body: bool = False


def _parse_operands(rest: str) -> list[str]:
    """Names of %value operands in the top-level argument list."""
    depth = 0
    args = []
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                args.append(rest[:i])
                break
            depth -= 1
    text = args[0] if args else rest
    return re.findall(r"%([\w\.\-]+)", text)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        if " = " not in s and _COMP_RE.match(s) and s.endswith("{"):
            m = _COMP_RE.match(s)
            cur = Computation(m.group("name"), [], {})
            comps[cur.name] = cur
            # header parameters carry shapes: "(p: f32[2]{0}, q: s32[])"
            hdr = s[s.index("("):s.rindex("->")]
            for pm in re.finditer(r"([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                  hdr):
                cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        op = Op(m.group("name"), m.group("op"), m.group("type"), s,
                _parse_operands(m.group("rest")))
        cur.ops.append(op)
        cur.symbols[op.name] = op.type_str
    return comps


def _find_entry(comps: dict[str, Computation], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    if m:
        return m.group(1)
    return next(iter(comps))


def _trip_count(comps, cond_name: str) -> int:
    """Trip count of a scan-lowered while: the constant operand of the
    condition's ROOT compare (``i < N``).  Falls back to the max constant in
    the condition if the root pattern is absent."""
    cond = comps.get(cond_name)
    if cond is None or not cond.ops:
        return 1
    root = cond.ops[-1]
    # precise: a constant defined in the condition and fed to the root
    vals = []
    for name in root.operands:
        for op in cond.ops:
            if op.name == name and op.op == "constant":
                m = _CONST_RE.search(op.line)
                if m:
                    vals.append(int(m.group(1)))
    if vals:
        return max(vals)
    # fallback: max constant in the condition (+1 level of callees)
    best = 0
    seen = [cond_name] + [c for op in cond.ops
                          for _, c in _CALL_RE.findall(op.line)]
    for cname in seen:
        c = comps.get(cname)
        if c is None:
            continue
        for op in c.ops:
            for v in _CONST_RE.findall(op.line):
                best = max(best, int(v))
    return best if best > 0 else 1


def _multiplicities(comps, entry: str) -> dict[str, float]:
    """Execution count of each computation, propagated from ENTRY."""
    edges: dict[str, list[tuple[str, float, str]]] = defaultdict(list)
    for comp in comps.values():
        for op in comp.ops:
            if op.op == "while":
                kinds = dict((k, v) for k, v in _CALL_RE.findall(op.line))
                body = kinds.get("body")
                cond = kinds.get("condition")
                tc = _trip_count(comps, cond) if cond else 1
                if body:
                    edges[comp.name].append((body, float(tc), "body"))
                if cond:
                    edges[comp.name].append((cond, float(tc + 1), "cond"))
            else:
                fused = op.op == "fusion"
                for kind, callee in _CALL_RE.findall(op.line):
                    edges[comp.name].append(
                        (callee, 1.0, "fusion" if fused else "call"))
                bm = _BRANCH_RE.search(op.line)
                if bm:
                    for callee in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                        edges[comp.name].append((callee, 1.0, "branch"))

    in_edges: dict[str, list[tuple[str, float, str]]] = defaultdict(list)
    for src, outs in edges.items():
        for dst, w, kind in outs:
            in_edges[dst].append((src, w, kind))

    mult: dict[str, float] = defaultdict(float)
    fused_body: dict[str, bool] = defaultdict(bool)
    mult[entry] = 1.0
    # fixpoint over the DAG (depth-many passes suffice)
    for _ in range(len(comps) + 2):
        changed = False
        for dst, ins in in_edges.items():
            nv = 1.0 if dst == entry else 0.0
            fb = False
            for src, w, kind in ins:
                if mult[src] > 0:
                    nv += mult[src] * w
                    fb = fb or kind == "fusion" or fused_body[src]
            if abs(nv - mult[dst]) > 1e-9 or fb != fused_body[dst]:
                mult[dst], fused_body[dst] = nv, fb
                changed = True
        if not changed:
            break
    return dict(mult), dict(fused_body)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _dot_flops(op: Op, symbols: dict) -> float:
    res = _result_dims(op.type_str)
    out = 1.0
    for d in res:
        out *= d
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1.0
    if cdims and op.operands:
        lhs = symbols.get(op.operands[0])
        if lhs is not None:
            ldims = _result_dims(lhs)
            for ci in cdims.group(1).split(","):
                if ci and int(ci) < len(ldims):
                    contract *= ldims[int(ci)]
    return 2.0 * out * contract


def op_bytes(op: Op, comp: Computation, comps: dict) -> float:
    """Estimated HBM traffic of one scheduled op line."""
    base = op.op.replace("-start", "")
    rbytes = _shape_bytes(op.type_str)
    if base in ("dynamic-slice", "gather"):
        return 2 * rbytes
    if base == "dynamic-update-slice":
        upd = (comp.symbols.get(op.operands[1])
               if len(op.operands) > 1 else None)
        return 3 * _shape_bytes(upd) if upd else rbytes
    if op.op == "fusion":
        return _fusion_bytes(op, comp, comps, rbytes)
    b = rbytes
    for o in op.operands:
        t = comp.symbols.get(o)
        if t is not None:
            b += _shape_bytes(t)
    return b


def _fusion_bytes(op: Op, comp: Computation, comps: dict,
                  rbytes: float) -> float:
    """HBM traffic of one fusion op line.

    Default: operands + result (one pass).  Scan-ACCUMULATOR fusions — root
    is a dynamic-update-slice (or a tuple of them) writing one slice into a
    stacked buffer that is aliased in place — touch only the slice per
    iteration, not the whole buffer; counting the buffer would overstate a
    94-layer scan's traffic by ~L x (this was a 500x error on the rwkv cell,
    see EXPERIMENTS.md §Perf).
    """
    callee = dict(_CALL_RE.findall(op.line)).get("calls")
    fc = comps.get(callee) if callee else None
    aliased_shapes: list[str] = []
    sliced_param_bytes: dict[int, float] = {}
    slice_bytes = 0.0
    is_accum = False
    if fc and fc.ops:
        # map the fusion computation's parameter names to operand indices
        param_idx = {}
        for r in fc.ops:
            if r.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", r.line)
                if m:
                    param_idx[r.name] = int(m.group(1))
        for r in fc.ops:
            # dynamic-update-slice: writes only the update region; the big
            # buffer is aliased in place (scan accumulators, cache updates)
            if r.op == "dynamic-update-slice" and len(r.operands) > 1:
                upd = fc.symbols.get(r.operands[1])
                buf = fc.symbols.get(r.operands[0])
                if upd is None or buf is None:
                    continue
                is_accum = True
                slice_bytes += 2 * _shape_bytes(upd)
                aliased_shapes.append(buf)
            # dynamic-slice of a fusion parameter: reads only the slice (the
            # scan-xs pattern: the stacked (L, ...) input sliced per step)
            elif r.op == "dynamic-slice" and r.operands:
                k = param_idx.get(r.operands[0])
                if k is not None:
                    sliced_param_bytes[k] = (sliced_param_bytes.get(k, 0.0)
                                             + _shape_bytes(r.type_str))

    alias_bytes = sum(_shape_bytes(a) for a in aliased_shapes)
    b = slice_bytes + max(0.0, rbytes - alias_bytes) if is_accum else rbytes
    remaining_alias = list(aliased_shapes)
    for idx, o in enumerate(op.operands):
        t = comp.symbols.get(o)
        if t is None:
            continue
        if idx in sliced_param_bytes:
            b += sliced_param_bytes[idx]      # only the slices are read
            continue
        tb = _shape_bytes(t)
        if is_accum:
            matched = next((a for a in remaining_alias
                            if _shape_bytes(a) == tb), None)
            if matched is not None:
                remaining_alias.remove(matched)  # in-place buffer
                continue
        b += tb
    return b


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "iota", "after-all", "partition-id", "replica-id",
               # control-flow wrappers: their bodies' ops are counted with
               # multiplicity; counting the wrapper would double the carry
               "while", "conditional", "call", "optimization-barrier"}


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0                      # per device, loop-corrected
    bytes_accessed: float = 0.0             # per device HBM traffic estimate
    collective_bytes: float = 0.0           # per device ring-adjusted wire
    attn_score_bytes: float = 0.0           # subset of bytes_accessed that a
                                            # flash-attention kernel keeps in
                                            # VMEM (S_q x S_k score tensors)
    collective_by_type: dict = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    dot_count: int = 0
    while_trips: dict = dataclasses.field(default_factory=dict)

    def to_dict(self):
        return dataclasses.asdict(self)


def _is_score_shape(type_str: str, score_dims, floor: float) -> bool:
    dims = _result_dims(type_str)
    if len(dims) < 4 or not score_dims:
        return False
    if dims[-1] not in score_dims:
        return False
    return _shape_bytes(type_str) >= floor


def analyze(text: str, *, n_devices: int, score_dims=(),
            score_floor: float = 32e6) -> HloStats:
    """``score_dims``: candidate S_k tile sizes — ops whose results look like
    attention score tensors (>=4-D, last dim in score_dims, >= score_floor
    bytes) are tallied into ``attn_score_bytes`` so the roofline can report a
    flash-kernel-adjusted memory term alongside the raw one."""
    comps = parse_hlo(text)
    entry = _find_entry(comps, text)
    mult, fused_body = _multiplicities(comps, entry)
    stats = HloStats()

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        in_fusion = fused_body.get(comp.name, False)
        for op in comp.ops:
            base = op.op.replace("-start", "")
            if op.op.endswith("-done"):
                continue
            # ---- FLOPs (count dots everywhere, incl. fusion bodies) -------
            if base == "dot":
                stats.flops += m * _dot_flops(op, comp.symbols)
                stats.dot_count += 1
            # ---- collectives ---------------------------------------------
            if base in COLLECTIVES:
                g = _group_size(op.line, n_devices)
                rbytes = _shape_bytes(op.type_str)
                if base == "all-gather":
                    wire = (g - 1) / g * rbytes
                elif base == "reduce-scatter":
                    wire = (g - 1) * rbytes
                elif base == "all-reduce":
                    wire = 2 * (g - 1) / g * rbytes
                elif base == "all-to-all":
                    wire = (g - 1) / g * rbytes
                else:                               # collective-permute
                    wire = rbytes
                stats.collective_bytes += m * wire
                t = stats.collective_by_type.setdefault(
                    base, {"count": 0, "wire_bytes": 0.0})
                t["count"] += int(m)
                t["wire_bytes"] += m * wire
                stats.collective_count += int(m)
            # ---- HBM bytes (scheduled ops only; fusion body = in-register)
            if in_fusion or op.op in _SKIP_BYTES:
                continue
            b = m * op_bytes(op, comp, comps)
            stats.bytes_accessed += b
            if _is_score_shape(op.type_str, score_dims, score_floor):
                stats.attn_score_bytes += b

    for comp in comps.values():
        for op in comp.ops:
            if op.op == "while":
                kinds = dict(_CALL_RE.findall(op.line))
                cond = kinds.get("condition")
                if cond:
                    stats.while_trips[op.name] = _trip_count(comps, cond)
    return stats
