from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               lr_schedule, global_norm, clip_by_global_norm)
from repro.optim.compress import (int8_compress, int8_decompress,
                                  compressed_psum, CompressionState,
                                  init_compression_state)
