"""Int8 gradient compression with error feedback.

For cross-pod data-parallel all-reduce the wire format dominates: an int8
payload moves 4x less than f32 (2x less than bf16) over the slow inter-pod
links.  Per-tensor symmetric quantization ``q = round(g / s)``, s = max|g|/127,
with the quantization residual fed back into the next step's gradient
(error feedback), which is what keeps SGD convergence unaffected (Karimireddy
et al., 2019).

Usage inside a shard_map'd train step (explicit-DP mode, the paper's
"communication as a pluggable function" design)::

    g, err = compressed_psum(g, err, comm)   # comm: Comm over ("pod","data")

The all-reduce itself runs as all_gather(int8) + local dequant-sum: a true
int8 ring all-reduce needs custom accumulation; gather+sum keeps the wire
traffic int8 (the win) at the cost of n_dp partial sums in f32 locally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.comm import Comm

CompressionState = dict  # {"err": pytree of f32 residuals}


def init_compression_state(grads) -> CompressionState:
    return {"err": jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)}


def int8_compress(g, *, axis=None):
    """Symmetric int8 quantization: ``q = round(g / s)``, s = max|g|/127.

    ``axis=None`` (the gradient wire format) reduces over the whole tensor
    and returns a scalar f32 scale.  ``axis=k`` (per-channel, the KV-page
    format) reduces over that axis only: the scale has ``g``'s shape with
    axis ``k`` removed, one scale per remaining index — e.g. a
    (page, head, D) block with ``axis=-1`` gets a (page, head) scale.
    """
    gf = g.astype(jnp.float32)
    s = jnp.max(jnp.abs(gf)) if axis is None else jnp.max(
        jnp.abs(gf), axis=axis)
    s = jnp.maximum(s / 127.0, 1e-30)
    sb = s if axis is None else jnp.expand_dims(s, axis)
    q = jnp.clip(jnp.round(gf / sb), -127, 127).astype(jnp.int8)
    return q, s


def int8_decompress(q, s, *, axis=None, dtype=jnp.float32):
    """Inverse of :func:`int8_compress`; ``axis`` must match the compress
    call so the (axis-removed) scale broadcasts back into place."""
    sb = s if axis is None else jnp.expand_dims(s, axis)
    return (q.astype(jnp.float32) * sb).astype(dtype)


def compressed_psum(grads, err, comm: Comm):
    """Error-feedback int8 mean-all-reduce of a gradient pytree.

    Returns (mean_grads f32, new_err).  Wire payload: int8 + one f32 scale
    per tensor.
    """
    n = comm.size()

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = int8_compress(gf)
        new_e = gf - int8_decompress(q, s)          # residual stays local
        qs = comm.all_gather(q)                     # (n, ...) int8 on the wire
        ss = comm.all_gather(s)                     # (n,) f32
        mean = jnp.tensordot(ss, qs.astype(jnp.float32), axes=([0], [0])) / n
        return mean.astype(g.dtype), new_e

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
