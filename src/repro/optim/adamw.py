"""AdamW with warmup+cosine schedule, global-norm clipping, and configurable
moment dtypes.

The moment dtype matters at the assigned scales: arctic-480b's 469B MoE
params with f32 (m, v) cost 3.7 TB of optimizer state; bf16 moments halve the
per-device HBM bill (7.3 GB -> 3.7 GB on the 512-chip mesh) at negligible
quality cost (the update math still runs in f32).  Dense <=14B archs keep f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32    # bf16 for the >=200B MoE archs


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to ``min_lr_frac * peak``."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0., 1.)
    cos = cfg.peak_lr * (cfg.min_lr_frac
                         + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, stats).  Math in f32; storage in
    the params'/moments' own dtypes."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return (pf.astype(p.dtype), mf.astype(cfg.moment_dtype),
                vf.astype(cfg.moment_dtype))

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
