"""Result collection — the paper's ``collect_subproblem_output_args``.

In MPI the master rank loops over ``recv``; in SPMD the same effect is an
``all_gather`` (every shard ends up with the global result; the host process
then plays the paper's "master" role).  A host-side paper-faithful variant is
kept for heterogeneous (non-array) outputs produced by the host-level task
farm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def collect_subproblem_output_args(my_output, comm, *, tiled: bool = True):
    """SPMD collection: gather each leaf's leading (local-task) axis.

    ``comm`` is a :class:`repro.core.comm.Comm` (or SerialComm).  Returns the
    globally-ordered stacked outputs (rank-major order, matching the paper's
    rank-ordered recv loop).
    """
    return jax.tree_util.tree_map(lambda x: comm.all_gather(x, tiled=tiled), my_output)


def collect_host_outputs(per_rank_outputs: list[list]) -> list:
    """Paper-faithful host-side collection: concatenate rank-ordered lists."""
    out: list = []
    for chunk in per_rank_outputs:
        out.extend(chunk)
    return out


def unpad_leading(tree, n_valid: int):
    """Drop padding rows added by :func:`repro.core.partition.pad_leading`."""
    return jax.tree_util.tree_map(lambda x: x[:n_valid], tree)
