"""Generic time-integration loops — the paper's §3.2.

:func:`time_integration` is the paper's serial loop, verbatim.

:func:`parallel_time_integration` is the SPMD adaptation: the per-step body
(``do_timestep``) is a jitted SPMD program over a device mesh; the host loop
plays the role of the paper's rank-0 orchestration (timing, load-balance
trigger, ``finalize_timestep`` bookkeeping, and fault hooks).  The production
trainer (:mod:`repro.train.trainer`) is this function with
``do_timestep = train_step`` — the paper's pattern used as the spine of the
training loop.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

import jax


def time_integration(initialize: Callable, do_timestep: Callable,
                     finalize: Callable):
    """Paper-faithful serial loop (walkers = any object with __len__ and
    finalize_timestep)."""
    walkers, timesteps = initialize()
    output = []
    for _ in range(timesteps):
        old_len = len(walkers)
        output.append(do_timestep(walkers))
        walkers.finalize_timestep(old_len, len(walkers))
    return finalize(output)


def parallel_time_integration(
    initialize: Callable[[], tuple[Any, int]],
    do_timestep: Callable[[Any], tuple[Any, Any]],
    finalize: Callable[[list], Any],
    *,
    finalize_timestep: Optional[Callable[[Any, int, Any], Any]] = None,
    on_step_end: Optional[Callable[[int, Any, dict], None]] = None,
    should_stop: Optional[Callable[[int, Any], bool]] = None,
):
    """Generic host loop driving a jitted SPMD step.

    Args:
      initialize: () -> (state, timesteps).  ``state`` is a device-resident
        pytree (already sharded over the mesh).
      do_timestep: (state) -> (new_state, observables).  Typically a
        ``jax.jit`` with donated state.
      finalize: (list of host observables) -> result, run once at the end
        (paper's rank-0 finalize).
      finalize_timestep: optional (state, step, observables) -> state hook
        (paper's ``walkers.finalize_timestep``; e.g. LR/ckpt bookkeeping).
      on_step_end: optional host callback (step, observables, stats) — used by
        the trainer for checkpoints/metrics/fault handling.
      should_stop: optional early-exit predicate.

    Returns (finalize result, stats dict with per-step host timings).
    """
    state, timesteps = initialize()
    output = []
    timings = []
    for step in range(timesteps):
        t0 = time.perf_counter()
        state, obs = do_timestep(state)
        obs = jax.device_get(obs)
        dt = time.perf_counter() - t0
        timings.append(dt)
        output.append(obs)
        if finalize_timestep is not None:
            state = finalize_timestep(state, step, obs)
        if on_step_end is not None:
            on_step_end(step, obs, {"step_time": dt})
        if should_stop is not None and should_stop(step, obs):
            break
    result = finalize(output)
    return result, {"timings": timings, "state": state}
