"""The function-centric executor runtime — one scheduling subsystem for the
paper's ``(initialize, func, finalize)`` contract.

The paper's thesis is that *one* generic parallel layer can drive many serial
applications; this module is that layer for the whole repo.  The former tiers
(``solve_problem`` / ``vmap_solve_problem`` / ``parallel_solve_problem`` /
``host_task_farm``) are now thin wrappers over four :class:`Executor`
implementations:

=====================  =====================================================
Executor               Parallelism
=====================  =====================================================
:class:`SerialExecutor`      none — the paper's §2.1 loop, verbatim semantics
:class:`VmapExecutor`        single device — ``vmap`` over stacked tasks
                             (the VPU/MXU *is* the inner parallelism)
:class:`MeshExecutor`        SPMD — tasks sharded over a mesh axis, pad+mask
                             replacing the paper's ±1 rule, gather-to-master
:class:`ThreadFarmExecutor`  host threads — a genuinely concurrent master/
                             worker farm for separately-jitted programs
                             (threads release the GIL during device compute)
=====================  =====================================================

Every executor accepts the same user functions:

* ``initialize() -> tasks`` — either the paper's host form (a list of
  ``(args, kwargs)`` pairs) or the stacked form (a pytree whose leaves stack
  the per-task arguments along axis 0).
* ``func`` — maps one task to its output (``func(*args, **kwargs)`` in host
  form; ``func(task_slice)`` in stacked form).
* ``finalize(outputs)`` or ``finalize(outputs, valid_mask)`` — run once on
  the master with the collected results.  Executors that pad (the mesh tier)
  pass the valid-task mask when ``finalize`` takes two arguments, otherwise
  they trim padding first — so serial user code never sees padding.

The :class:`ThreadFarmExecutor` carries the paper's §3.2 dynamic-scheduling
machinery at host level:

* **work stealing** — tasks start on per-worker deques (the paper's ±1
  partition, order-preserving); an idle worker steals from the back of the
  longest queue.
* **timing-proportional rebalancing** — queued work is periodically
  redistributed with :func:`repro.core.load_balance.find_optimal_workload`
  and :func:`repro.core.load_balance.redistribute_plan` (the paper's
  measured-speed rebalance, workers that measured slower keep fewer items).
* **straggler re-dispatch** — with ``deadline_factor`` set, an idle worker
  re-issues any task whose elapsed time exceeds
  ``max(deadline_factor * median_runtime, min_straggler_s)``; the first
  completion wins (the classic backup-task trick; see
  :func:`repro.train.fault.redispatch_stragglers`).
"""
from __future__ import annotations

import bisect
import collections
import inspect
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from functools import partial
from typing import Any, Callable, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition as _part
from repro.core.load_balance import find_optimal_workload, redistribute_plan


# ---------------------------------------------------------------------------
# Shared contract helpers
# ---------------------------------------------------------------------------

def _finalize_arity(finalize: Callable) -> int:
    """How many positional arguments ``finalize`` accepts (1 or 2).

    Two-argument finalizers receive ``(outputs, valid_mask)`` — the documented
    padded-farm signature; one-argument finalizers get padding trimmed off.
    Only a second *required* positional counts: a defaulted second parameter
    (``np.mean``'s ``axis``, a ``verbose=False`` flag) or ``*args`` keeps the
    one-argument calling convention, so the mask can never land in an
    unrelated parameter of a pre-runtime finalizer.
    """
    try:
        sig = inspect.signature(finalize)
    except (TypeError, ValueError):
        return 1
    required = [p for p in sig.parameters.values()
                if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
                and p.default is p.empty]
    return 2 if len(required) >= 2 else 1


def _call_finalize(finalize: Callable, outputs, mask, n_valid: int):
    """Invoke ``finalize`` honoring its arity: padded outputs + mask for
    two-argument finalizers, trimmed outputs for one-argument ones."""
    if _finalize_arity(finalize) >= 2:
        return finalize(outputs, mask)
    leaves = jax.tree_util.tree_leaves(outputs)
    if leaves and leaves[0].shape[0] != n_valid:        # only the mesh pads
        outputs = jax.tree_util.tree_map(lambda x: x[:n_valid], outputs)
    return finalize(outputs)


def _normalize_tasks(tasks):
    """Materialize non-pytree iterables (generators of task pairs are valid
    input to the paper's ``for a, kw in initialize()`` loop)."""
    if (not isinstance(tasks, (list, tuple, dict))
            and not hasattr(tasks, "shape") and hasattr(tasks, "__iter__")):
        return list(tasks)
    return tasks


def _is_host_tasks(tasks) -> bool:
    """Paper host form (list of ``(args, kwargs)`` pairs) vs stacked-pytree
    form.  A tuple pytree of stacked arrays — e.g. ``(a_vals, b_vals)`` — is
    a valid stacked form, so only the exact pair shape selects the host
    path."""
    return (isinstance(tasks, (list, tuple))
            and all(isinstance(t, (tuple, list)) and len(t) == 2
                    and isinstance(t[0], (tuple, list))
                    and isinstance(t[1], dict)
                    for t in tasks))


def _n_stacked(tasks) -> int:
    return jax.tree_util.tree_leaves(tasks)[0].shape[0]


def _task_slice(tasks, i: int):
    return jax.tree_util.tree_map(lambda x: x[i], tasks)


def _stack_outputs(outputs: Sequence):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outputs)


def straggler_deadline(timings: Sequence[float], factor: float,
                       floor: float = 0.0) -> float:
    """Shared deadline rule: ``max(factor * median(timings), floor)``.

    Used by the thread farm's re-dispatch and the trainer's step watchdog so
    both tiers flag stragglers identically.
    """
    if not timings:
        return floor                 # no history yet: only the floor applies
    med = sorted(timings)[len(timings) // 2]
    return max(factor * med, floor)


# ---------------------------------------------------------------------------
# The Executor protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class Executor(Protocol):
    """Anything that can drive the paper's three user functions."""

    def run(self, initialize: Callable, func: Callable, finalize: Callable):
        ...


class SerialExecutor:
    """Paper-faithful §2.1: a Python loop over tasks, no parallelism.

    Host form keeps the paper's exact semantics
    (``output = [func(*a, **kw) for a, kw in initialize()]``); stacked form
    loops over leading-axis slices and stacks the outputs, so it is the
    bit-exact reference for the vectorized tiers.
    """

    def run(self, initialize, func, finalize):
        tasks = _normalize_tasks(initialize())
        if _is_host_tasks(tasks):
            output = [func(*args, **kwargs) for args, kwargs in tasks]
            return finalize(output)
        n = _n_stacked(tasks)
        outputs = _stack_outputs([func(_task_slice(tasks, i))
                                  for i in range(n)])
        return _call_finalize(finalize, outputs,
                              jnp.ones(n, bool), n)


class VmapExecutor:
    """Single-device tier: ``jit(vmap(func))`` over the stacked task pytree."""

    def run(self, initialize, func, finalize):
        tasks = _normalize_tasks(initialize())
        if _is_host_tasks(tasks):
            raise TypeError("VmapExecutor needs stacked-pytree tasks; use "
                            "SerialExecutor or ThreadFarmExecutor for host "
                            "(args, kwargs) task lists")
        n = _n_stacked(tasks)
        outputs = jax.jit(jax.vmap(func))(tasks)
        return _call_finalize(finalize, outputs, jnp.ones(n, bool), n)


class MeshExecutor:
    """SPMD tier: tasks sharded over ``mesh`` axis ``axis``.

    Tasks are padded to a multiple of the axis size (the paper's ±1 rule
    becomes pad+mask), sharded, evaluated with a vmapped ``func`` inside each
    shard, and gathered to the master.  Two-argument finalizers receive the
    *padded* outputs plus the valid-task mask (the documented
    ``finalize(outputs, valid_mask)`` contract); one-argument finalizers get
    the padding trimmed.
    """

    def __init__(self, mesh, *, axis: str = "data"):
        self.mesh, self.axis = mesh, axis

    def run(self, initialize, func, finalize):
        tasks = _normalize_tasks(initialize())
        if _is_host_tasks(tasks):
            raise TypeError("MeshExecutor needs stacked-pytree tasks")
        n_tasks = _n_stacked(tasks)
        n_shards = self.mesh.shape[self.axis]
        padded = _part.pad_to_multiple(n_tasks, n_shards)
        tasks, mask = _part.pad_leading(tasks, padded)
        tasks = _part.shard_tasks(tasks, self.mesh, self.axis)
        out = jax.jit(jax.vmap(func))(tasks)
        # gather to the host — the paper's collect-to-master step
        out = jax.device_get(out)
        return _call_finalize(finalize, out, np.asarray(mask), n_tasks)


# ---------------------------------------------------------------------------
# The concurrent host-level task farm
# ---------------------------------------------------------------------------

class _FarmState:
    """Shared master/worker state, guarded by one condition variable."""

    def __init__(self, n: int, num_workers: int):
        self.n = n
        self.cond = threading.Condition()
        # per-worker deques seeded with the paper's ±1 contiguous partition
        offs = _part.partition_offsets(n, num_workers)
        self.queues = [collections.deque(range(offs[w], offs[w + 1]))
                       for w in range(num_workers)]
        self.results: list = [None] * n
        self.done = [False] * n
        self.attempts = [0] * n          # attempts dispatched (0, 1, or 2)
        self.attempts_done = [0] * n     # attempts finished (incl. failures)
        self.errors: list = [None] * n
        self.started: dict[int, float] = {}     # idx -> first-attempt start
        self.completed = 0
        self.task_timings: list = [None] * n   # per task INDEX (old contract)
        self.sorted_timings: list[float] = []  # for O(1) median at the poll
        self.worker_times: list[list[float]] = [[] for _ in range(num_workers)]
        self.worker_tasks = [0] * num_workers
        self.stragglers: list[int] = []
        self.steals = 0
        self.rebalances = 0
        self._since_rebalance = 0
        self.worker_crash: BaseException | None = None
        self.failed = False              # a task settled with an error


class ThreadFarmExecutor:
    """A genuinely concurrent master/worker farm over host threads.

    Each task is typically a separately-jitted device program or an I/O-bound
    callable — both release the GIL, so a thread pool gives real overlap (the
    part of the paper's design that must stay at host level on TPU).

    Args:
      num_workers: pool size (default ``min(n_tasks, os.cpu_count())``).
      deadline_factor: enable straggler re-dispatch — an *idle* worker
        re-issues a task whose elapsed time exceeds
        ``max(deadline_factor * median_runtime, min_straggler_s)``; first
        completion wins and each task is re-issued at most once.
      rebalance: enable timing-proportional redistribution of queued work
        (paper's ``find_optimal_workload`` + ``redistribute_plan``).
      steal: enable idle workers stealing from the longest queue.
      min_straggler_s: floor under which a running task is never considered a
        straggler (guards against µs-scale medians re-issuing healthy tasks).
      poll_interval: idle-worker wait granularity in seconds.
    """

    def __init__(self, num_workers: int | None = None, *,
                 deadline_factor: float | None = None,
                 rebalance: bool = True, steal: bool = True,
                 min_straggler_s: float = 0.01,
                 poll_interval: float = 0.002):
        self.num_workers = num_workers
        self.deadline_factor = deadline_factor
        self.rebalance = rebalance
        self.steal = steal
        self.min_straggler_s = min_straggler_s
        self.poll_interval = poll_interval
        # the OS thread pool persists across map calls (admission loops call
        # the farm every tick; per-call pool teardown is pure overhead)
        self._pool: ThreadPoolExecutor | None = None
        self._pool_size = 0
        self._call_lock = threading.Lock()
        self._in_worker = threading.local()   # marks this farm's own threads

    def _get_pool(self, n_workers: int) -> ThreadPoolExecutor:
        if self._pool is None or self._pool_size < n_workers:
            if self._pool is not None:
                self._pool.shutdown(wait=False)
            self._pool = ThreadPoolExecutor(max_workers=n_workers)
            self._pool_size = n_workers
        return self._pool

    def shutdown(self):
        """Release the persistent pool.  Safe against an in-flight
        ``map_callables`` (waits for it); a later call transparently
        recreates the pool."""
        with self._call_lock:
            self._shutdown_pool_locked()

    def _shutdown_pool_locked(self):
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None
            self._pool_size = 0

    # -- the Executor contract ----------------------------------------------

    def run(self, initialize, func, finalize):
        tasks = _normalize_tasks(initialize())
        if _is_host_tasks(tasks):
            thunks = [partial(func, *args, **kwargs) for args, kwargs in tasks]
            results, _ = self.map_callables(thunks)
            return finalize(results)
        n = _n_stacked(tasks)
        thunks = [partial(func, _task_slice(tasks, i)) for i in range(n)]
        results, _ = self.map_callables(thunks)
        outputs = _stack_outputs(results)
        return _call_finalize(finalize, outputs, jnp.ones(n, bool), n)

    # -- the farm itself ------------------------------------------------------

    def map_callables(self, thunks: Sequence[Callable[[], Any]]):
        """Run independent zero-arg callables; returns (results, stats).

        Results are indexed by task position regardless of execution order
        (work stealing and re-dispatch never reorder outputs).
        """
        n = len(thunks)
        if n == 0:
            return [], {"timings": [], "stragglers": [], "steals": 0,
                        "rebalances": 0, "worker_tasks": [], "num_workers": 0}
        W = self.num_workers or (os.cpu_count() or 1)
        W = max(1, min(W, n))
        if getattr(self._in_worker, "active", False):
            # a task of THIS farm instance is calling back into the same
            # instance (e.g. a task on a long-lived engine farm): taking
            # _call_lock would deadlock against the outer run, so nest
            # serially — the paper's serial semantics, which nested fine
            # before the refactor
            return self._map_serial(thunks)
        # serialize whole-farm runs: one pool, one run at a time per instance
        with self._call_lock:
            st = _FarmState(n, W)
            pool = self._get_pool(W)
            for wid in range(W):
                pool.submit(self._safe_worker, st, wid, thunks)
            # Wait for every TASK to settle, not for every WORKER to return:
            # with straggler re-dispatch, a backup completion must unblock
            # the caller even while the original attempt is still stuck in
            # its thunk (that worker keeps its pool slot until the thunk
            # returns — the cost of backing up a truly hung task).
            with st.cond:
                while (st.completed < st.n and st.worker_crash is None
                       and not st.failed):
                    st.cond.wait()
            if st.worker_crash is not None:
                raise st.worker_crash   # a bug in the farm itself, not a task
        for err in st.errors:
            if err is not None:
                raise err
        stats = {"timings": st.task_timings, "stragglers": st.stragglers,
                 "steals": st.steals, "rebalances": st.rebalances,
                 "worker_tasks": st.worker_tasks, "num_workers": W}
        return st.results, stats

    # -- worker internals -----------------------------------------------------

    def _safe_worker(self, st: _FarmState, wid: int, thunks):
        """Worker-loop bugs must wake the master, never silently strand it."""
        self._in_worker.active = True
        try:
            self._worker(st, wid, thunks)
        except BaseException as e:                      # noqa: BLE001
            with st.cond:
                st.worker_crash = e
                st.cond.notify_all()
        finally:
            self._in_worker.active = False

    def _map_serial(self, thunks: Sequence[Callable[[], Any]]):
        """Serial fallback for nested calls: the original host_task_farm
        loop, including post-hoc straggler redo."""
        results, timings, stragglers = [], [], []
        for i, thunk in enumerate(thunks):
            t0 = time.perf_counter()
            out = thunk()
            dt = time.perf_counter() - t0
            if (self.deadline_factor is not None and timings
                    and dt > straggler_deadline(timings, self.deadline_factor,
                                                self.min_straggler_s)):
                stragglers.append(i)
                t0 = time.perf_counter()
                try:
                    redo, redo_ok = thunk(), True
                except BaseException:                   # noqa: BLE001
                    redo, redo_ok = None, False
                redo_dt = time.perf_counter() - t0
                if redo_ok and redo_dt < dt:
                    out, dt = redo, redo_dt
            results.append(out)
            timings.append(dt)
        return results, {"timings": timings, "stragglers": stragglers,
                         "steals": 0, "rebalances": 0,
                         "worker_tasks": [len(thunks)], "num_workers": 1}

    def _worker(self, st: _FarmState, wid: int, thunks):
        while True:
            with st.cond:
                idx = None
                while idx is None:
                    if (st.completed >= st.n or st.failed
                            or st.worker_crash is not None):
                        return
                    idx = self._pop_task(st, wid)
                    if idx is None:
                        # nothing queued: wait for a completion.  Only time
                        # the wait when straggler re-dispatch is on — that is
                        # the one event that arrives by clock, not by notify.
                        st.cond.wait(self.poll_interval
                                     if self.deadline_factor is not None
                                     else None)
            t0 = time.perf_counter()
            try:
                out, err = thunks[idx](), None
            except BaseException as e:                  # noqa: BLE001
                # BaseException too: a task calling sys.exit() must settle
                # the task (error re-raised at the join), not kill the
                # worker loop and deadlock the farm
                out, err = None, e
            dt = time.perf_counter() - t0
            with st.cond:
                st.attempts_done[idx] += 1
                # single-worker farm: no idle peer can ever back up a
                # straggler, so keep the serial semantics — re-run a task
                # that breached the deadline BEFORE settling it, so the
                # master cannot return while the redo still mutates state
                inline_redo = (
                    err is None
                    and not st.done[idx]
                    and self.deadline_factor is not None
                    and len(st.queues) == 1
                    and st.attempts[idx] == 1
                    and len(st.sorted_timings) > 0
                    and dt > straggler_deadline(
                        st.sorted_timings, self.deadline_factor,
                        self.min_straggler_s))
                if inline_redo:
                    st.attempts[idx] = 2
                    st.stragglers.append(idx)
            if inline_redo:
                t0 = time.perf_counter()
                try:
                    out2, redo_ok = thunks[idx](), True
                except BaseException:                   # noqa: BLE001
                    out2, redo_ok = None, False         # keep the original
                dt2 = time.perf_counter() - t0
                if redo_ok and dt2 < dt:
                    out, dt = out2, dt2                 # faster attempt wins
            with st.cond:
                if inline_redo:
                    st.attempts_done[idx] += 1
                # An errored attempt only settles the task once no other
                # attempt is in flight — a fast-failing backup must not
                # discard an original that is still about to succeed.
                settles = not st.done[idx] and (
                    err is None
                    or st.attempts_done[idx] >= st.attempts[idx])
                if settles:                             # first success wins
                    st.done[idx] = True
                    st.started.pop(idx, None)   # keep the straggler scan
                    st.results[idx] = out       # proportional to in-flight
                    st.errors[idx] = err
                    st.completed += 1
                    st.task_timings[idx] = dt
                    bisect.insort(st.sorted_timings, dt)
                    st.worker_times[wid].append(dt)
                    st.worker_tasks[wid] += 1
                    st._since_rebalance += 1
                    if err is not None:
                        # fail fast: stop starting queued tasks (the serial
                        # farm propagated the first error immediately)
                        st.failed = True
                        for q in st.queues:
                            q.clear()
                    self._maybe_rebalance(st)
                st.cond.notify_all()

    def _pop_task(self, st: _FarmState, wid: int):
        """Own queue -> steal from longest queue -> straggler re-dispatch."""
        now = time.perf_counter()
        q = st.queues[wid]
        if q:
            idx = q.popleft()
        else:
            idx = None
            if self.steal:
                victim = max(range(len(st.queues)),
                             key=lambda w: len(st.queues[w]))
                if st.queues[victim]:
                    idx = st.queues[victim].pop()
                    st.steals += 1
            if idx is None:
                return self._pop_straggler(st, now)
        st.attempts[idx] = 1
        st.started[idx] = now
        return idx

    def _pop_straggler(self, st: _FarmState, now: float):
        if self.deadline_factor is None or not st.sorted_timings:
            return None
        # sorted list maintained at settle time -> O(1) median per poll
        med = st.sorted_timings[len(st.sorted_timings) // 2]
        deadline = max(self.deadline_factor * med, self.min_straggler_s)
        for idx, t0 in st.started.items():
            if (not st.done[idx] and st.attempts[idx] == 1
                    and now - t0 > deadline):
                st.attempts[idx] = 2                    # re-issue at most once
                st.stragglers.append(idx)
                return idx
        return None

    def _maybe_rebalance(self, st: _FarmState):
        """Paper §3.2: redistribute queued work in proportion to measured
        per-worker speed.  Runs under the lock, at most once per W
        completions, once every worker has a timing sample."""
        W = len(st.queues)
        if (not self.rebalance or W < 2 or st._since_rebalance < W
                or any(not t for t in st.worker_times)):
            return
        queued = [len(q) for q in st.queues]
        if sum(queued) < 2:
            return
        st._since_rebalance = 0
        means = [max(sum(t) / len(t), 1e-9) for t in st.worker_times]
        targets = find_optimal_workload(means, queued)
        plan = redistribute_plan(queued, targets)
        for src, dst, k in plan:
            for _ in range(k):
                if st.queues[src]:
                    st.queues[dst].append(st.queues[src].pop())
        if plan:
            st.rebalances += 1


# ---------------------------------------------------------------------------
# Stage coordination
# ---------------------------------------------------------------------------

def run_stages(executor: Executor, stages: Sequence[Callable[[], Any]]) -> bool:
    """One coordination step of a multi-role pipeline, driven through the
    generic ``(initialize, func, finalize)`` contract: each stage is a
    zero-arg callable returning a truthy busy flag; the triple is built on
    the fly — ``initialize`` yields one host-form task per stage, ``func``
    runs it, ``finalize`` ORs the busy flags.

    The point of routing through the protocol instead of a plain loop is
    that the SAME stage functions run serially (:class:`SerialExecutor`:
    deterministic order, stage i completes before i+1 starts — the
    bit-reproducible mode tests pin) or genuinely overlapped
    (:class:`ThreadFarmExecutor`: stages that release the GIL inside jitted
    device calls run concurrently).  Stacked-form executors (vmap/mesh)
    cannot run host callables and reject host-form tasks themselves.

    Used by :class:`repro.serve.disagg.DisaggServeEngine` to coordinate its
    prefiller and decoder roles; returns True if any stage reported work.
    """
    return executor.run(
        lambda: [((stage,), {}) for stage in stages],
        lambda stage: bool(stage()),
        lambda outs: any(outs))


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

_HOST_EXECUTORS = {
    "serial": SerialExecutor,
    "thread": ThreadFarmExecutor,
}


def make_executor(spec: str | Executor = "vmap", *, mesh=None,
                  axis: str = "data", **kwargs) -> Executor:
    """Executor from a spec string: ``serial`` | ``vmap`` | ``mesh`` |
    ``thread``.  Passing an existing :class:`Executor` returns it unchanged;
    ``mesh`` requires the ``mesh=`` argument.
    """
    if not isinstance(spec, str):
        if kwargs or mesh is not None:
            opts = (["mesh"] if mesh is not None else []) + sorted(kwargs)
            raise ValueError(
                "make_executor received an Executor instance together with "
                f"constructor options {opts} — options only apply to spec "
                "strings; configure the instance directly instead")
        return spec
    if spec in _HOST_EXECUTORS:
        return _HOST_EXECUTORS[spec](**kwargs)
    if spec == "vmap":
        return VmapExecutor(**kwargs)
    if spec == "mesh":
        if mesh is None:
            raise ValueError("make_executor('mesh') requires mesh=")
        return MeshExecutor(mesh, axis=axis, **kwargs)
    raise ValueError(f"unknown executor spec {spec!r}; expected one of "
                     "'serial', 'vmap', 'mesh', 'thread'")
