"""Pluggable communication object — the JAX analogue of the paper's
``send``/``recv``/``all_gather`` *function arguments*.

The paper passes MPI primitives INTO its generic functions so the transport is
swappable (pypar vs mpi4py vs ...).  Inside a single JAX SPMD program the
transport is a set of named-axis collectives; we preserve the paper's design by
bundling axis-bound collective closures into a :class:`Comm` value that generic
functions take as an argument.  A :class:`SerialComm` implements the same
interface for single-process execution, so user code is transport-agnostic,
exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp


def _axis_size(axis) -> int:
    """Version-compat static axis size: ``jax.lax.axis_size`` where it
    exists, else the classic ``psum(1, axis)`` idiom."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis))
    return int(jax.lax.psum(1, axis))


@dataclasses.dataclass(frozen=True)
class Comm:
    """Axis-bound collectives, usable inside ``shard_map``/``pmap`` bodies.

    ``axis`` may be a single axis name or a tuple of names (collectives then
    operate over the product of those mesh axes).
    """

    axis: Any  # str | tuple[str, ...]

    # -- topology ----------------------------------------------------------
    def rank(self) -> jax.Array:
        """Paper's ``my_rank``."""
        return jax.lax.axis_index(self.axis)

    def size(self) -> int:
        """Paper's ``num_procs`` (static)."""
        if isinstance(self.axis, (tuple, list)):
            import math
            return int(math.prod(_axis_size(a) for a in self.axis))
        return int(_axis_size(self.axis))

    # -- collectives --------------------------------------------------------
    def all_gather(self, x, *, axis: int = 0, tiled: bool = False):
        """``axis`` selects where shards land (tiled: concat dim; untiled:
        the inserted stack dim) — e.g. ``axis=-1, tiled=True`` reassembles a
        vocab-sharded logits row, the serving TP head's single gather."""
        return jax.lax.all_gather(x, self.axis, axis=axis, tiled=tiled)

    def all_reduce_sum(self, x):
        return jax.lax.psum(x, self.axis)

    def all_reduce_max(self, x):
        return jax.lax.pmax(x, self.axis)

    def all_reduce_min(self, x):
        return jax.lax.pmin(x, self.axis)

    def all_to_all(self, x, *, split_axis: int, concat_axis: int, tiled: bool = True):
        """Paper's ``redistribute_work`` exchange as one collective: rank r
        keeps chunk r of ``split_axis`` and receives everyone else's,
        stacked along ``concat_axis``.  This is the MoE expert-parallel
        dispatch/combine primitive (``moe_apply_expert_parallel``): the
        (E, C, d) capacity buffer splits over experts going out and over
        source ranks coming back.  SerialComm's twin is the identity, so
        the same block runs unchanged on one device."""
        return jax.lax.all_to_all(x, self.axis, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=tiled)

    def shift(self, x, offset: int = 1):
        """Ring point-to-point: every rank sends to ``rank+offset`` (mod n).

        This is the SPMD replacement for the paper's ``send``/``recv`` pair —
        point-to-point transfers must be expressed as a permutation so the
        compiler can schedule them on the ICI torus.
        """
        n = self.size()
        perm = [(i, (i + offset) % n) for i in range(n)]
        return jax.lax.ppermute(x, self.axis, perm)

    def permute(self, x, perm: Sequence[tuple[int, int]]):
        return jax.lax.ppermute(x, self.axis, perm)

    def broadcast_from(self, x, root: int = 0):
        """Paper's ``pypar.broadcast``: value from ``root`` to all ranks."""
        picked = jnp.where(self.rank() == root, x, jnp.zeros_like(x))
        return jax.lax.psum(picked, self.axis)

    def pvary(self, x):
        """Mark a replicated value as device-varying (vma bookkeeping)."""
        try:
            return jax.lax.pvary(x, self.axis)
        except Exception:  # older jax / outside manual context
            return x


class SerialComm:
    """Single-process Comm with identical interface (paper's serial path)."""

    axis = None

    def rank(self):
        return jnp.asarray(0)

    def size(self):
        return 1

    def all_gather(self, x, *, axis: int = 0, tiled: bool = False):
        return x if tiled else jnp.expand_dims(x, axis)

    def all_reduce_sum(self, x):
        return x

    def all_reduce_max(self, x):
        return x

    def all_reduce_min(self, x):
        return x

    def all_to_all(self, x, *, split_axis: int, concat_axis: int, tiled: bool = True):
        return x

    def shift(self, x, offset: int = 1):
        return x

    def permute(self, x, perm):
        return x

    def broadcast_from(self, x, root: int = 0):
        return x

    def pvary(self, x):
        return x


def make_comm(axis) -> Comm | SerialComm:
    """Factory: ``axis=None`` gives the serial transport."""
    return SerialComm() if axis is None else Comm(axis)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-compat ``shard_map``: newer jax exposes ``jax.shard_map``
    (with ``check_vma``); older releases only have
    ``jax.experimental.shard_map.shard_map`` (with ``check_rep``).

    The default matches jax's own (validation on); the repo's production
    call sites pass ``check_vma=False`` explicitly, as they always have."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
