"""The paper's §2 entry points, kept as thin wrappers over the executor
runtime (:mod:`repro.core.runtime`).

Historically this module carried four divergent implementations of the
``(initialize, func, finalize)`` pattern; they are now one subsystem:

1. :func:`solve_problem` — :class:`~repro.core.runtime.SerialExecutor`
   (paper-faithful serial semantics, any Python callables).
2. :func:`vmap_solve_problem` — :class:`~repro.core.runtime.VmapExecutor`
   (single-device JAX; the VPU/MXU *is* the inner parallelism).
3. :func:`parallel_solve_problem` — :class:`~repro.core.runtime.MeshExecutor`
   (multi-device SPMD over a mesh axis; pad+mask replaces the paper's ±1
   rule, and two-argument finalizers receive the documented
   ``finalize(outputs, valid_mask)`` signature).
4. :func:`host_task_farm` — :class:`~repro.core.runtime.ThreadFarmExecutor`
   (genuinely concurrent master/worker farm for arbitrary host callables,
   with work stealing, timing-proportional rebalancing, and deadline-based
   straggler re-dispatch).

New code should select an executor directly; these wrappers exist for the
paper-faithful spelling and backward compatibility.
"""
from __future__ import annotations

from typing import Callable, Sequence

from repro.core.runtime import (MeshExecutor, SerialExecutor,
                                ThreadFarmExecutor, VmapExecutor)


def solve_problem(initialize: Callable, func: Callable, finalize: Callable):
    """``output = [func(*a, **kw) for a, kw in initialize()]; finalize(output)``."""
    return SerialExecutor().run(initialize, func, finalize)


def vmap_solve_problem(initialize: Callable, func: Callable, finalize: Callable):
    """``initialize()`` returns a pytree whose leaves stack the per-task args
    along axis 0; ``func`` maps one task's pytree slice to outputs."""
    return VmapExecutor().run(initialize, func, finalize)


def parallel_solve_problem(initialize: Callable, func: Callable,
                           finalize: Callable, mesh, *, axis: str = "data"):
    """Task farm over mesh axis ``axis`` (the paper's
    ``parallel_solve_problem``); see :class:`repro.core.runtime.MeshExecutor`."""
    return MeshExecutor(mesh, axis=axis).run(initialize, func, finalize)


def host_task_farm(tasks: Sequence[Callable[[], object]], *,
                   num_workers: int | None = None,
                   deadline_factor: float | None = None):
    """Run independent zero-arg callables on the concurrent thread farm.

    Kept for backward compatibility; returns (results list, stats dict) with
    the historical ``timings`` / ``stragglers`` keys plus the farm's
    ``steals`` / ``rebalances`` / ``worker_tasks`` counters.

    Each call gets its own farm (released on return), so concurrent callers
    stay fully independent, as they were with the serial implementation.
    Hot loops that farm work every tick should hold a
    :class:`~repro.core.runtime.ThreadFarmExecutor` instead and reuse its
    persistent pool (the serve engine does exactly that).
    """
    farm = ThreadFarmExecutor(num_workers=num_workers,
                              deadline_factor=deadline_factor)
    try:
        return farm.map_callables(list(tasks))
    finally:
        farm.shutdown()
