"""The paper's §2: ``solve_problem`` / ``parallel_solve_problem``.

Three tiers, all sharing the (initialize, func, finalize) contract:

1. :func:`solve_problem` — paper-faithful serial version (any Python callables).
2. :func:`vmap_solve_problem` — single-device JAX: tasks as stacked pytrees,
   ``func`` vectorized with ``vmap`` (the TPU replacement for the paper's
   list-comprehension loop; the VPU/MXU *is* the inner parallelism).
3. :func:`parallel_solve_problem` — multi-device SPMD: tasks sharded over a
   mesh axis (paper's ``get_subproblem_input_args``), ``func`` vmapped within
   each shard, results collected with ``all_gather`` (paper's
   ``collect_subproblem_output_args``), ``finalize`` run host-side (paper's
   "only on master" step).

A host-level heterogeneous task farm (:func:`host_task_farm`) covers the
paper's original use-case of wrapping *arbitrary* serial code (here: separately
jitted programs of different shapes), with timing-based dynamic scheduling —
the part of the paper's design that must stay at the host level on TPU.
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import partition as _part
from repro.core.comm import Comm


# ---------------------------------------------------------------------------
# 1. Paper-faithful serial version (verbatim semantics from §2.1)
# ---------------------------------------------------------------------------

def solve_problem(initialize: Callable, func: Callable, finalize: Callable):
    """``output = [func(*a, **kw) for a, kw in initialize()]; finalize(output)``."""
    input_args = initialize()
    output = [func(*args, **kwargs) for args, kwargs in input_args]
    return finalize(output)


# ---------------------------------------------------------------------------
# 2. Single-device JAX version
# ---------------------------------------------------------------------------

def vmap_solve_problem(initialize: Callable, func: Callable, finalize: Callable):
    """``initialize()`` returns a pytree whose leaves stack the per-task args
    along axis 0; ``func`` maps one task's pytree slice to outputs."""
    tasks = initialize()
    output = jax.jit(jax.vmap(func))(tasks)
    return finalize(output)


# ---------------------------------------------------------------------------
# 3. SPMD version
# ---------------------------------------------------------------------------

def parallel_solve_problem(initialize: Callable, func: Callable, finalize: Callable,
                           mesh, *, axis: str = "data", donate: bool = False):
    """Task farm over mesh axis ``axis``.

    ``initialize()`` → stacked task pytree (leading axis = #tasks).  Tasks are
    padded to a multiple of the axis size (paper's ±1 rule becomes pad+mask),
    sharded, evaluated with a vmapped ``func`` inside the shard, and gathered.
    ``finalize(outputs, valid_mask)`` runs on host with the full result.
    """
    tasks = initialize()
    n_tasks = jax.tree_util.tree_leaves(tasks)[0].shape[0]
    n_shards = mesh.shape[axis]
    padded = _part.pad_to_multiple(n_tasks, n_shards)
    tasks, mask = _part.pad_leading(tasks, padded)
    tasks = _part.shard_tasks(tasks, mesh, axis)

    vfunc = jax.vmap(func)

    out_sharding = NamedSharding(mesh, P())

    @jax.jit
    def run(tasks):
        out = vfunc(tasks)
        # Keep results sharded until the host needs them; the gather to the
        # host below is the paper's collect-to-master step.
        return out

    out = run(tasks)
    out = jax.device_get(out)
    out = jax.tree_util.tree_map(lambda x: x[:n_tasks], out)
    return finalize(out)


# ---------------------------------------------------------------------------
# Host-level heterogeneous task farm (paper's original scope: arbitrary
# serial programs), with the paper's timing-driven dynamic scheduling.
# ---------------------------------------------------------------------------

def host_task_farm(tasks: Sequence[Callable[[], object]], *,
                   num_workers: int | None = None,
                   deadline_factor: float | None = None):
    """Run independent zero-arg callables with greedy dynamic dispatch.

    This models the paper's master/worker farm at the host level (each task is
    typically a separately-jitted program).  ``deadline_factor`` enables the
    straggler mitigation used by the production trainer: a task whose runtime
    exceeds ``deadline_factor`` x (median runtime so far) is recorded as a
    straggler and re-dispatched once (results of the first completion win).

    Returns (results list, stats dict).
    """
    results: list = [None] * len(tasks)
    timings: list[float] = []
    stragglers: list[int] = []
    for i, task in enumerate(tasks):
        t0 = time.perf_counter()
        results[i] = task()
        dt = time.perf_counter() - t0
        if deadline_factor is not None and timings:
            med = sorted(timings)[len(timings) // 2]
            if dt > deadline_factor * med:
                stragglers.append(i)
                # re-dispatch once (first completion wins; on a real cluster
                # this would go to a hot spare — see train/fault.py)
                t0 = time.perf_counter()
                redo = task()
                redo_dt = time.perf_counter() - t0
                if redo_dt < dt:
                    results[i], dt = redo, redo_dt
        timings.append(dt)
    return results, {"timings": timings, "stragglers": stragglers}
