"""Dynamic load balancing — the paper's §3.2 machinery, TPU-adapted.

Two regimes:

* **Host level (paper-faithful)**: :func:`find_optimal_workload` implements the
  paper's timing-proportional redistribution (workers that measured slower get
  fewer items), and :func:`redistribute_plan` computes the paper's iterative
  max→min transfer schedule.  Used by the heterogeneous task farm and the
  serving batcher.

* **SPMD level (TPU-native)**: populations live in fixed-capacity, compacted
  arrays (`data[:count]` are live).  :func:`redistribute_work` equalizes counts
  across a mesh axis with a deterministic all-gather + global re-slice — the
  static-shape replacement for the paper's pickled ``cut_slice``/``paste_slice``
  messages.  :func:`dynamic_load_balancing` wraps it with the paper's
  threshold test.

The same capacity/target math drives the MoE router (experts = processors,
tokens = walkers): see :mod:`repro.models.moe`.
"""
from __future__ import annotations

from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.comm import Comm


# ---------------------------------------------------------------------------
# Paper-faithful host-side functions
# ---------------------------------------------------------------------------

def find_optimal_workload(timing_list, current_work_per_proc):
    """Verbatim port of the paper's implementation (numpy).

    ``C = total_work / sum(1/t_i)``; rank i gets ``C / t_i`` items, remainders
    distributed greedily by largest fractional part.
    """
    timing_list = np.asarray(timing_list, dtype=np.float64)
    current_work_per_proc = np.asarray(current_work_per_proc, dtype=np.int64)
    total_work = int(current_work_per_proc.sum())
    C = total_work / np.sum(1.0 / timing_list)
    tmp = C / timing_list
    rebalanced = tmp.astype(np.int64)
    remainders = tmp - rebalanced
    while rebalanced.sum() < total_work:
        k = int(np.argmax(remainders))
        rebalanced[k] += 1
        remainders[k] = 0
    return rebalanced


def redistribute_plan(work_per_proc, rebalanced_work):
    """Paper's transfer schedule: repeatedly move surplus from the most
    overloaded rank to the most underloaded.  Returns [(src, dst, n), ...]."""
    diff = np.asarray(work_per_proc, np.int64) - np.asarray(rebalanced_work, np.int64)
    plan: list[tuple[int, int, int]] = []
    while diff.any():
        src = int(np.argmax(diff))
        dst = int(np.argmin(diff))
        n = int(min(diff[src], -diff[dst]))
        if n <= 0:
            break
        plan.append((src, dst, n))
        diff[src] -= n
        diff[dst] += n
    return plan


# ---------------------------------------------------------------------------
# SPMD count-based rebalancing
# ---------------------------------------------------------------------------

def balanced_counts(total, n):
    """Target per-shard counts (±1 rule), as a jnp array of shape (n,)."""
    base = total // n
    extra = total - base * n
    return base + (jnp.arange(n) < extra).astype(base.dtype)


def redistribute_work(local_data, local_count, comm: Comm,
                      target_counts=None):
    """Equalize a compacted fixed-capacity population across ``comm.axis``.

    Args:
      local_data: pytree; every leaf has shape (capacity, ...) and live items
        occupy slots [0, local_count).
      local_count: int32 scalar of live items on this shard.
      comm: :class:`Comm` bound to the population axis.
      target_counts: optional (n,) target; defaults to balanced ±1 split.

    Returns (new_local_data, new_local_count).  Deterministic: the global
    rank-major order of live items is preserved (matches the paper's
    rank-ordered cut/paste semantics).
    """
    n = comm.size()
    rank = comm.rank()
    count_shape = jnp.shape(local_count)
    local_count = jnp.asarray(local_count, jnp.int32).reshape(())
    counts = comm.all_gather(local_count)  # (n,)
    counts = counts.reshape(n)
    total = counts.sum()
    if target_counts is None:
        target_counts = balanced_counts(total, n).astype(jnp.int32)
    src_offsets = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    dst_offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                   jnp.cumsum(target_counts)[:-1]])

    my_target = target_counts[rank]
    my_dst_off = dst_offsets[rank]

    def reslice(leaf):
        cap = leaf.shape[0]
        gathered = comm.all_gather(leaf)           # (n, cap, ...)
        # global index of my new slot s is my_dst_off + s; its source shard r
        # satisfies src_offsets[r] <= g < src_offsets[r] + counts[r].
        s = jnp.arange(cap, dtype=jnp.int32)
        g = my_dst_off + s
        r = jnp.clip(jnp.searchsorted(src_offsets, g, side="right") - 1, 0, n - 1)
        j = g - src_offsets[r]
        valid = s < my_target
        j = jnp.where(valid, jnp.clip(j, 0, cap - 1), 0)
        out = gathered[r, j]
        # zero out dead slots so padding stays inert
        mask_shape = (cap,) + (1,) * (out.ndim - 1)
        return jnp.where(valid.reshape(mask_shape), out, jnp.zeros_like(out))

    new_data = jax.tree_util.tree_map(reslice, local_data)
    return new_data, my_target.astype(jnp.int32).reshape(count_shape)


def dynamic_load_balancing(local_data, local_count, comm: Comm,
                           threshold_factor: float = 1.1):
    """Paper's ``dynamic_load_balancing``: rebalance only when
    ``max_count > threshold_factor * min_count`` (count-driven on TPU;
    wall-clock balancing stays at the host level — see
    :class:`repro.core.runtime.ThreadFarmExecutor`).

    Returns (data, count, counts_per_shard, did_rebalance).
    """
    n = comm.size()
    count_shape = jnp.shape(local_count)
    counts = comm.all_gather(
        jnp.asarray(local_count, jnp.int32).reshape(())).reshape(n)
    cmax = counts.max()
    cmin = counts.min()
    need = cmax.astype(jnp.float32) > threshold_factor * jnp.maximum(
        cmin.astype(jnp.float32), 1.0)

    def _do(_):
        return redistribute_work(local_data, local_count, comm)

    def _skip(_):
        return local_data, jnp.asarray(local_count, jnp.int32).reshape(count_shape)

    data, count = jax.lax.cond(need, _do, _skip, operand=None)
    new_counts = comm.all_gather(
        jnp.asarray(count).reshape(())).reshape(n)
    return data, count, new_counts, need
