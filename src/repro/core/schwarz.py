"""Additive Schwarz iterations — the paper's §3.3, TPU-adapted.

The paper's ``additive_Schwarz_iterations(subdomain_solve, communicate,
set_BC, max_iter, threshold, solution, convergence_test)`` signature is kept
intact; the pieces map as:

* ``subdomain_solve`` — user function: local solve on this shard's subdomain
  (wraps "the existing serial code"; here a jnp stencil/solver kernel).
* ``communicate`` — generic: halo exchange via ``ppermute`` shifts
  (:func:`halo_exchange`) instead of neighbour send/recv.
* ``convergence_test`` — generic: local relative change + ``pmax`` all-reduce
  (paper's ``all_reduce(..., MAX)``).
* the `while not_converged` loop becomes ``jax.lax.while_loop`` so the whole
  iteration compiles into ONE SPMD program (collectives scheduled by XLA, no
  per-iteration host round-trip — the TPU-native improvement over the paper's
  host-driven loop).

The same neighbour-exchange pattern is reused for ring attention / KV halos
(:mod:`repro.mesh.ring`) and pipeline stage transfer (:mod:`repro.mesh.pipeline`).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.comm import Comm


def halo_exchange(field, comm: Comm, halo: int, *, axis: int = 0,
                  periodic: bool = False):
    """Exchange ``halo``-wide boundary slabs with ring neighbours.

    ``field``: local interior block, decomposed along ``axis`` over
    ``comm.axis``.  Returns ``(left_ghost, right_ghost)`` — the neighbouring
    shards' adjacent slabs (zeros at non-periodic ends, which the caller's
    ``set_BC`` overwrites with physical boundary values).
    """
    n = comm.size()

    def take(x, lo, hi):
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(lo, hi)
        return x[tuple(idx)]

    my_left = take(field, 0, halo)            # my first rows -> left neighbour's right ghost
    my_right = take(field, field.shape[axis] - halo, field.shape[axis])

    if periodic:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [((i + 1) % n, i) for i in range(n)]
    else:
        fwd = [(i, i + 1) for i in range(n - 1)]
        bwd = [(i + 1, i) for i in range(n - 1)]

    left_ghost = comm.permute(my_right, fwd)   # from rank-1: its last rows
    right_ghost = comm.permute(my_left, bwd)   # from rank+1: its first rows
    return left_ghost, right_ghost


def simple_convergence_test(solution, solution_prev, comm: Comm,
                            threshold: float = 1e-3):
    """Paper-faithful: max_s ||u_s,k - u_s,k-1||^2 / ||u_s,k||^2 < threshold."""
    diff = solution - solution_prev
    num = jnp.vdot(diff, diff).real
    den = jnp.maximum(jnp.vdot(solution, solution).real, 1e-30)
    glob = comm.all_reduce_max(num / den)
    return glob < threshold


def additive_schwarz_iterations(
    subdomain_solve: Callable,
    communicate: Callable,
    set_bc: Callable,
    max_iter: int,
    threshold: float,
    solution,
    comm: Comm,
    convergence_test: Optional[Callable] = None,
):
    """Run additive Schwarz to convergence inside one compiled while_loop.

    ``subdomain_solve(solution) -> solution`` performs the local solve given
    ghost values already present; ``communicate(solution) -> solution``
    refreshes ghosts from neighbours; ``set_bc`` applies physical BCs.

    Returns (solution, iterations_used, converged_flag).
    """
    if convergence_test is None:
        convergence_test = functools.partial(simple_convergence_test,
                                             threshold=threshold)

    def cond(carry):
        _, _, it, not_conv = carry
        return jnp.logical_and(not_conv, it < max_iter)

    def body(carry):
        sol, _, it, _ = carry
        prev = sol
        sol = communicate(sol)
        sol = set_bc(sol)
        sol = subdomain_solve(sol)
        converged = convergence_test(sol, prev, comm)
        return sol, prev, it + 1, jnp.logical_not(converged)

    sol, _, iters, not_conv = jax.lax.while_loop(
        cond, body, (solution, solution, jnp.asarray(0, jnp.int32),
                     jnp.asarray(True)))
    return sol, iters, jnp.logical_not(not_conv)
