"""Core function-centric parallelization layer (the paper's contribution).

The generic machinery mirrors the paper's functions one-to-one:

=======================================  =========================================
Paper (Python + MPI)                     This package (JAX SPMD)
=======================================  =========================================
``solve_problem``                        :func:`repro.core.functional.solve_problem`
``parallel_solve_problem``               :func:`repro.core.functional.parallel_solve_problem`
``simple_partitioning``                  :func:`repro.core.partition.simple_partitioning`
``get_subproblem_input_args``            :func:`repro.core.partition.get_subproblem_input_args`
``collect_subproblem_output_args``       :func:`repro.core.collect.collect_subproblem_output_args`
``time_integration``                     :func:`repro.core.time_integration.time_integration`
``parallel_time_integration``            :func:`repro.core.time_integration.parallel_time_integration`
``dynamic_load_balancing``               :func:`repro.core.load_balance.dynamic_load_balancing`
``find_optimal_workload``                :func:`repro.core.load_balance.find_optimal_workload`
``redistribute_work``                    :func:`repro.core.load_balance.redistribute_work`
``additive_Schwarz_iterations``          :func:`repro.core.schwarz.additive_schwarz_iterations`
``simple_convergence_test``              :func:`repro.core.schwarz.simple_convergence_test`
send/recv/all_gather function arguments  :class:`repro.core.comm.Comm`
=======================================  =========================================

All four tiers are implementations of one :class:`repro.core.runtime.Executor`
protocol — ``SerialExecutor`` / ``VmapExecutor`` / ``MeshExecutor`` /
``ThreadFarmExecutor`` — sharing the paper's ``(initialize, func, finalize)``
contract; the functions above are thin wrappers kept for the paper-faithful
spelling.
"""
from repro.core.comm import Comm
from repro.core.functional import (solve_problem, parallel_solve_problem,
                                   vmap_solve_problem, host_task_farm)
from repro.core.runtime import (Executor, MeshExecutor, SerialExecutor,
                                ThreadFarmExecutor, VmapExecutor,
                                make_executor, straggler_deadline)
from repro.core.partition import simple_partitioning, get_subproblem_input_args, pad_to_multiple
from repro.core.collect import collect_subproblem_output_args
from repro.core.time_integration import time_integration, parallel_time_integration
from repro.core.load_balance import (
    find_optimal_workload, redistribute_work, dynamic_load_balancing, balanced_counts)
from repro.core.schwarz import additive_schwarz_iterations, simple_convergence_test, halo_exchange

__all__ = [
    "Comm", "solve_problem", "parallel_solve_problem", "vmap_solve_problem",
    "host_task_farm", "Executor", "SerialExecutor", "VmapExecutor", "MeshExecutor",
    "ThreadFarmExecutor", "make_executor", "straggler_deadline",
    "simple_partitioning", "get_subproblem_input_args", "pad_to_multiple",
    "collect_subproblem_output_args", "time_integration", "parallel_time_integration",
    "find_optimal_workload", "redistribute_work", "dynamic_load_balancing",
    "balanced_counts", "additive_schwarz_iterations", "simple_convergence_test",
    "halo_exchange",
]
