"""Work partitioning — the paper's ``simple_partitioning`` and
``get_subproblem_input_args`` adapted to static SPMD sharding.

The ±1 balancing rule is kept verbatim from the paper: ``length`` items over
``num_procs`` parts gives ``length // num_procs`` each, with the first
``length % num_procs`` parts getting one extra.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def simple_partitioning(length: int, num_procs: int) -> np.ndarray:
    """Paper-faithful: balanced part sizes (numpy int array of len num_procs)."""
    sublengths = np.full(num_procs, length // num_procs, dtype=np.int64)
    sublengths[: length % num_procs] += 1
    return sublengths


def partition_offsets(length: int, num_procs: int) -> np.ndarray:
    """Start offset of each part (len num_procs + 1)."""
    sizes = simple_partitioning(length, num_procs)
    return np.concatenate([[0], np.cumsum(sizes)])


def get_subproblem_input_args(input_args: list, my_rank: int, num_procs: int) -> list:
    """Paper-faithful host-side task-list split (works on any Python list)."""
    offs = partition_offsets(len(input_args), num_procs)
    return input_args[offs[my_rank]: offs[my_rank + 1]]


def pad_to_multiple(n: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``n``."""
    return ((n + m - 1) // m) * m


def pad_leading(tree, target: int, fill=0):
    """Pad every leaf's leading axis to ``target`` rows; returns (tree, valid mask)."""
    n = jax.tree_util.tree_leaves(tree)[0].shape[0]
    pad = target - n
    if pad < 0:
        raise ValueError(f"cannot pad {n} down to {target}")

    def _pad(x):
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    mask = jnp.arange(target) < n
    return jax.tree_util.tree_map(_pad, tree), mask


def batch_sharding(mesh, *, batch_axes=("data",), rest_ndim: int = 1) -> NamedSharding:
    """NamedSharding for a [batch, ...] array: batch over ``batch_axes``."""
    spec = P(batch_axes, *([None] * rest_ndim))
    return NamedSharding(mesh, spec)


def shard_tasks(tree, mesh, axis="data"):
    """Shard a stacked task pytree's leading axis over ``axis`` (device_put)."""
    def _shard(x):
        spec = P(axis, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(_shard, tree)
