"""Trainer — the paper's ``parallel_time_integration`` used as the spine of a
production training loop.

Mapping:

    initialize         -> build/restore TrainState + data iterator
    do_timestep        -> the jitted train step (donated, SPMD)
    finalize_timestep  -> checkpoint cadence + NaN guard + metrics
    finalize           -> final checkpoint + summary

The loop itself IS :func:`repro.core.time_integration.parallel_time_integration`
— the framework does not special-case ML training; a training run and a DMC
walker simulation drive the same generic function with different user
functions, which is the paper's whole point.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax

from repro.core.runtime import straggler_deadline
from repro.core.time_integration import parallel_time_integration
from repro.optim.adamw import AdamWConfig
from repro.train import checkpoint as ckpt_lib
from repro.train.fault import NanGuard, loss_is_bad
from repro.train.state import create_train_state, state_shardings
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    accum_steps: int = 1
    grad_sync: str = "gspmd"            # gspmd | compressed
    nan_guard: bool = True
    straggler_factor: float = 3.0       # host-level step-deadline watchdog
    resume: bool = True


class Trainer:
    def __init__(self, model, opt_cfg: AdamWConfig, tcfg: TrainerConfig,
                 data_iter: Iterator[dict], *, mesh=None, rules=None,
                 key=None, log: Callable[[str], None] = print):
        self.model, self.opt_cfg, self.tcfg = model, opt_cfg, tcfg
        self.data_iter = data_iter
        self.mesh, self.rules = mesh, rules
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.log = log
        self.step_fn = make_train_step(model, opt_cfg, mesh, rules,
                                       accum_steps=tcfg.accum_steps,
                                       grad_sync=tcfg.grad_sync)
        self.shardings = (state_shardings(model, mesh, rules)
                          if mesh is not None else None)
        self.start_step = 0
        self.metrics_history: list[dict] = []
        self.stragglers: list[int] = []
        self._guard = (NanGuard(tcfg.ckpt_dir, self.shardings)
                       if (tcfg.nan_guard and tcfg.ckpt_dir) else None)
        self._data_skip = 0

    # -- the three user functions handed to the generic loop ---------------

    def _initialize(self):
        state = create_train_state(self.model, self.key, self.opt_cfg,
                                   self.mesh, self.rules)
        if (self.tcfg.resume and self.tcfg.ckpt_dir
                and ckpt_lib.latest_checkpoint(self.tcfg.ckpt_dir) is not None):
            state, step = ckpt_lib.restore_checkpoint(
                self.tcfg.ckpt_dir, state, shardings=self.shardings)
            self.start_step = step
            self.log(f"[trainer] resumed from step {step}")
        return state, self.tcfg.steps - self.start_step

    def _do_timestep(self, state):
        batch = next(self.data_iter)
        if self._data_skip:                       # NaN rollback batch skip
            for _ in range(self._data_skip):
                batch = next(self.data_iter)
            self._data_skip = 0
        return self.step_fn(state, batch)

    def _finalize_timestep(self, state, step, obs):
        gstep = self.start_step + step + 1
        if self._guard is not None:
            rolled = self._guard.check(obs["loss"], state)
            if rolled is not None:
                state, rstep, skip = rolled
                self._data_skip = skip
                self.log(f"[trainer] NaN at step {gstep}; rolled back to "
                         f"{rstep}, skipping {skip} batch(es)")
                return state
        if (self.tcfg.ckpt_dir and gstep % self.tcfg.ckpt_every == 0):
            ckpt_lib.save_checkpoint(self.tcfg.ckpt_dir, gstep, state,
                                     keep=self.tcfg.ckpt_keep)
        return state

    def _on_step_end(self, step, obs, stats):
        gstep = self.start_step + step + 1
        self.metrics_history.append(
            {"step": gstep, **{k: float(v) for k, v in obs.items()},
             "step_time": stats["step_time"]})
        times = [m["step_time"] for m in self.metrics_history]
        if len(times) >= 5:
            # same deadline rule as the thread farm's re-dispatch
            deadline = straggler_deadline(times, self.tcfg.straggler_factor)
            if stats["step_time"] > deadline:
                self.stragglers.append(gstep)
                self.log(f"[trainer] straggler step {gstep}: "
                         f"{stats['step_time']:.3f}s vs deadline "
                         f"{deadline:.3f}s")
        if gstep % self.tcfg.log_every == 0:
            self.log(f"[trainer] step {gstep} loss {obs['loss']:.4f} "
                     f"lr {obs.get('lr', 0):.2e} ({stats['step_time']:.3f}s)")

    def _finalize(self, outputs):
        if self.tcfg.ckpt_dir and outputs:
            pass  # last periodic checkpoint already saved in finalize_timestep
        return {"history": self.metrics_history,
                "stragglers": self.stragglers}

    # -- public --------------------------------------------------------------

    def fit(self):
        result, stats = parallel_time_integration(
            self._initialize, self._do_timestep, self._finalize,
            finalize_timestep=self._finalize_timestep,
            on_step_end=self._on_step_end)
        self.final_state = stats["state"]
        if self.tcfg.ckpt_dir:
            gstep = self.start_step + len(self.metrics_history)
            ckpt_lib.save_checkpoint(self.tcfg.ckpt_dir, gstep,
                                     self.final_state,
                                     keep=self.tcfg.ckpt_keep)
        return result
