"""TrainState: the device-resident pytree the trainer time-integrates.

The state is a plain dict (params / opt / step) so the generic machinery
(checkpointing, resharding, the paper-style time loop) treats it exactly like
the DMC app treats its walker population: an opaque pytree with a sharding.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.mesh.axes import AxisRules, logical_to_sharding
from repro.models.module import abstract_params, sharding_tree, spec_tree
from repro.optim.adamw import AdamWConfig, adamw_init

TrainState = dict  # {"params": ..., "opt": {"m","v","step"}}


def create_train_state(model, key, opt_cfg: AdamWConfig,
                       mesh=None, rules: AxisRules | None = None,
                       param_dtype=jnp.float32) -> TrainState:
    """Initialize params + optimizer, sharded at birth when a mesh is given."""
    defs = model.param_defs()

    def build(key):
        params = model.init(key, dtype=param_dtype)
        return {"params": params, "opt": adamw_init(params, opt_cfg)}

    if mesh is None:
        return build(key)

    p_shard = sharding_tree(defs, mesh, rules)
    out_shardings = {
        "params": p_shard,
        "opt": {"m": p_shard, "v": p_shard,
                "step": jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())},
    }
    return jax.jit(build, out_shardings=out_shardings)(key)


def abstract_train_state(model, opt_cfg: AdamWConfig, mesh, rules,
                         param_dtype=jnp.float32) -> TrainState:
    """ShapeDtypeStruct stand-in (dry-run: no allocation for the 480B archs)."""
    defs = model.param_defs()
    params = abstract_params(defs, mesh, rules, dtype=param_dtype)

    def moment(p):
        return jax.ShapeDtypeStruct(p.shape, opt_cfg.moment_dtype,
                                    sharding=p.sharding)

    m = jax.tree_util.tree_map(moment, params)
    return {
        "params": params,
        "opt": {"m": m, "v": m,
                "step": jax.ShapeDtypeStruct(
                    (), jnp.int32,
                    sharding=jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec()))},
    }


def state_shardings(model, mesh, rules):
    defs = model.param_defs()
    p_shard = sharding_tree(defs, mesh, rules)
    scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return {"params": p_shard,
            "opt": {"m": p_shard, "v": p_shard, "step": scalar}}
