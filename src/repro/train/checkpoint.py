"""Atomic sharded checkpointing with latest-valid discovery.

Layout (one directory per step)::

    <dir>/step_00000420/
        manifest.json         leaf paths -> {shape, dtype, file, crc}
        <leaf>.npy            one array per leaf
        COMMIT                written LAST; its presence marks validity

Fault-tolerance properties:

* **Atomic**: everything is written into ``step_X.tmp`` and renamed after the
  COMMIT marker lands — a crash mid-save leaves a ``.tmp`` that discovery
  ignores.
* **Self-validating**: restore checks per-leaf CRCs; a corrupted checkpoint
  raises and :func:`latest_checkpoint` callers fall back to the previous one
  (see :class:`repro.train.fault.NanGuard`).
* **Mesh-independent**: arrays are saved in logical (global) layout, so a
  checkpoint written on a 256-chip mesh restores onto 512 chips or one CPU —
  this is the elastic-scaling path (``fault.reshard_state``).

On a real multi-host pod each host would write only its addressable shards
(tensorstore-style); the single-process layout keeps the same manifest/commit
protocol, which is what the restart logic depends on.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _leaf_name(path) -> str:
    s = jax.tree_util.keystr(path)
    return re.sub(r"[^A-Za-z0-9_.-]", "_", s).strip("_") or "root"


def checkpoint_steps(ckpt_dir: str) -> list[int]:
    """Committed steps, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "COMMIT")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_checkpoint(ckpt_dir: str) -> Optional[int]:
    steps = checkpoint_steps(ckpt_dir)
    return steps[-1] if steps else None


def save_checkpoint(ckpt_dir: str, step: int, state: Any, *,
                    keep: int = 3) -> str:
    """Write ``state`` atomically; prune to the newest ``keep`` checkpoints."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    manifest = {}
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        fn = name + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest[name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc": zlib.crc32(arr.tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f, indent=1)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    steps = checkpoint_steps(ckpt_dir)
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{old:08d}"),
                      ignore_errors=True)
    return final


def restore_checkpoint(ckpt_dir: str, state_like: Any, *,
                       step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``state_like``.

    ``shardings``: optional matching pytree of NamedShardings — arrays are
    device_put straight onto the (possibly different) target mesh.
    Raises ValueError on missing/corrupted data (callers fall back).
    """
    if step is None:
        step = latest_checkpoint(ckpt_dir)
        if step is None:
            raise ValueError(f"no committed checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]

    paths, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    out = []
    for (path, like), sh in zip(paths, shard_leaves):
        name = _leaf_name(path)
        if name not in manifest:
            raise ValueError(f"checkpoint {d} missing leaf {name}")
        meta = manifest[name]
        arr = np.load(os.path.join(d, meta["file"]))
        if zlib.crc32(arr.tobytes()) != meta["crc"]:
            raise ValueError(f"checkpoint {d} leaf {name} corrupted (crc)")
        if tuple(arr.shape) != tuple(np.shape(like)):
            raise ValueError(f"leaf {name}: shape {arr.shape} != "
                             f"expected {np.shape(like)}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(state_like), out)
    return state, step
