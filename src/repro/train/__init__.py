from repro.train.state import TrainState, create_train_state, abstract_train_state
from repro.train.step import make_train_step, make_eval_step
from repro.train.checkpoint import (save_checkpoint, restore_checkpoint,
                                    latest_checkpoint, checkpoint_steps)
from repro.train.fault import reshard_state, NanGuard
from repro.train.trainer import Trainer, TrainerConfig
