"""Train/eval step builders — the ``do_timestep`` of the paper's generic loop.

``make_train_step`` returns one jitted SPMD program: loss -> grad -> clip ->
AdamW, with the state donated (in-place buffer reuse) and every input/output
sharding pinned.  Two gradient-sync modes:

* ``gspmd`` (default): gradients are reduced by the compiler as part of the
  backward pass (fully overlapped by XLA's latency-hiding scheduler).
* compressed cross-pod sync lives in :mod:`repro.train.pod_dp`: per-pod
  compiled programs + a host-level int8 error-feedback exchange (the paper's
  thin-Python-communication-layer design applied to the inter-pod fabric).

Gradient accumulation (``accum_steps``) scans over microbatches, which is
also the main activation-memory lever (the other is remat).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.comm import Comm
from repro.mesh.axes import AxisRules, logical_to_mesh, logical_to_sharding
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.compress import compressed_psum
from repro.train.state import state_shardings


def _split_microbatches(batch, n: int):
    """(B, ...) -> (n, B/n, ...) for every leaf."""
    def sp(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree_util.tree_map(sp, batch)


def make_train_step(model, opt_cfg: AdamWConfig, mesh=None,
                    rules: AxisRules | None = None, *,
                    accum_steps: int = 1, grad_sync: str = "gspmd",
                    donate: bool = True):
    """Returns ``step(state, batch) -> (state, metrics)`` (jitted)."""
    cfg = model.cfg

    def make_grads_of(rules_):
        def loss_fn(params, batch):
            loss, metrics = model.loss(params, batch, rules_)
            return loss, metrics

        def grads_of(params, batch):
            if accum_steps == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
                return loss, metrics, grads

            micro = _split_microbatches(batch, accum_steps)

            def body(carry, mb):
                loss_acc, grads_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), metrics

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), metrics = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            scale = 1.0 / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
            last = jax.tree_util.tree_map(lambda m: m[-1], metrics)
            return loss_sum * scale, last, grads

        return grads_of

    grads_of = make_grads_of(rules)

    def step(state, batch):
        loss, metrics, grads = grads_of(state["params"], batch)
        new_params, new_opt, stats = adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        out = {"loss": loss, **metrics, **stats}
        return {"params": new_params, "opt": new_opt}, out

    if mesh is None:
        return jax.jit(step, donate_argnums=(0,) if donate else ())

    st_sh = state_shardings(model, mesh, rules)
    if grad_sync == "compressed":
        raise ValueError(
            "compressed cross-pod sync is host-orchestrated: use "
            "repro.train.pod_dp.make_pod_dp_step (a single-jit partial-manual "
            "shard_map over 'pod' crashes XLA's SPMD partitioner; see "
            "EXPERIMENTS.md)")

    return jax.jit(step,
                   in_shardings=(st_sh, None),
                   out_shardings=(st_sh, None),
                   donate_argnums=(0,) if donate else ())


def _strip_axis(rules: AxisRules, axis: str) -> AxisRules:
    """Rules with every reference to ``axis`` removed (for code running on a
    per-pod sub-mesh, e.g. the host-level pod-DP path)."""
    out = {}
    for k, v in rules.rules.items():
        if v == axis:
            v = None
        elif isinstance(v, (tuple, list)):
            v = tuple(a for a in v if a != axis) or None
        out[k] = v
    return AxisRules(out, rules.mesh)


def make_eval_step(model, mesh=None, rules=None):
    def step(params, batch):
        loss, metrics = model.loss(params, batch, rules)
        return {"loss": loss, **metrics}
    return jax.jit(step)
