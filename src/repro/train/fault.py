"""Fault tolerance: NaN rollback, corrupted-checkpoint fallback, elastic
resharding, and the host-level straggler watchdog.

Failure model at 1000+ nodes (what each piece handles):

* **Numerical blow-up** (bad batch, hardware bit-flip): ``NanGuard`` watches
  the loss; on NaN/inf it restores the latest *valid* checkpoint and skips
  ahead of the offending batch (deterministic data pipeline = skipping is a
  pure index bump).
* **Corrupted/partial checkpoint** (crash mid-save): ``restore_latest_valid``
  walks checkpoints newest-first until one passes CRC validation.
* **Node count change** (preemption, repair, scale-up): ``reshard_state``
  re-device_puts a mesh-independent checkpoint onto the new mesh's shardings;
  resume is bit-exact because the data pipeline is a pure function of step.
* **Stragglers**: inside one jitted SPMD step TPUs are lock-stepped, so
  stragglers only exist at host level (input stalls, separately-jitted farm
  tasks).  :func:`redispatch_stragglers` runs such tasks on the runtime's
  :class:`~repro.core.runtime.ThreadFarmExecutor`, whose idle workers
  re-issue any task exceeding ``k x`` the median runtime (the classic
  backup-task trick, first completion wins); the Trainer's watchdog flags
  steps breaching the same :func:`~repro.core.runtime.straggler_deadline`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from repro.core.functional import host_task_farm
from repro.train import checkpoint as ckpt


def redispatch_stragglers(tasks: Sequence[Callable[[], Any]], *,
                          deadline_factor: float = 3.0,
                          num_workers: int | None = None):
    """Run host-level tasks with backup re-dispatch of stragglers.

    Fault-tolerance-flavored entry point over the runtime's thread farm
    (same machinery as :func:`repro.core.functional.host_task_farm`, with
    re-dispatch on by default): tasks whose elapsed time exceeds
    ``deadline_factor`` x the median runtime are re-issued once to an idle
    worker and the first completion wins.  Returns (results, stats) with
    ``stats['stragglers']`` listing re-issued indices.
    """
    return host_task_farm(tasks, num_workers=num_workers,
                          deadline_factor=deadline_factor)


def loss_is_bad(loss) -> bool:
    x = float(jax.device_get(loss))
    return math.isnan(x) or math.isinf(x)


def restore_latest_valid(ckpt_dir: str, state_like, shardings=None,
                         *, max_back: int = 5):
    """Walk committed checkpoints newest-first; return the first that passes
    validation.  Raises if none of the newest ``max_back`` are usable."""
    steps = ckpt.checkpoint_steps(ckpt_dir)[::-1][:max_back]
    last_err: Exception | None = None
    for s in steps:
        try:
            return ckpt.restore_checkpoint(ckpt_dir, state_like, step=s,
                                           shardings=shardings)
        except (ValueError, OSError) as e:          # corrupted -> try older
            last_err = e
    raise ValueError(f"no valid checkpoint among steps {steps}: {last_err}")


def reshard_state(state, shardings):
    """Elastic scaling: place a (host or other-mesh) state onto new shardings."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        state, shardings)


@dataclasses.dataclass
class NanGuard:
    """Loss watchdog with rollback-and-skip.

    After ``max_rollbacks`` consecutive rollbacks it raises — at that point
    the failure is systematic, not transient, and a human should look.
    """
    ckpt_dir: str
    shardings: Any = None
    max_rollbacks: int = 3
    skip_batches: int = 1
    _consecutive: int = 0

    def check(self, loss, state_like):
        """Returns None if healthy, else (restored_state, restored_step,
        data_skip) after rolling back."""
        if not loss_is_bad(loss):
            self._consecutive = 0
            return None
        self._consecutive += 1
        if self._consecutive > self.max_rollbacks:
            raise FloatingPointError(
                f"loss NaN persisted through {self.max_rollbacks} rollbacks")
        state, step = restore_latest_valid(self.ckpt_dir, state_like,
                                           self.shardings)
        return state, step, self.skip_batches
