"""Cross-pod data parallelism with int8 error-feedback gradient exchange,
orchestrated at the HOST level — the paper's thesis applied to multi-pod
training.

Each pod runs its own compiled SPMD program (grads + update) on its own
sub-mesh; the *inter-pod* communication — the slow tier — is done by a thin
Python layer that moves int8-quantized gradients between pods, exactly like
the paper's thin MPI layer moved pickled arrays between serial processes.
(A single-jit formulation with a partial-manual shard_map over "pod" hits an
XLA SPMD-partitioner check failure — see EXPERIMENTS.md §Perf notes — and a
multi-controller deployment needs the host path anyway: pods on different
fabrics cannot share one XLA program.)

Wire format per tensor per step: int8 payload + one f32 scale (4x smaller
than f32, 2x smaller than bf16); the quantization residual stays pod-local as
error feedback, so convergence is unaffected (tests assert loss parity with
uncompressed DP).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.mesh.axes import AxisRules
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import int8_compress, int8_decompress
from repro.train.state import state_shardings
from repro.train.step import _strip_axis


def split_pod_meshes(mesh):
    """(2,16,16) ("pod","data","model") -> [two (16,16) sub-meshes]."""
    assert "pod" in mesh.axis_names
    pod_idx = list(mesh.axis_names).index("pod")
    rest = tuple(a for a in mesh.axis_names if a != "pod")
    out = []
    for p in range(mesh.shape["pod"]):
        devs = np.take(mesh.devices, p, axis=pod_idx)
        out.append(jax.sharding.Mesh(devs, rest))
    return out


@dataclasses.dataclass
class PodDPStep:
    """Host-level train step over per-pod compiled programs."""
    model: object
    opt_cfg: AdamWConfig
    submeshes: list
    sub_rules: list
    compress: bool = True

    def __post_init__(self):
        model, opt_cfg = self.model, self.opt_cfg
        self.grads_fns, self.apply_fns, self.shardings = [], [], []
        for m, r in zip(self.submeshes, self.sub_rules):
            sh = state_shardings(model, m, r)
            self.shardings.append(sh)

            def make(r=r, sh=sh):
                def grads(params, batch):
                    def loss_fn(p, b):
                        return model.loss(p, b, r)
                    (loss, metrics), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, batch)
                    return loss, metrics, g

                def apply(state, mean_grads):
                    new_p, new_opt, stats = adamw_update(
                        state["params"], mean_grads, state["opt"], opt_cfg)
                    return {"params": new_p, "opt": new_opt}, stats

                return (jax.jit(grads, in_shardings=(sh["params"], None)),
                        jax.jit(apply, donate_argnums=(0,),
                                in_shardings=(sh, sh["params"]),
                                out_shardings=(sh, None)))

            gf, af = make()
            self.grads_fns.append(gf)
            self.apply_fns.append(af)

    def init_state(self, key, param_dtype=jnp.float32):
        """Identical params on every pod + per-pod EF residuals (host f32)."""
        pods = []
        for m, r, sh in zip(self.submeshes, self.sub_rules, self.shardings):
            params = jax.jit(
                lambda k: self.model.init(k, dtype=param_dtype),
                out_shardings=sh["params"])(key)
            pods.append({"params": params,
                         "opt": adamw_init(params, self.opt_cfg)})
        err = [jax.tree_util.tree_map(
            lambda p: np.zeros(p.shape, np.float32), pods[p]["params"])
            for p in range(len(pods))]
        return {"pods": pods, "err": err}

    def __call__(self, state, batch):
        """batch: host/global arrays (B, ...); B splits across pods."""
        n = len(self.submeshes)
        B = jax.tree_util.tree_leaves(batch)[0].shape[0]
        per = B // n
        losses, wires = [], []
        bytes_fp32 = bytes_wire = 0

        # 1. per-pod local grads (each pod's own compiled program)
        for p in range(n):
            bp = jax.tree_util.tree_map(
                lambda x: x[p * per:(p + 1) * per], batch)
            loss, metrics, grads = self.grads_fns[p](
                state["pods"][p]["params"], bp)
            losses.append(loss)

            if self.compress:
                # 2. quantize (on device), ship int8+scale (host = the wire)
                leaves, tdef = jax.tree_util.tree_flatten(grads)
                errs = tdef.flatten_up_to(state["err"][p])
                qs, new_errs = [], []
                for g, e in zip(leaves, errs):
                    gf = g.astype(jnp.float32) + jnp.asarray(e)
                    q, s = int8_compress(gf)
                    q_host = np.asarray(jax.device_get(q))
                    s_host = float(jax.device_get(s))
                    # EF residual stays local to the pod
                    new_errs.append(np.asarray(jax.device_get(
                        gf - int8_decompress(q, s))))
                    qs.append((q_host, s_host))
                    bytes_fp32 += q_host.size * 4
                    bytes_wire += q_host.size + 4
                state["err"][p] = tdef.unflatten(new_errs)
                wires.append((qs, tdef))
            else:
                wires.append((jax.device_get(grads), None))

        # 3. host "all-reduce" across pods (the inter-pod fabric)
        if self.compress:
            qs0, tdef = wires[0]
            mean_leaves = []
            for i in range(len(qs0)):
                acc = np.zeros(qs0[i][0].shape, np.float32)
                for p in range(n):
                    q, s = wires[p][0][i]
                    acc += q.astype(np.float32) * s
                mean_leaves.append(acc / n)
            mean_grads = tdef.unflatten(mean_leaves)
        else:
            mean_grads = jax.tree_util.tree_map(
                lambda *gs: sum(np.asarray(g, np.float64) for g in gs) / n,
                *[w[0] for w in wires])
            mean_grads = jax.tree_util.tree_map(
                lambda g: g.astype(np.float32), mean_grads)

        # 4. every pod applies the same mean gradient
        all_stats = None
        for p in range(n):
            state["pods"][p], stats = self.apply_fns[p](
                state["pods"][p], mean_grads)
            all_stats = stats
        loss = float(np.mean([float(l) for l in losses]))
        out = {"loss": loss, **{k: float(v) for k, v in all_stats.items()},
               "wire_bytes": bytes_wire, "fp32_bytes": bytes_fp32}
        return state, out


def make_pod_dp_step(model, opt_cfg: AdamWConfig, mesh,
                     rules: AxisRules, *, compress: bool = True) -> PodDPStep:
    submeshes = split_pod_meshes(mesh)
    sub_rules = [_strip_axis(rules, "pod").with_mesh(m) for m in submeshes]
    return PodDPStep(model, opt_cfg, submeshes, sub_rules, compress)
