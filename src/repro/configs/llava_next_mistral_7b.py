"""llava-next-mistral-7b — Mistral-7B backbone + anyres vision stub
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

Per the brief, the modality frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (anyres: 576 base + 4 tiles x 576 = 2880 image
tokens), which are concatenated in front of the text embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000,
    qkv_bias=False, qk_norm=False, rope_theta=1e6,
    n_image_tokens=2880,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, n_image_tokens=8,
    tp=1, dtype="float32", kv_chunk=32)
