from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.configs.registry import get_config, list_archs, smoke_config
