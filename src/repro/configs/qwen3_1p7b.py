"""qwen3-1.7b — dense, GQA, qk_norm [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab=151936,
    qkv_bias=False, qk_norm=True, rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, tp=1, dtype="float32", kv_chunk=32)
