"""Model / shape configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128

    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    local_window: Optional[int] = None      # sliding-window size (local layers)
    global_every: int = 0                   # gemma3: every Nth layer is global
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    dense_residual: bool = False            # arctic: dense MLP in parallel w/ MoE
    capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01
    router_z_weight: float = 1e-3

    # SSM (mamba2) / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_kernel: int = 4
    shared_attn_every: int = 0              # zamba: shared attn block cadence

    # rwkv
    rwkv_head_dim: int = 0
    rwkv_time_chunk: int = 32    # chunked matmul wkv form (0 = per-step scan)

    # vlm / audio frontends (stubs per brief: precomputed embeddings)
    n_image_tokens: int = 0
    n_audio_frames: int = 0
    decoder_layers: int = 0                 # whisper: n_layers = encoder layers

    # runtime
    dtype: str = "bfloat16"
    param_dtype: str = "float32"            # bf16 for >=200B MoE (HBM fit)
    moment_dtype: str = "float32"           # AdamW m/v storage
    remat: str = "full"                     # none | dots | full — "full"
    # saves only scan carries: the only policy whose temp footprint fits 16GB
    # HBM at train_4k for the 7B+ archs (see EXPERIMENTS.md §Dry-run)
    kv_chunk: int = 1024
    use_pallas: bool = False
    z_loss: float = 0.0
    tp: int = 16                            # model-axis size (vocab padding)

    # ---- derived -----------------------------------------------------------
    @property
    def padded_q_heads(self) -> int:
        return self.n_heads                 # heads never TP-sharded

    @property
    def padded_kv_heads(self) -> int:
        return self.n_kv_heads

    @property
    def padded_vocab(self) -> int:
        return _pad_to(self.vocab, max(self.tp * 8, 128))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim if self.rwkv_head_dim else 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.decoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the 500k-context shape."""
        return self.family in ("ssm", "hybrid") or (
            self.local_window is not None and self.global_every > 0)

    def window_for_layer(self, layer_idx: int) -> Optional[int]:
        """gemma3 5:1 pattern: every ``global_every``-th layer is global."""
        if self.local_window is None:
            return None
        if self.global_every and (layer_idx + 1) % self.global_every == 0:
            return None                    # global layer
        return self.local_window

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — the config skip table, in code."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode skipped per assignment"
    return True, ""
