"""qwen2.5-14b — dense, GQA, QKV bias [hf:Qwen/Qwen2.5 family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab=152064,
    qkv_bias=True, qk_norm=False, rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, tp=1, dtype="float32", kv_chunk=32)
