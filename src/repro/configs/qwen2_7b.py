"""qwen2-7b — dense, GQA, QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064,
    qkv_bias=True, qk_norm=False, rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, tp=1, dtype="float32", kv_chunk=32)
