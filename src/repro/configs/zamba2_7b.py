"""zamba2-7b — hybrid: 81 Mamba2 layers + shared transformer blocks applied
every 27 layers (shared weights, 3 applications) [arXiv:2411.15242].

ssm_state=64, d_inner = 2 x 3584 = 7168, 112 SSM heads of 64 channels.
Shared attention block: 32 MHA heads (kv=32), d_ff 14336.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000,
    qkv_bias=False, qk_norm=False, rope_theta=1e6,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, conv_kernel=4,
    shared_attn_every=27,
)

SMOKE = CONFIG.replace(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, ssm_state=8, ssm_head_dim=16, shared_attn_every=3,
    tp=1, dtype="float32", kv_chunk=32)
