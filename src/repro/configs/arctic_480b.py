"""arctic-480b — dense-MoE hybrid: 128 experts top-2 IN PARALLEL with a dense
residual MLP every layer [hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab=32000,
    qkv_bias=False, qk_norm=False, rope_theta=1e6,
    n_experts=128, top_k=2, expert_d_ff=4864, dense_residual=True,
    param_dtype="bfloat16", moment_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab=512, n_experts=8, top_k=2, expert_d_ff=32,
    tp=1, dtype="float32", kv_chunk=32)
