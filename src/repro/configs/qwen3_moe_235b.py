"""qwen3-moe-235b-a22b — 128 experts top-8, qk_norm [hf:Qwen/Qwen3-MoE]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    qkv_bias=False, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8, expert_d_ff=1536, dense_residual=False,
    param_dtype="bfloat16", moment_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab=512, n_experts=8, top_k=4, expert_d_ff=32,
    tp=1, dtype="float32", kv_chunk=32)
