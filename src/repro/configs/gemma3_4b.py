"""gemma3-4b — dense, 5:1 local:global sliding-window, 128k context
[hf:google/gemma-3 family].  Every 6th layer is global; local window 1024."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144,
    qkv_bias=False, qk_norm=True, rope_theta=1e6,
    local_window=1024, global_every=6,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, local_window=8, global_every=3,
    tp=1, dtype="float32", kv_chunk=32)
