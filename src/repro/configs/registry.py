"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

_MODULES = {
    "qwen2-7b": "repro.configs.qwen2_7b",
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "qwen2.5-14b": "repro.configs.qwen2p5_14b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}


def list_archs() -> list[str]:
    return list(_MODULES)


def get_config(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def smoke_config(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).SMOKE
