"""rwkv6-3b (Finch) — attention-free, data-dependent decay [arXiv:2404.05892].

40 heads x 64 channels; channel-mix FFN hidden 8960; vocab 65536.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=8960, vocab=65536,
    rope_theta=0.0,
    rwkv_head_dim=64,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, d_ff=128, vocab=512, rwkv_head_dim=16,
    tp=1, dtype="float32")
