"""whisper-tiny — encoder-decoder, conv frontend stubbed [arXiv:2212.04356].

Per the brief, the audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (1500 frames x d_model) directly to the encoder.
The 4-layer encoder is bidirectional; the 4-layer decoder has causal self- and
cross-attention.  Decode shapes exercise the decoder (the assignment's stress
shapes exceed the model's published 448-token decoder context; positions are
handled structurally).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab=51865,
    qkv_bias=True, qk_norm=False, rope_theta=1e4,
    n_audio_frames=1500, decoder_layers=4,
)

SMOKE = CONFIG.replace(
    n_layers=2, decoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=512, n_audio_frames=16,
    tp=1, dtype="float32", kv_chunk=32)
