"""Speculative multi-token decode: pluggable drafter functions.

The paper's core claim — parallel machinery in a thin generic layer, the
concrete computation supplied as plain Python functions — applied one level
up the serving stack: a *drafter* is a function ``propose(tokens, k)`` that
guesses the next ``k`` tokens of a stream, and the engine's generic verify
loop scores every guess in ONE batched forward through the target model
(:meth:`repro.models.api.Model.paged_verify`), accepting the longest prefix
the target agrees with.  The engine never looks inside a drafter, exactly
like the task farm never looks inside ``func``: swapping the drafting
strategy is swapping a function.

Two weight-free drafters ship here (both work on random-init models, since
neither learns anything the target doesn't already know):

* :class:`NgramDrafter` — prompt-lookup decoding: match the tail n-gram of
  the generated stream against earlier positions of prompt + output and
  propose the historical continuation.  Shines on the shared-prefix /
  repetitive workloads the prefix cache targets (retrieval prompts, code,
  self-repeating generations).
* :class:`TruncatedSelfDrafter` — run the FIRST ``layers`` blocks of the
  target itself (shared embedding + lm head) as a cheap autoregressive
  proposer.  No extra weights; the draft model is a prefix of the target.

A drafter failing or proposing nothing simply costs nothing: the engine
falls back to plain per-token decode for that slot on that tick.  Drafts
are *proposals* — correctness never depends on them, so a drafter may be
arbitrarily sloppy (wrong drafts are rejected by the verify rule and the
stream continues bit-identically to non-speculative decode).
"""

from __future__ import annotations

__all__ = ["Drafter", "NgramDrafter", "TruncatedSelfDrafter",
           "make_drafter"]

import functools
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


@runtime_checkable
class Drafter(Protocol):
    """The pluggable proposer contract — one function.

    ``propose(tokens, k)``: ``tokens`` is the request's full visible stream
    (prompt + every generated token, the last element being the token whose
    successor is wanted) as ``(n,) int32``; return up to ``k`` proposed
    continuation tokens as ``(m,) int32`` (``m <= k``; empty means "no
    guess").  Must be deterministic in ``tokens`` — the parity guarantee
    (speculative greedy streams == plain greedy streams) holds regardless,
    but determinism keeps acceptance counters reproducible run to run.
    """

    def propose(self, tokens: np.ndarray, k: int) -> np.ndarray:
        """Up to ``k`` draft tokens continuing ``tokens`` (may be empty)."""
        ...


class NgramDrafter:
    """Prompt-lookup decoding (n-gram matching against the own stream).

    Find the most recent earlier occurrence of the stream's final n-gram
    (longest ``max_n`` first, down to ``min_n``) and propose the tokens
    that followed it.  Pure host-side numpy — zero device work.
    """

    def __init__(self, max_n: int = 3, min_n: int = 1):
        assert 1 <= min_n <= max_n
        self.max_n, self.min_n = max_n, min_n

    def propose(self, tokens: np.ndarray, k: int) -> np.ndarray:
        """Tokens that followed the stream's own last n-gram, up to ``k``."""
        t = np.ascontiguousarray(tokens, np.int32)
        best = np.zeros(0, np.int32)
        if k <= 0:
            return best
        for n in range(min(self.max_n, len(t) - 1), self.min_n - 1, -1):
            tail = t[-n:]
            # windows[j] = t[j:j+n]; candidate matches must have a
            # continuation (j + n < len(t)) and not be the tail itself
            win = np.lib.stride_tricks.sliding_window_view(t[:-1], n)
            hits = np.nonzero((win == tail).all(axis=1))[0]
            if not hits.size:
                continue
            # prefer the most recent occurrence with a FULL k-token
            # continuation: in a loop of period p the very last match sits
            # p tokens from the end and could only propose p tokens — one
            # period earlier proposes the whole window
            full = hits[hits + n + k <= len(t)]
            if full.size:
                j = int(full[-1])
                return t[j + n:j + n + k].copy()
            j = int(hits[-1])
            if len(t) - (j + n) > len(best):
                best = t[j + n:].copy()
        return best


def _bucket(n: int, buckets=(16, 32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 2047) // 2048) * 2048


class TruncatedSelfDrafter:
    """Draft with the first ``layers`` blocks of the target model itself.

    The draft "model" is a prefix of the target: shared token embedding,
    blocks ``0..layers-1``, the final norm and the shared lm head — no
    extra parameters, so it works on any (random-init included) DecoderLM
    checkpoint.  Proposals are greedy and autoregressive: each draft token
    re-runs the truncated forward over the (bucketed) full stream, which is
    cheap because ``layers`` is small and smoke/serving contexts are short.
    """

    def __init__(self, model, params, *, layers: int = 2):
        from repro.models import transformer as T
        cfg = model.cfg
        if not model.supports_paged_decode():
            raise ValueError(
                f"{cfg.name} ({cfg.family}) has no stacked decoder blocks "
                "to truncate; use the ngram drafter")
        k = max(1, min(layers, cfg.n_layers))
        self.layers = k
        self.cfg = cfg.replace(n_layers=k)
        self.vocab = cfg.vocab
        self.params = {
            "embed": params["embed"],
            "blocks": jax.tree_util.tree_map(lambda a: a[:k],
                                             params["blocks"]),
            "final_norm": params["final_norm"],
            "unembed": params["unembed"],
        }

        @functools.partial(jax.jit, static_argnums=())
        def _next_logits(p, toks, n_valid):
            hidden, _ = T.forward(p, self.cfg, None, tokens=toks)
            h = jax.lax.dynamic_slice_in_dim(hidden, n_valid - 1, 1, axis=1)
            return T.lm_logits(p, h, self.cfg, None)

        self._next_logits = _next_logits

    def propose(self, tokens: np.ndarray, k: int) -> np.ndarray:
        """Greedy rollout of the truncated model, one token at a time."""
        from repro.serve.sampling import greedy
        t = list(np.asarray(tokens, np.int32))
        out = []
        for _ in range(max(0, k)):
            n = len(t)
            buf = np.zeros((1, _bucket(n)), np.int32)
            buf[0, :n] = t
            logits = self._next_logits(self.params, jnp.asarray(buf),
                                       jnp.int32(n))
            nxt = int(greedy(logits, true_vocab=self.vocab)[0, 0])
            out.append(nxt)
            t.append(nxt)
        return np.asarray(out, np.int32)


def make_drafter(name: str, model=None, params=None) -> Drafter:
    """Resolve a CLI-style drafter name.

    ``"ngram"`` (or ``"ngram-N"`` for a max n-gram of N) needs no model;
    ``"self-K"`` (or ``"self"``, K defaulting to 2) truncates the target to
    its first K layers and needs ``model`` + ``params``.
    """
    base, _, arg = name.partition("-")
    if base == "ngram":
        return NgramDrafter(max_n=int(arg) if arg else 3)
    if base == "self":
        if model is None or params is None:
            raise ValueError("the self-K drafter needs model= and params=")
        return TruncatedSelfDrafter(model, params,
                                    layers=int(arg) if arg else 2)
    raise ValueError(f"unknown drafter {name!r} (want ngram[-N] or self[-K])")
