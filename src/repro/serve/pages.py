"""Paged KV-cache storage: a model-agnostic page pool + per-slot page tables.

This is the paper's dynamic-population append/delete applied to *memory*
instead of walkers: the pool's pages are the capacity, requests allocate
pages as they enter and grow, and free them as they leave.  The engine's
footprint becomes ``pages_in_use x page_size`` tokens instead of
``max_slots x max_len`` — short requests stop paying for the longest one.

Layering contract (function-centric): this module never looks inside a
model.  A model describes each decode-cache leaf with a
:class:`PagedLeafSpec` (leading dims / trailing dims / dtype around the
token axis) and the pool materializes storage of shape
``prefix + (num_pages, page_size) + suffix`` per leaf.  The pure functions
:func:`scatter_chunk`, :func:`scatter_token` and :func:`gather_pages` are
the only ways device code touches that storage, so the same pool serves the
dense, MoE and VLM cache families unchanged.

Host-side bookkeeping (the free list) is deterministic: pages are handed
out FIFO, so identical request streams produce identical page tables —
which is what makes paged-vs-dense token parity testable.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedLeafSpec:
    """One decode-cache leaf, described around its token axis.

    A dense cache leaf ``(L, B, S, H, D)`` becomes
    ``prefix=(L,), suffix=(H, D)`` — batch and sequence axes are replaced
    by the pool's ``(num_pages, page_size)`` pair.
    """
    prefix: tuple
    suffix: tuple
    dtype: Any

    def storage_shape(self, num_pages: int, page_size: int) -> tuple:
        return tuple(self.prefix) + (num_pages, page_size) + tuple(self.suffix)


def _is_spec(x) -> bool:
    return isinstance(x, PagedLeafSpec)


def tree_deleted(tree) -> bool:
    """True if any array leaf was consumed by a raising donated call
    (jit donation: the callee took the buffers before failing)."""
    return any(getattr(leaf, "is_deleted", lambda: False)()
               for leaf in jax.tree_util.tree_leaves(tree))


# extra never-allocated page absorbing dead-slot decode writes; storage is
# always materialized with ``num_pages + N_TRASH`` pages
N_TRASH = 1


class PagePool:
    """Fixed-size KV pages with a FIFO free list and a high-water stat.

    One extra *trash* page (index ``num_pages``) is always allocated so
    batched decode can keep dead slots in the SPMD step: their token writes
    land in the trash page instead of corrupting a live one.
    """

    def __init__(self, leaf_specs, *, num_pages: int, page_size: int,
                 shardings=None):
        """``shardings``: optional pytree of ``jax.sharding.Sharding``
        matching ``leaf_specs`` — mesh serving materializes the KV storage
        already partitioned (heads over the "model" axis) so no leaf ever
        exists unsharded on one device."""
        assert num_pages >= 1 and page_size >= 1
        self.leaf_specs = leaf_specs
        self.num_pages = num_pages
        self.page_size = page_size
        self.trash_page = num_pages            # valid index, never allocated
        self._shardings = shardings
        self.storage = self._fresh_storage()
        self._free: deque[int] = deque(range(num_pages))
        self._high_water = 0

    def _fresh_storage(self):
        def zeros(s):
            return jnp.zeros(
                s.storage_shape(self.num_pages + N_TRASH, self.page_size),
                s.dtype)
        if self._shardings is None:
            return jax.tree_util.tree_map(zeros, self.leaf_specs,
                                          is_leaf=_is_spec)
        return jax.tree_util.tree_map(
            lambda s, sh: jax.device_put(zeros(s), sh),
            self.leaf_specs, self._shardings, is_leaf=_is_spec)

    def storage_deleted(self) -> bool:
        """True if any storage buffer was consumed (a jitted call with
        donation that raised after taking its arguments)."""
        return tree_deleted(self.storage)

    def reset_storage(self) -> None:
        """Rebuild zeroed storage with the original shapes/shardings.  The
        KV *contents* are gone — callers must evict every resident request
        first (recompute-style re-prefill preserves their streams)."""
        self.storage = self._fresh_storage()

    # -- host-side accounting -------------------------------------------------

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def high_water(self) -> int:
        """Max pages simultaneously in use since construction."""
        return self._high_water

    def alloc(self, n: int) -> Optional[list[int]]:
        """Pop ``n`` pages, or None (allocate-all-or-nothing) if exhausted."""
        if n < 0 or len(self._free) < n:
            return None
        pages = [self._free.popleft() for _ in range(n)]
        self._high_water = max(self._high_water, self.pages_in_use)
        return pages

    def free(self, pages) -> None:
        for p in pages:
            assert 0 <= p < self.num_pages, p
            self._free.append(int(p))

    def tokens_capacity(self) -> int:
        return self.num_pages * self.page_size


# ---------------------------------------------------------------------------
# Pure device ops (jit-safe; storage in, storage out)
# ---------------------------------------------------------------------------

def _pfx(n_prefix: int) -> tuple:
    return (slice(None),) * n_prefix


def scatter_chunk(storage, pages, chunk, *, page_size: int, n_prefix: int = 0):
    """Write a page-aligned token chunk into its pages.

    storage: (prefix..., N, page_size, suffix...)
    pages:   (n,) int32 page ids
    chunk:   (prefix..., n * page_size, suffix...)
    """
    n = pages.shape[0]
    pre = chunk.shape[:n_prefix]
    suf = chunk.shape[n_prefix + 1:]
    blk = chunk.reshape(pre + (n, page_size) + suf)
    idx = _pfx(n_prefix) + (pages,)
    return storage.at[idx].set(blk.astype(storage.dtype))


def scatter_token(storage, pages, offs, vals, *, n_prefix: int = 0):
    """Write one token per slot at (page, offset) — the decode-step write.

    storage: (prefix..., N, page_size, suffix...)
    pages, offs: (B,) int32;   vals: (prefix..., B, suffix...)
    """
    idx = _pfx(n_prefix) + (pages, offs)
    return storage.at[idx].set(vals.astype(storage.dtype))


def gather_pages(storage, tables, *, n_prefix: int = 0):
    """Gather each slot's pages back into a contiguous view.

    storage: (prefix..., N, page_size, suffix...);  tables: (B, P) int32
    -> (prefix..., B, P * page_size, suffix...)
    """
    B, P = tables.shape
    idx = _pfx(n_prefix) + (tables,)
    g = storage[idx]                  # (prefix..., B, P, page_size, suffix...)
    pre = g.shape[:n_prefix]
    suf = g.shape[n_prefix + 3:]
    return g.reshape(pre + (B, P * storage.shape[n_prefix + 1]) + suf)


# ---------------------------------------------------------------------------
# Dense per-slot state store (the degenerate "one page per slot" layout)
# ---------------------------------------------------------------------------

def write_slot(state, slot_state, slot: int):
    """Write a (B=1) prefill state into slot ``slot`` of the batched state.

    The dense-path replacement for splice-by-``dynamic_update_slice``: every
    leaf has batch on axis 1 (stacked caches and recurrent O(1) states
    alike); a leaf with a sequence axis (axis 2) shorter than the slot's
    is zero-padded — the validity length masks the tail.
    """
    def leaf(dst, src):
        if src.ndim >= 3 and src.shape[2] < dst.shape[2]:
            pad = [(0, 0)] * src.ndim
            pad[2] = (0, dst.shape[2] - src.shape[2])
            src = jnp.pad(src, pad)
        return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

    return jax.tree_util.tree_map(leaf, state, slot_state)
