"""Paged KV-cache storage: refcounted page pool + radix prefix cache.

This is the paper's dynamic-population append/delete applied to *memory*
instead of walkers: the pool's pages are the capacity, requests allocate
pages as they enter and grow, and free them as they leave.  The engine's
footprint becomes ``pages_in_use x page_size`` tokens instead of
``max_slots x max_len`` — short requests stop paying for the longest one.

Pages are **refcounted and content-addressed**: a :class:`PrefixCache`
(radix trie keyed by page-sized token chunks) maps shared prompt prefixes
to pages already holding their K/V, so N requests with a common system
prompt hold ONE copy of its pages.  Lifecycle of a page:

    free ──alloc──> held (rc=1) ──incref──> shared (rc>1)
      ^                │ register (full, content known)
      │                v
      └──evict(LRU)── cached (rc=0, in the index) ──match+incref──> held

A held page that is still registered may be re-shared by a later match;
an unreferenced cached page is an LRU eviction candidate whenever the
free list runs short.  Writers never mutate a shared page: the serving
layer copies it first (:func:`copy_pages`, copy-on-write) or — when it is
the page's only holder — unregisters it and writes in place.

Layering contract (function-centric): this module never looks inside a
model.  A model describes each decode-cache leaf with a
:class:`PagedLeafSpec` (leading dims / trailing dims / dtype around the
token axis) and the pool materializes storage of shape
``prefix + (num_pages, page_size) + suffix`` per leaf.  The pure functions
:func:`scatter_chunk`, :func:`scatter_token`, :func:`gather_pages` and
:func:`copy_pages` are the only ways device code touches that storage, so
the same pool serves the dense, MoE and VLM cache families unchanged.

Host-side bookkeeping (free list, refcounts, radix index, LRU clock) is
deterministic: identical request streams produce identical page tables —
which is what makes cache-on-vs-off token parity testable.
"""

from __future__ import annotations

__all__ = ["CrossKVPool", "KVHandoff", "N_TRASH",
           "PagePool", "PagedLeafSpec", "PrefixCache",
           "copy_pages", "gather_pages", "scatter_chunk",
           "scatter_token", "scatter_window", "tree_deleted",
           "write_slot"]

import dataclasses
import heapq
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedLeafSpec:
    """One decode-cache leaf, described around its token axis.

    A dense cache leaf ``(L, B, S, H, D)`` becomes
    ``prefix=(L,), suffix=(H, D)`` — batch and sequence axes are replaced
    by the pool's ``(num_pages, page_size)`` pair.
    """
    prefix: tuple
    suffix: tuple
    dtype: Any

    def storage_shape(self, num_pages: int, page_size: int) -> tuple:
        """prefix + (num_pages, page_size) + suffix — the pool array shape."""
        return tuple(self.prefix) + (num_pages, page_size) + tuple(self.suffix)


def _is_spec(x) -> bool:
    return isinstance(x, PagedLeafSpec)


def tree_deleted(tree) -> bool:
    """True if any array leaf was consumed by a raising donated call
    (jit donation: the callee took the buffers before failing)."""
    return any(getattr(leaf, "is_deleted", lambda: False)()
               for leaf in jax.tree_util.tree_leaves(tree))


# extra never-allocated page absorbing dead-slot decode writes; storage is
# always materialized with ``num_pages + N_TRASH`` pages
N_TRASH = 1


class _PrefixNode:
    """One cached page: a page-sized token chunk hanging off its parent."""
    __slots__ = ("key", "page", "parent", "children", "last_use")

    def __init__(self, key: bytes, page: int, parent):
        self.key = key                  # the ps int32 tokens, as bytes
        self.page = page
        self.parent = parent
        self.children: dict[bytes, _PrefixNode] = {}
        self.last_use = 0


class PrefixCache:
    """Radix index over cached pages: token-chunk content -> page id.

    Each node is one FULL page whose K/V content is final (its ``page_size``
    tokens are known); a path from the root spells out a token prefix at
    page granularity.  :meth:`match` additionally shares a *partial* last
    page when a cached child covers the request's whole remaining prompt —
    the case that makes decode-time copy-on-write reachable (two requests
    with the same prompt share its final, partially-filled page until one
    of them decodes into it).

    The cache is an index only — refcounts and the free list live on the
    :class:`PagePool`, which consults the index on allocation (LRU leaf
    eviction of unreferenced pages) and on release (parking).
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._root = _PrefixNode(b"", -1, None)
        self._by_page: dict[int, _PrefixNode] = {}
        self._clock = 0

    def __contains__(self, page: int) -> bool:
        return page in self._by_page

    def __len__(self) -> int:
        return len(self._by_page)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _walk(self, toks: np.ndarray, depth: int) -> Optional[_PrefixNode]:
        """The node spelling ``toks[:depth * page_size]`` (root for 0)."""
        ps, cur = self.page_size, self._root
        for i in range(depth):
            cur = cur.children.get(toks[i * ps:(i + 1) * ps].tobytes())
            if cur is None:
                return None
        return cur

    def match(self, toks: np.ndarray) -> tuple[list[int], int]:
        """Longest cached prefix of ``toks``: (page ids, tokens covered).

        Walks full-page chunks, then tries one partial step: a child whose
        content begins with the ENTIRE remaining prompt extends the match
        to ``len(toks)`` (the sharer's tail page covers our last tokens).
        Matched nodes get their LRU stamp bumped.
        """
        toks = np.ascontiguousarray(toks, np.int32)
        total, ps = len(toks), self.page_size
        cur, pages, k = self._root, [], 0
        while (k + 1) * ps <= total:
            child = cur.children.get(toks[k * ps:(k + 1) * ps].tobytes())
            if child is None:
                break
            child.last_use = self._tick()
            pages.append(child.page)
            cur, k = child, k + 1
        rem = total - k * ps
        if rem > 0:
            pre = toks[k * ps:].tobytes()
            cands = [c for c in cur.children.values()
                     if c.key.startswith(pre)]
            if cands:
                best = max(cands, key=lambda c: c.last_use)
                best.last_use = self._tick()
                pages.append(best.page)
                return pages, total
        return pages, k * ps

    def insert(self, toks: np.ndarray, depth: int, page: int) -> bool:
        """Register ``page`` as chunk ``depth`` of sequence ``toks``.

        First registration wins: an existing node for the same chunk (from
        another request that computed the same prefix) keeps its page and
        only gets an LRU bump.  Returns True iff ``page`` was registered.
        Registration requires the parent chain to exist (chunks register
        in order, so it does — unless an unregistered ancestor blocked it).
        """
        toks = np.ascontiguousarray(toks, np.int32)
        ps = self.page_size
        if (depth + 1) * ps > len(toks) or page in self._by_page:
            return False
        cur = self._walk(toks, depth)
        if cur is None:
            return False
        key = toks[depth * ps:(depth + 1) * ps].tobytes()
        node = cur.children.get(key)
        if node is not None:
            node.last_use = self._tick()
            return False
        node = _PrefixNode(key, int(page), cur)
        node.last_use = self._tick()
        cur.children[key] = node
        self._by_page[int(page)] = node
        return True

    def touch(self, page: int) -> None:
        """Refresh a cached page's LRU timestamp (prefix re-match)."""
        node = self._by_page.get(page)
        if node is not None:
            node.last_use = self._tick()

    def forget(self, page: int) -> list[int]:
        """Unregister ``page`` AND its whole subtree (descendants spell
        longer sequences through the mutated page — their chain is broken).
        Returns every unregistered page id, ``page`` first."""
        node = self._by_page.get(page)
        if node is None:
            return []
        del node.parent.children[node.key]
        dropped, stack = [], [node]
        while stack:
            nd = stack.pop()
            dropped.append(nd.page)
            del self._by_page[nd.page]
            stack.extend(nd.children.values())
        return dropped

    def evict_leaves(self, n: int, evictable: Callable[[int], bool]
                     ) -> list[int]:
        """Drop up to ``n`` LRU *leaf* nodes whose page passes ``evictable``
        (the pool passes "refcount == 0").  Leaf-first keeps every surviving
        chain matchable; freeing a parent would orphan its descendants.

        One scan seeds a heap of current leaves; evicting a node can only
        expose its parent as the next candidate, so the loop stays linear
        instead of rescanning the whole index per page."""
        heap = [(nd.last_use, nd.page) for nd in self._by_page.values()
                if not nd.children]
        heapq.heapify(heap)
        dropped: list[int] = []
        while heap and len(dropped) < n:
            stamp, page = heapq.heappop(heap)
            nd = self._by_page.get(page)
            if nd is None or nd.children or not evictable(page):
                continue
            if nd.last_use != stamp:        # touched since seeding: re-queue
                heapq.heappush(heap, (nd.last_use, page))
                continue
            parent = nd.parent
            del parent.children[nd.key]
            del self._by_page[page]
            dropped.append(page)
            if parent is not self._root and not parent.children:
                heapq.heappush(heap, (parent.last_use, parent.page))
        return dropped


class PagePool:
    """Refcounted fixed-size KV pages with a FIFO free list.

    One extra *trash* page (index ``num_pages``) is always allocated so
    batched decode can keep dead slots in the SPMD step: their token writes
    land in the trash page instead of corrupting a live one.

    With ``prefix_cache=True`` the pool carries a :class:`PrefixCache`:
    pages whose refcount drops to zero while registered are *parked* in the
    cache (evictable LRU) instead of returning to the free list, and
    :meth:`alloc` transparently evicts parked pages when the free list runs
    short.  ``pages_free + pages_cached + pages_in_use == num_pages``
    always — the partition the property tests check.
    """

    def __init__(self, leaf_specs, *, num_pages: int, page_size: int,
                 shardings=None, prefix_cache: bool = False):
        """``shardings``: optional pytree of ``jax.sharding.Sharding``
        matching ``leaf_specs`` — mesh serving materializes the KV storage
        already partitioned (heads over the "model" axis) so no leaf ever
        exists unsharded on one device."""
        assert num_pages >= 1 and page_size >= 1
        self.leaf_specs = leaf_specs
        self.num_pages = num_pages
        self.page_size = page_size
        self.trash_page = num_pages            # valid index, never allocated
        self._shardings = shardings
        self.storage = self._fresh_storage()
        self._free: deque[int] = deque(range(num_pages))
        self._free_set: set[int] = set(self._free)
        self._ref = np.zeros(num_pages, np.int64)
        self.prefix = PrefixCache(page_size) if prefix_cache else None
        self._n_cached = 0
        self.evictions = 0
        self._high_water = 0

    def _fresh_storage(self):
        def zeros(s):
            return jnp.zeros(
                s.storage_shape(self.num_pages + N_TRASH, self.page_size),
                s.dtype)
        if self._shardings is None:
            return jax.tree_util.tree_map(zeros, self.leaf_specs,
                                          is_leaf=_is_spec)
        return jax.tree_util.tree_map(
            lambda s, sh: jax.device_put(zeros(s), sh),
            self.leaf_specs, self._shardings, is_leaf=_is_spec)

    def storage_deleted(self) -> bool:
        """True if any storage buffer was consumed (a jitted call with
        donation that raised after taking its arguments)."""
        return tree_deleted(self.storage)

    def reset_storage(self) -> None:
        """Rebuild zeroed storage with the original shapes/shardings.  The
        KV *contents* are gone — callers must evict every resident request
        first (recompute-style re-prefill preserves their streams); the
        prefix cache is flushed for the same reason (its entries point at
        content that no longer exists)."""
        self.storage = self._fresh_storage()
        self.flush_cache()

    def flush_cache(self) -> None:
        """Drop every prefix-cache entry.  Parked (unreferenced) pages
        return to the free list; held pages just lose their registration."""
        if self.prefix is None:
            return
        cached = list(self.prefix._by_page)
        self.prefix = PrefixCache(self.page_size)
        for p in cached:
            if self._ref[p] == 0:
                self._free_push(p)
        self._n_cached = 0

    # -- host-side accounting -------------------------------------------------

    @property
    def pages_free(self) -> int:
        """Pages on the free list (unreferenced, unregistered)."""
        return len(self._free)

    @property
    def pages_cached(self) -> int:
        """Registered pages no request holds (LRU eviction candidates)."""
        return self._n_cached

    @property
    def pages_in_use(self) -> int:
        """Pages referenced by at least one slot (free + cached excluded)."""
        return self.num_pages - len(self._free) - self._n_cached

    @property
    def high_water(self) -> int:
        """Max pages simultaneously referenced since construction."""
        return self._high_water

    def ref(self, page: int) -> int:
        """Current refcount of one page."""
        return int(self._ref[page])

    def _free_push(self, page: int) -> None:
        self._free.append(int(page))
        self._free_set.add(int(page))

    def _note_usage(self) -> None:
        self._high_water = max(self._high_water, self.pages_in_use)

    def alloc(self, n: int) -> Optional[list[int]]:
        """Pop ``n`` exclusive pages (refcount 1), or None if exhausted
        (allocate-all-or-nothing).  When the free list runs short, LRU
        unreferenced cached pages are evicted to cover the shortfall."""
        if n < 0:
            return None
        if len(self._free) < n and self.prefix is not None:
            dropped = self.prefix.evict_leaves(
                n - len(self._free), lambda p: self._ref[p] == 0)
            for p in dropped:
                self._n_cached -= 1
                self._free_push(p)
            self.evictions += len(dropped)
        if len(self._free) < n:
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._free_set.discard(p)
            self._ref[p] = 1
        self._note_usage()
        return pages

    def incref(self, pages) -> None:
        """Take a reference on already-registered pages (a prefix match).
        Unreferenced cached pages move from the cache partition to held."""
        for p in pages:
            p = int(p)
            assert 0 <= p < self.num_pages, p
            if self._ref[p] == 0:
                if self.prefix is None or p not in self.prefix:
                    raise ValueError(
                        f"incref of page {p} that is neither held nor cached")
                self._n_cached -= 1
            self._ref[p] += 1
        self._note_usage()

    def decref(self, pages) -> None:
        """Drop one reference per page.  A page reaching refcount zero is
        parked in the prefix cache if registered (it stays matchable and
        becomes an LRU eviction candidate), else returned to the free list.
        Decref below zero raises — the refcount twin of a double free."""
        for p in pages:
            p = int(p)
            if not 0 <= p < self.num_pages:
                raise ValueError(f"decref of invalid page id {p}")
            if self._ref[p] <= 0:
                raise ValueError(
                    f"decref of page {p} below zero (double release)")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                if self.prefix is not None and p in self.prefix:
                    self._n_cached += 1
                    self.prefix.touch(p)
                else:
                    self._free_push(p)

    def unregister(self, page: int) -> None:
        """Drop ``page`` (and any cached descendants) from the prefix index
        — the write-in-place path when its single holder is about to mutate
        it.  Unreferenced descendants return to the free list."""
        if self.prefix is None:
            return
        for q in self.prefix.forget(page):
            if self._ref[q] == 0:
                self._n_cached -= 1
                self._free_push(q)

    def free(self, pages) -> None:
        """Return exclusively-held pages to the free list.  Freeing a page
        already on the free list, or one still shared (refcount > 1),
        raises instead of silently corrupting the FIFO order — release
        paths must go through :meth:`decref`."""
        for p in pages:
            p = int(p)
            if not 0 <= p < self.num_pages:
                raise ValueError(f"free of invalid page id {p}")
            if p in self._free_set:
                raise ValueError(f"double free of page {p}")
            if self._ref[p] > 1:
                raise ValueError(
                    f"free of page {p} with refcount {int(self._ref[p])}; "
                    "shared pages are released via decref")
            if self.prefix is not None and p in self.prefix:
                if self._ref[p] == 0:       # was parked in the cache
                    self._n_cached -= 1
                for q in self.prefix.forget(p):
                    if q != p and self._ref[q] == 0:    # orphaned descendants
                        self._n_cached -= 1
                        self._free_push(q)
            self._ref[p] = 0
            self._free_push(p)

    def tokens_capacity(self) -> int:
        """Total token positions the pool can hold."""
        return self.num_pages * self.page_size


class CrossKVPool(PagePool):
    """Refcounted pages for encoder–decoder *cross-attention* K/V.

    Whisper-style serving computes each request's cross K/V exactly once
    (from the audio encoder's output) and then only ever *reads* it during
    decode — so this pool is a deliberately narrowed :class:`PagePool`:

    * **Read-only after prefill.** Pages are written once by the encode
      path's scatter and never mutated, so copy-on-write never applies and
      the pool refuses a prefix cache (cross K/V is keyed by audio content,
      not by token prefixes — the radix index would never match it).
    * **Refcounts still matter.** Release and preemption go through the
      same ``alloc`` / ``decref`` / ``free`` lifecycle as self-attention
      pages, so the conservation invariant ``pages_free + pages_in_use ==
      num_pages`` holds under forced preemption (property-tested).
    * **Quantization composes.** int8 cross pages carry per-(row, head)
      scale leaves exactly like self-attention pages
      (:func:`repro.serve.quant.quantize_leaf_specs`); the decode-time
      gather dequantizes after the read.

    The trash page exists here too: dead decode slots point their cross
    page table at it (with ``frames_len = 0`` masking the whole read).
    """

    def __init__(self, leaf_specs, *, num_pages: int, page_size: int,
                 shardings=None, prefix_cache: bool = False):
        if prefix_cache:
            raise ValueError(
                "CrossKVPool does not support a prefix cache: cross K/V is "
                "content-addressed by audio, not by token prefixes")
        super().__init__(leaf_specs, num_pages=num_pages,
                         page_size=page_size, shardings=shardings,
                         prefix_cache=False)


@dataclasses.dataclass
class KVHandoff:
    """A completed prefill's KV in flight between two pools — the unit of
    disaggregated prefill/decode transfer (:mod:`repro.serve.disagg`).

    The prefiller gathers the request's pages into a contiguous chunk
    (``kv``: one leaf per pool leaf, shaped ``prefix + (n * page_size,) +
    suffix`` — int8 payloads travel WITH their scale leaves, since scales
    are ordinary pool leaves), takes one extra reference per source page,
    and releases the slot.  The held references pin the source pages —
    they may stay registered in the prefiller's prefix cache and be
    re-shared by later admissions, but can never be evicted or reallocated
    — until the decoder has scattered the chunk into its own pool and the
    coordinator calls :meth:`release`.  ``release`` is idempotent: the
    in-flight references are dropped exactly once, so a retry loop that
    races a preemption can never double-free.
    """
    req: Any                    # the Request, with its first token appended
    length: int                 # prefilled positions (first token excluded)
    kv: Any                     # gathered storage pytree (see above)
    pages: list                 # source page ids holding the in-flight refs
    pool: Any                   # source PagePool
    released: bool = False

    def release(self) -> None:
        """Drop the handoff's page references (idempotent)."""
        if self.released:
            return
        self.released = True
        self.pool.decref(self.pages)


# ---------------------------------------------------------------------------
# Pure device ops (jit-safe; storage in, storage out)
# ---------------------------------------------------------------------------

def _pfx(n_prefix: int) -> tuple:
    return (slice(None),) * n_prefix


def _check_write_dtype(storage, vals, op: str):
    """Scatter writes must arrive already in the pool's storage dtype.

    The old behavior silently ``.astype``'d the values — which turned a
    missing quantization step (f32 K/V written into an int8 pool) or a
    precision mismatch (f32 into bf16) into wrong cached numbers with no
    error.  Conversions are now explicit at the call site: the model
    writes K/V in the cache dtype, and a quantization policy produces the
    int8 payload + scale before the scatter.  Dtypes are static under
    ``jit``, so this raises at trace time, not per step.
    """
    if jnp.dtype(vals.dtype) != jnp.dtype(storage.dtype):
        raise TypeError(
            f"{op}: value dtype {jnp.dtype(vals.dtype).name} != storage "
            f"dtype {jnp.dtype(storage.dtype).name}; convert (or quantize) "
            "explicitly before the scatter — implicit lossy casts are not "
            "performed")


def scatter_chunk(storage, pages, chunk, *, page_size: int, n_prefix: int = 0):
    """Write a page-aligned token chunk into its pages.

    storage: (prefix..., N, page_size, suffix...)
    pages:   (n,) int32 page ids
    chunk:   (prefix..., n * page_size, suffix...) — in the storage dtype
    """
    _check_write_dtype(storage, chunk, "scatter_chunk")
    n = pages.shape[0]
    pre = chunk.shape[:n_prefix]
    suf = chunk.shape[n_prefix + 1:]
    blk = chunk.reshape(pre + (n, page_size) + suf)
    idx = _pfx(n_prefix) + (pages,)
    return storage.at[idx].set(blk)


def scatter_token(storage, pages, offs, vals, *, n_prefix: int = 0):
    """Write one token per slot at (page, offset) — the decode-step write.

    storage: (prefix..., N, page_size, suffix...)
    pages, offs: (B,) int32;   vals: (prefix..., B, suffix...) — in the
    storage dtype
    """
    _check_write_dtype(storage, vals, "scatter_token")
    idx = _pfx(n_prefix) + (pages, offs)
    return storage.at[idx].set(vals)


def scatter_window(storage, pages, offs, vals, *, n_prefix: int = 0):
    """Write a per-slot window of tokens at (page, offset) pairs — the
    speculative-verify write (C candidate positions per slot committed in
    one scatter; pad / dead positions point at the trash page).

    storage: (prefix..., N, page_size, suffix...)
    pages, offs: (B, C) int32;   vals: (prefix..., B, C, suffix...)
    """
    B, C = pages.shape
    pre = vals.shape[:n_prefix]
    suf = vals.shape[n_prefix + 2:]
    flat = vals.reshape(pre + (B * C,) + suf)
    return scatter_token(storage, pages.reshape(-1), offs.reshape(-1), flat,
                         n_prefix=n_prefix)


def gather_pages(storage, tables, *, n_prefix: int = 0):
    """Gather each slot's pages back into a contiguous view.

    storage: (prefix..., N, page_size, suffix...);  tables: (B, P) int32
    -> (prefix..., B, P * page_size, suffix...)
    """
    B, P = tables.shape
    idx = _pfx(n_prefix) + (tables,)
    g = storage[idx]                  # (prefix..., B, P, page_size, suffix...)
    pre = g.shape[:n_prefix]
    suf = g.shape[n_prefix + 3:]
    return g.reshape(pre + (B, P * storage.shape[n_prefix + 1]) + suf)


def copy_pages(storage, leaf_specs, src, dst):
    """Copy whole pages ``src[i] -> dst[i]`` in every leaf — the
    copy-on-write device op.  ``src``/``dst``: (n,) int32 page ids; sources
    are read before any destination is written (XLA gather then scatter),
    so disjoint copies from one shared source are safe in a single call.
    """
    def leaf(st, spec):
        n = len(spec.prefix)
        return st.at[_pfx(n) + (dst,)].set(st[_pfx(n) + (src,)])

    return jax.tree_util.tree_map(leaf, storage, leaf_specs)


# ---------------------------------------------------------------------------
# Dense per-slot state store (the degenerate "one page per slot" layout)
# ---------------------------------------------------------------------------

def write_slot(state, slot_state, slot: int):
    """Write a (B=1) prefill state into slot ``slot`` of the batched state.

    The dense-path replacement for splice-by-``dynamic_update_slice``: every
    leaf has batch on axis 1 (stacked caches and recurrent O(1) states
    alike); a leaf with a sequence axis (axis 2) shorter than the slot's
    is zero-padded — the validity length masks the tail.
    """
    def leaf(dst, src):
        if src.ndim >= 3 and src.shape[2] < dst.shape[2]:
            pad = [(0, 0)] * src.ndim
            pad[2] = (0, dst.shape[2] - src.shape[2])
            src = jnp.pad(src, pad)
        return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))

    return jax.tree_util.tree_map(leaf, state, slot_state)
