"""Traffic harness: seeded arrival processes driving a serving engine.

Every throughput number before this module came from "submit N requests,
run until drained" — no arrival process, so no queueing delay, no TTFT
distribution, no SLO.  This is the load-generation half of the measurement
story (the metric half is :mod:`repro.serve.metrics`):

* :func:`make_workload` — a deterministic, seeded workload: Poisson or
  bursty arrivals, shared-prefix chat sessions (``n_sessions`` system
  prompts drawn once, requests appending their own tails — the pattern the
  prefix cache exists for), and a mixed prompt-length distribution
  (weighted uniform bands, defaulting to mostly-short-some-long).
* :class:`TrafficHarness` — drives any engine with the monolithic
  interface (``submit`` / ``tick`` / per-request ``output`` + ``done_at``),
  so the monolithic :class:`~repro.serve.engine.ServeEngine` and the
  disaggregated :class:`~repro.serve.disagg.DisaggServeEngine` measure
  under identical load.  It emits a flat event log — ``submit`` at the
  request's *arrival* time (so TTFT includes queueing delay), ``tokens``
  whenever a tracked request's output grew during a tick, ``done`` on
  retirement — which :func:`repro.serve.metrics.compute_report` folds into
  the report.
* clocks — the :class:`VirtualClock` advances one time unit per engine
  tick and fast-forwards idle gaps, making the entire run (schedule,
  event log, report) a deterministic function of the seed: the property
  CI gates depend on.  The :class:`WallClock` measures real seconds for
  on-hardware numbers; arrivals become offsets from the run start.
* traces — :func:`record_trace` / :func:`workload_from_trace` serialize a
  run (workload + events + token streams) to a JSON-safe dict and rebuild
  the workload from it, so a recorded run replays bit-identically under
  the virtual clock.
"""

from __future__ import annotations

__all__ = ["DEFAULT_LEN_MIX", "TrafficHarness", "TrafficRequest",
           "VirtualClock", "WallClock", "bursty_arrivals",
           "make_workload", "poisson_arrivals", "record_trace",
           "run_traffic", "workload_from_trace"]

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.serve import metrics as MT

# mostly short prompts with a long tail — (weight, lo, hi) inclusive bands
DEFAULT_LEN_MIX = ((3.0, 4, 24), (1.0, 32, 72))


@dataclasses.dataclass
class TrafficRequest:
    """One generated request: arrival time plus everything ``submit`` needs."""
    arrival: float
    prompt: np.ndarray
    max_new_tokens: int = 16
    session: int = -1               # -1: no shared prefix
    seed: Optional[int] = None      # per-request sampling seed (None: greedy)
    encoder_input: Optional[np.ndarray] = None
    #                                 (n, d_model) float32 encoder payload
    #                                 (image-patch embeds / audio frames);
    #                                 None keeps the request text-only

    def to_dict(self) -> dict:
        """JSON-safe dict; float32 payloads survive the round trip exactly."""
        d = {"arrival": float(self.arrival),
             "prompt": [int(t) for t in self.prompt],
             "max_new_tokens": int(self.max_new_tokens),
             "session": int(self.session),
             "seed": None if self.seed is None else int(self.seed)}
        if self.encoder_input is not None:
            # float32 -> Python float (double) -> JSON -> float32 is exact
            # in both directions, so a replayed trace carries bit-identical
            # payloads (and the prefix cache re-keys identically)
            d["encoder_input"] = [
                [float(x) for x in row]
                for row in np.asarray(self.encoder_input, np.float32)]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TrafficRequest":
        """Inverse of :meth:`to_dict`."""
        enc = d.get("encoder_input")
        return cls(arrival=float(d["arrival"]),
                   prompt=np.asarray(d["prompt"], np.int32),
                   max_new_tokens=int(d["max_new_tokens"]),
                   session=int(d.get("session", -1)),
                   seed=d.get("seed"),
                   encoder_input=None if enc is None
                   else np.asarray(enc, np.float32))


# -- arrival processes -------------------------------------------------------

def poisson_arrivals(n: int, rate: float, rng) -> np.ndarray:
    """Cumulative exponential inter-arrivals: the memoryless process every
    open-loop serving benchmark assumes.  ``rate`` is requests per time
    unit (ticks for the virtual clock)."""
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def bursty_arrivals(n: int, rate: float, rng, *, burst: int = 4) -> np.ndarray:
    """Same long-run rate as Poisson, but requests arrive in bursts of
    ``burst`` at Poisson-distributed burst starts — the thundering-herd
    shape that stresses admission and preemption."""
    starts = np.cumsum(rng.exponential(burst / rate,
                                       size=-(-n // burst)))
    return np.repeat(starts, burst)[:n]


def _mixed_lengths(n: int, rng, len_mix) -> np.ndarray:
    w = np.asarray([m[0] for m in len_mix], np.float64)
    comp = rng.choice(len(len_mix), size=n, p=w / w.sum())
    return np.asarray([int(rng.integers(len_mix[c][1], len_mix[c][2] + 1))
                       for c in comp], np.int64)


def make_workload(*, kind: str = "poisson", n_requests: int, rate: float,
                  vocab: int, seed: int = 0, max_new_tokens: int = 16,
                  shared_prefix_len: int = 16, n_sessions: int = 4,
                  len_mix=DEFAULT_LEN_MIX, burst: int = 4,
                  seeded_sampling: bool = False,
                  encoder: Optional[str] = None,
                  encoder_shape: Optional[tuple] = None,
                  encoder_frac: float = 1.0,
                  n_encoder_inputs: int = 4) -> list[TrafficRequest]:
    """A fully deterministic workload: every random draw comes from one
    ``np.random.default_rng(seed)`` in a fixed order, so the same
    arguments always produce the identical request schedule.

    ``encoder`` opens the multimodal band: ``"image"`` or ``"audio"``
    attaches an ``(n, d_model)`` float32 payload of shape
    ``encoder_shape`` to a fraction ``encoder_frac`` of requests, drawn
    from a pool of ``n_encoder_inputs`` distinct payloads.  A session-
    bound request always reuses its session's payload — the repeated-image
    chat pattern VLM prefix caching exists for.  ``encoder=None`` (the
    default) makes NO extra rng draws, so every pre-existing argument
    combination keeps its exact request schedule."""
    rng = np.random.default_rng(seed)
    if kind == "poisson":
        arrivals = poisson_arrivals(n_requests, rate, rng)
    elif kind == "bursty":
        arrivals = bursty_arrivals(n_requests, rate, rng, burst=burst)
    else:
        raise ValueError(
            f"unknown arrival kind {kind!r}; want 'poisson' or 'bursty' "
            "(replay a recorded trace via workload_from_trace)")
    prefixes = []
    if shared_prefix_len > 0 and n_sessions > 0:
        prefixes = [rng.integers(0, vocab, size=shared_prefix_len)
                    for _ in range(n_sessions)]
    lengths = _mixed_lengths(n_requests, rng, len_mix)
    enc_pool = []
    if encoder is not None:
        if encoder not in ("image", "audio"):
            raise ValueError(
                f"unknown encoder kind {encoder!r}; want 'image' or "
                "'audio'")
        if encoder_shape is None or len(encoder_shape) != 2:
            raise ValueError(
                "encoder workloads need encoder_shape=(n, d_model) — "
                "n_image_tokens/n_audio_frames by the model's d_model")
        enc_pool = [rng.standard_normal(encoder_shape).astype(np.float32)
                    for _ in range(max(1, n_encoder_inputs))]
    out = []
    for i in range(n_requests):
        sess = int(rng.integers(0, n_sessions)) if prefixes else -1
        tail = rng.integers(0, vocab, size=int(lengths[i]))
        prompt = (np.concatenate([prefixes[sess], tail]) if sess >= 0
                  else tail).astype(np.int32)
        enc = None
        if enc_pool:
            carry = bool(rng.random() < encoder_frac)
            idx = (sess % len(enc_pool)) if sess >= 0 \
                else int(rng.integers(0, len(enc_pool)))
            if carry:
                enc = enc_pool[idx]
        out.append(TrafficRequest(
            arrival=float(arrivals[i]), prompt=prompt,
            max_new_tokens=max_new_tokens, session=sess,
            seed=i if seeded_sampling else None,
            encoder_input=enc))
    return out


# -- clocks ------------------------------------------------------------------

class VirtualClock:
    """Deterministic time: one engine tick = ``tick_time`` units; idle gaps
    fast-forward to the next arrival instead of spinning."""

    def __init__(self, tick_time: float = 1.0):
        self.now = 0.0
        self.tick_time = tick_time

    def after_tick(self) -> float:
        """Advance one tick; returns the new time."""
        self.now += self.tick_time
        return self.now

    def fast_forward(self, t: float) -> None:
        """Jump ahead to ``t`` (never backwards)."""
        self.now = max(self.now, t)


class WallClock:
    """Real seconds since the run started; arrivals are offsets from it."""

    def __init__(self):
        self._t0 = time.perf_counter()

    @property
    def now(self) -> float:
        """Seconds since construction."""
        return time.perf_counter() - self._t0

    def after_tick(self) -> float:
        """Wall time advances on its own; just report it."""
        return self.now

    def fast_forward(self, t: float) -> None:
        """Sleep (briefly) towards ``t``; the caller re-checks in a loop."""
        dt = t - self.now
        if dt > 0:
            time.sleep(min(dt, 0.05))       # re-checked by the caller's loop


# -- the harness -------------------------------------------------------------

class TrafficHarness:
    """Open-loop load driver: submit requests when their arrival time comes
    (never earlier — queueing delay is part of the measurement), tick the
    engine, and record the event log."""

    def __init__(self, engine, *, clock: str = "virtual",
                 tick_time: float = 1.0):
        self.engine = engine
        self.clock_kind = clock
        if clock == "virtual":
            self.clock = VirtualClock(tick_time)
        elif clock == "wall":
            self.clock = WallClock()
        else:
            raise ValueError(f"unknown clock {clock!r}; want 'virtual' or "
                             "'wall'")
        self.events: list[dict] = []

    def _submit_queue(self) -> list:
        # the queue new submissions land on — the prefiller's for a
        # disaggregated engine
        eng = getattr(self.engine, "prefiller", self.engine)
        return eng.sched.queue

    def _engine_busy(self) -> bool:
        if hasattr(self.engine, "has_work"):
            return self.engine.has_work()
        return self.engine.sched.has_work()

    def run(self, workload, *, max_ticks: int = 100_000) -> list[dict]:
        """Drive the engine through the workload; returns the event log."""
        work = sorted(workload, key=lambda r: r.arrival)
        events = self.events = []
        track: dict[int, dict] = {}
        i = 0
        for _ in range(max_ticks):
            if i >= len(work) and all(t["done"] for t in track.values()):
                break
            if (i < len(work) and work[i].arrival > self.clock.now
                    and not self._engine_busy()):
                self.clock.fast_forward(work[i].arrival)
            while i < len(work) and work[i].arrival <= self.clock.now:
                tr = work[i]
                # encoder payloads ride as an OPTIONAL kwarg so text-only
                # submissions (and engines without the parameter, like the
                # disaggregated pair) see the exact pre-multimodal call
                kw = {} if tr.encoder_input is None \
                    else {"encoder_input": tr.encoder_input}
                rid = self.engine.submit(tr.prompt,
                                         max_new_tokens=tr.max_new_tokens,
                                         seed=tr.seed, **kw)
                req = self._submit_queue()[-1]
                assert req.rid == rid
                track[rid] = {"req": req, "seen": 0, "done": False}
                ev = {"t": float(tr.arrival), "rid": rid,
                      "kind": "submit",
                      "prompt_len": int(len(tr.prompt)),
                      "session": int(tr.session)}
                if tr.encoder_input is not None:
                    ev["encoder_len"] = int(len(tr.encoder_input))
                events.append(ev)
                i += 1
            self.engine.tick()
            now = self.clock.after_tick()
            for rid, tr in track.items():
                if tr["done"]:
                    continue
                n_new = len(tr["req"].output) - tr["seen"]
                if n_new > 0:
                    events.append({"t": now, "rid": rid, "kind": "tokens",
                                   "n": n_new})
                    tr["seen"] += n_new
                if tr["req"].done_at is not None:
                    tr["done"] = True
                    events.append({"t": now, "rid": rid, "kind": "done",
                                   "error": tr["req"].error is not None})
        return events

    def outputs(self) -> dict:
        """Token stream per rid from the engine's finished list."""
        return {int(r.rid): [int(t) for t in r.output]
                for r in self.engine.finished}


def run_traffic(engine, workload, *, clock: str = "virtual",
                tick_time: float = 1.0, slo: Optional[dict] = None,
                max_ticks: int = 100_000) -> dict:
    """One harness run end to end: events, token streams, metric report."""
    h = TrafficHarness(engine, clock=clock, tick_time=tick_time)
    events = h.run(workload, max_ticks=max_ticks)
    return {"events": events, "outputs": h.outputs(),
            "report": MT.compute_report(events, slo=slo)}


# -- trace record / replay ---------------------------------------------------

def record_trace(workload, events, outputs) -> dict:
    """A JSON-safe record of one run: replaying its workload under the
    virtual clock reproduces ``events`` and ``outputs`` bit-identically."""
    return {"version": 1,
            "workload": [r.to_dict() for r in workload],
            "events": list(events),
            "outputs": {str(rid): [int(t) for t in toks]
                        for rid, toks in outputs.items()}}


def workload_from_trace(trace: dict) -> list[TrafficRequest]:
    """Rebuild the exact workload a :func:`record_trace` dict captured."""
    return [TrafficRequest.from_dict(d) for d in trace["workload"]]
