"""Quantization policies for serving: int8 KV pages and int8 serve params.

KV bytes/token is the binding constraint on serving concurrency — every
bench since the pool landed is "at equal KV budget" — so halving bytes per
token is a direct ~2x on concurrent users.  This module supplies the
*policy objects* that make that happen without the numerics leaking into
model code (the paper's function-centric rule: data-representation
transforms belong in the orchestration layer, threaded through as
functions, the way MaxText threads an ``AqtQuantization`` object through
every layer):

* :class:`Int8KVQuant` — per-token-row, per-head symmetric int8 for the
  paged KV cache.  ``quantize`` maps a K/V block ``(..., Hkv, D)`` to an
  int8 block plus an f32 scale of shape ``(..., Hkv)`` (the D axis is
  reduced away); ``dequantize`` inverts it.  Both delegate to
  :mod:`repro.optim.compress` — one quantization module, two consumers
  (gradient all-reduce and the KV path).
* :func:`quantize_leaf_specs` — grows a model's paged-KV leaf-spec tree
  with a sibling ``*_scale`` leaf per KV leaf.  The scales are ORDINARY
  pool leaves (``prefix + (num_pages, page_size) + (Hkv,)``), so every
  page-granular mechanism — content addressing, refcounts, copy-on-write,
  prefix-cache parking, preemption replay — moves the scales with their
  pages for free, and under tensor-parallel serving the head axis shards
  over "model" exactly like the KV leaves.
* weights-only int8: :func:`quantize_params` / :func:`dequantize_params` /
  :func:`quantize_param_specs` — per-tensor symmetric int8 for the serve
  params, dequantized on apply inside the jitted serving calls.  The
  scalar scale replicates under any TP layout while the int8 payload keeps
  the weight's original partition spec, so quantize-then-shard equals
  shard-then-quantize and tp=N streams stay equal to tp=1.

Accuracy is gated by greedy token-match rate, not bit-parity: int8 KV
changes logits, so the contract is "the quantized stream agrees with the
full-precision stream on >= 95% of greedy tokens" (tests + bench), while
quant-on streams stay BIT-identical across prefix-cache on/off, COW,
preemption and tp — the pages hold the same int8 content either way.
"""

from __future__ import annotations

__all__ = ["Int8KVQuant", "SCALE_SUFFIX", "dequantize_params",
           "kv_bytes_per_token", "make_kv_quant", "quantize_leaf_specs",
           "quantize_param_specs", "quantize_params"]

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.optim.compress import int8_compress, int8_decompress

SCALE_SUFFIX = "_scale"


class Int8KVQuant:
    """Per-(token, head) symmetric int8 for paged KV blocks.

    The scale axis layout is chosen so a written block's scale scatters
    through the SAME pure page ops as its values: quantizing a K/V block
    of shape ``(..., Hkv, D)`` reduces only the trailing D axis, leaving a
    ``(..., Hkv)`` scale whose leading axes line up with the value block's
    token axes.  Per-row scales also make appends exact — a new token
    never re-scales previously written rows, which is what keeps streams
    bit-identical across prefix-cache sharing and COW.
    """

    name = "int8"
    storage_dtype = jnp.dtype(jnp.int8)
    scale_dtype = jnp.dtype(jnp.float32)

    def quantize(self, block):
        """(..., Hkv, D) -> (int8 (..., Hkv, D), f32 scale (..., Hkv))."""
        return int8_compress(block, axis=-1)

    def dequantize(self, q, scale, dtype=jnp.float32):
        """int8 rows + per-row scale -> ``dtype`` values."""
        return int8_decompress(q, scale, axis=-1, dtype=dtype)


_KV_QUANTS = {"int8": Int8KVQuant}


def make_kv_quant(spec):
    """``None``/"off" -> None; "int8" -> :class:`Int8KVQuant`; a policy
    object (anything with quantize/dequantize/name) passes through."""
    if spec in (None, "off", False):
        return None
    if isinstance(spec, str):
        if spec not in _KV_QUANTS:
            raise ValueError(
                f"unknown kv_quant {spec!r}; known: "
                f"{sorted(_KV_QUANTS)} or 'off'")
        return _KV_QUANTS[spec]()
    if not (hasattr(spec, "quantize") and hasattr(spec, "dequantize")):
        raise ValueError(f"kv_quant policy {spec!r} lacks "
                         "quantize/dequantize")
    return spec


def quantize_leaf_specs(specs: dict, quant) -> dict:
    """Transform a flat ``{name: PagedLeafSpec}`` KV tree into its
    quantized layout: each leaf's dtype becomes the policy's storage dtype
    and a sibling ``{name}_scale`` leaf (same prefix, suffix minus the
    reduced trailing axis, scale dtype) carries the per-row scales."""
    from repro.serve.pages import PagedLeafSpec
    if quant is None:
        return specs
    if not isinstance(specs, dict):
        raise TypeError(f"quantized KV needs a dict leaf tree, got "
                        f"{type(specs).__name__}")
    out = {}
    for name, leaf in specs.items():
        if not leaf.suffix:
            raise ValueError(f"KV leaf {name!r} has no trailing axis to "
                             "reduce a scale over")
        out[name] = PagedLeafSpec(leaf.prefix, leaf.suffix,
                                  quant.storage_dtype)
        out[name + SCALE_SUFFIX] = PagedLeafSpec(
            leaf.prefix, leaf.suffix[:-1], quant.scale_dtype)
    return out


def kv_bytes_per_token(leaf_specs) -> int:
    """HBM bytes one cached token costs across every pool leaf (scale
    leaves included) — the quantity the equal-budget bench reports."""
    from repro.serve.pages import PagedLeafSpec

    def leaf(s):
        n = (int(np.prod(s.prefix, dtype=np.int64))
             * int(np.prod(s.suffix, dtype=np.int64)))
        return n * jnp.dtype(s.dtype).itemsize

    return int(sum(leaf(s) for s in jax.tree_util.tree_leaves(
        leaf_specs, is_leaf=lambda x: isinstance(x, PagedLeafSpec))))


# ---------------------------------------------------------------------------
# Weights-only int8 (serve params, dequant-on-apply)
# ---------------------------------------------------------------------------

def _weight_quantizable(a) -> bool:
    return (hasattr(a, "ndim") and a.ndim >= 2
            and jnp.issubdtype(a.dtype, jnp.floating))


def _is_q8(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q8", "s8"}


def quantize_params(params):
    """Per-tensor symmetric int8 for every float matrix in a param tree
    (vectors — norm scales, biases — stay as-is: negligible bytes, and
    their precision is what RMSNorm stability leans on).  Each quantized
    leaf becomes ``{"q8": int8, "s8": f32 scalar}``."""
    def leaf(a):
        if _weight_quantizable(a):
            q, s = int8_compress(a)
            return {"q8": q, "s8": s}
        return a

    return jax.tree_util.tree_map(leaf, params)


def dequantize_params(params, dtype=jnp.float32):
    """Inverse of :func:`quantize_params` — called INSIDE the jitted serve
    wrappers (dequant-on-apply), so the stored params stay int8 in HBM and
    the full-precision weights exist only transiently per call."""
    return jax.tree_util.tree_map(
        lambda x: int8_decompress(x["q8"], x["s8"], dtype=dtype)
        if _is_q8(x) else x,
        params, is_leaf=_is_q8)


def quantize_param_specs(specs, params):
    """Mirror a param PartitionSpec tree onto the quantized layout: the
    int8 payload keeps the weight's spec, the scalar scale replicates
    (``P()``) — sharding any axis of a per-tensor-scaled weight commutes
    with dequantization, which is what keeps tp=N equal to tp=1."""
    if isinstance(params, dict):
        return {k: quantize_param_specs(specs[k], params[k])
                for k in params}
    if _weight_quantizable(params):
        return {"q8": specs, "s8": P()}
    return specs
