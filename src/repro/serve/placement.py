"""Load-aware expert placement — the paper's §3.2 rebalancer applied to
experts instead of host tasks.

The serving engine measures per-expert routed-token counts (telemetry from
:func:`repro.models.moe.moe_apply_expert_parallel`); this module turns a
measured window into a :class:`PlacementPlan`:

* per-rank token **targets** come from the paper's
  :func:`repro.core.load_balance.find_optimal_workload` (uniform rank
  timings → the balanced ±1 split; measured per-rank seconds/token →
  timing-proportional targets on heterogeneous tiers),
* experts are assigned **greedily, hottest first**, to the rank with the
  largest remaining deficit that still has a free slot (each of the ``ep``
  ranks holds exactly ``E/ep`` physical expert slots),
* **hot-expert replication**: while a rank still exceeds its target, its
  hottest expert may claim a second slot from a zero-traffic expert on the
  most underloaded rank.  The replica pair splits the expert's capacity
  positions at a q8 fixed-point fraction (deterministic integer math, see
  ``PLACE_Q``); the combine simply sums, because each capacity row is
  computed exactly once regardless of which slot holds it.  The evicted
  zero-traffic expert keeps no weights — any future token routed to it is
  **dropped and counted** in the ``dropped`` telemetry, the same accounting
  as a capacity-factor drop.

A plan is applied between engine ticks as a pure permutation of the
expert-stacked weight leaves (:func:`apply_placement`) plus a (3, E)
dispatch map consumed inside the jitted step (a traced argument, so
re-placement never recompiles).  The identity plan reproduces the unplaced
integer slot indices exactly, keeping token streams bitwise unchanged.
"""

from __future__ import annotations

__all__ = ["PlacementPlan", "apply_placement", "identity_plan",
           "imbalance", "plan_placement"]

import dataclasses

import numpy as np

from repro.core.load_balance import find_optimal_workload
from repro.models.moe import PLACE_Q


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Expert → physical-slot assignment for ``ep`` expert-parallel ranks.

    Physical slot ``s`` lives on rank ``s // (n_experts // ep)`` and holds
    the weights of logical expert ``phys_expert[s]``.  Logical expert ``e``
    sends its first ``split_q[e] * C // PLACE_Q`` capacity positions to
    ``slot_a[e]`` and the rest to ``slot_b[e]`` (unsplit experts have
    ``slot_a == slot_b`` and ``split_q == 0``); ``-1`` slots mean the
    expert was evicted and its tokens are dropped + counted.
    """
    n_experts: int
    ep: int
    phys_expert: np.ndarray        # (E,) occupant of each physical slot
    slot_a: np.ndarray             # (E,) per logical expert
    slot_b: np.ndarray             # (E,)
    split_q: np.ndarray            # (E,) q8 fraction routed to slot_a

    def dispatch_arrays(self) -> np.ndarray:
        """(3, E) int32 [slot_a, slot_b, split_q] for the jitted dispatch."""
        return np.stack([self.slot_a, self.slot_b,
                         self.split_q]).astype(np.int32)

    def rank_loads(self, expert_tokens) -> np.ndarray:
        """(ep,) token load per rank if ``expert_tokens`` routed under this
        plan (replica splits approximated at the q8 fraction)."""
        counts = np.asarray(expert_tokens, np.int64).reshape(-1)
        spr = self.n_experts // self.ep
        loads = np.zeros(self.ep, np.int64)
        for e in range(self.n_experts):
            a, b = int(self.slot_a[e]), int(self.slot_b[e])
            if a < 0:
                continue
            na = int(counts[e]) * int(self.split_q[e]) // PLACE_Q
            loads[a // spr] += na
            loads[b // spr] += int(counts[e]) - na
        return loads


def identity_plan(n_experts: int, ep: int = 1) -> PlacementPlan:
    """Expert e in slot e, no replicas — bitwise-identical dispatch."""
    e = np.arange(n_experts, dtype=np.int64)
    return PlacementPlan(n_experts, ep, e.copy(), e.copy(), e.copy(),
                         np.zeros(n_experts, np.int64))


def imbalance(loads) -> float:
    """max/mean per-rank load; 1.0 (perfectly balanced) when idle."""
    loads = np.asarray(loads, np.float64)
    if loads.size == 0 or loads.sum() <= 0:
        return 1.0
    return float(loads.max() / loads.mean())


def plan_placement(expert_tokens, ep: int, *, rank_time_per_token=None,
                   replicate: bool = True) -> PlacementPlan:
    """Map measured per-expert token counts to a placement plan.

    ``rank_time_per_token``: optional (ep,) measured seconds/token per rank
    — fed to ``find_optimal_workload`` so slower ranks get proportionally
    smaller token targets (the paper's heterogeneous-farm rule).  ``None``
    means uniform ranks (balanced ±1 targets).

    Fully deterministic: ties break toward the lowest expert id / lowest
    rank (stable argsorts, first-max argmax).
    """
    counts = np.asarray(expert_tokens, np.int64).reshape(-1)
    E = counts.size
    if E == 0 or ep < 1 or E % ep:
        raise ValueError(f"n_experts={E} not divisible by ep={ep}")
    spr = E // ep
    total = int(counts.sum())

    times = (np.ones(ep) if rank_time_per_token is None
             else np.asarray(rank_time_per_token, np.float64))
    base = total // ep
    cur = np.full(ep, base, np.int64)
    cur[: total - base * ep] += 1
    targets = (find_optimal_workload(times, cur).astype(np.float64)
               if total else cur.astype(np.float64))

    # greedy LPT under per-rank slot budgets: hottest expert first, onto
    # the rank with the largest remaining deficit that has a free slot
    order = np.argsort(-counts, kind="stable")
    load = np.zeros(ep, np.float64)
    free = np.full(ep, spr, np.int64)
    phys_expert = np.full(E, -1, np.int64)
    slot_a = np.full(E, -1, np.int64)
    slot_b = np.full(E, -1, np.int64)
    split_q = np.zeros(E, np.int64)
    for e in order:
        deficit = targets - load
        deficit[free == 0] = -np.inf
        r = int(np.argmax(deficit))
        s = r * spr + int(spr - free[r])
        phys_expert[s] = e
        slot_a[e] = slot_b[e] = s
        load[r] += counts[e]
        free[r] -= 1

    if replicate and total:
        for _ in range(2 * E):
            r_hot = int(np.argmax(load))
            surplus = load[r_hot] - targets[r_hot]
            if surplus <= 0:
                break
            cand = [e for e in range(E)
                    if slot_a[e] >= 0 and slot_a[e] == slot_b[e]
                    and int(slot_b[e]) // spr == r_hot and counts[e] > 1]
            if not cand:
                break
            h = max(cand, key=lambda e: (counts[e], -e))
            # replica slot: a zero-traffic expert's slot on the most
            # underloaded rank — measured-hot experts are never evicted
            best = None
            for s in range(E):
                z = int(phys_expert[s])
                if (s // spr == r_hot or counts[z] != 0
                        or int(slot_a[z]) != s):
                    continue
                d = targets[s // spr] - load[s // spr]
                if best is None or d > best[0]:
                    best = (d, s)
            if best is None:
                # every zero-traffic expert sits on the hot rank (LPT packs
                # real traffic elsewhere first): swap one with the coldest
                # rank's smallest expert, then retry — pure permutation
                zeros = [e for e in range(E) if counts[e] == 0
                         and slot_a[e] >= 0 and slot_a[e] == slot_b[e]
                         and int(slot_a[e]) // spr == r_hot]
                order_r = np.argsort(load, kind="stable")
                r_cold = next((int(r) for r in order_r if r != r_hot), None)
                if not zeros or r_cold is None:
                    break
                small = [e for e in range(E)
                         if slot_a[e] >= 0 and slot_a[e] == slot_b[e]
                         and int(slot_a[e]) // spr == r_cold and e != h]
                if not small:
                    break
                z = min(zeros)
                w = min(small, key=lambda e: (counts[e], e))
                sz, sw = int(slot_a[z]), int(slot_a[w])
                slot_a[z] = slot_b[z] = sw
                slot_a[w] = slot_b[w] = sz
                phys_expert[sz], phys_expert[sw] = w, z
                load[r_hot] += counts[w]
                load[r_cold] -= counts[w]
                continue
            s_cold = best[1]
            r_cold = s_cold // spr
            move = min(surplus, targets[r_cold] - load[r_cold],
                       float(counts[h] - 1))
            if move < 1:
                break
            keep_frac = (counts[h] - move) / counts[h]
            q = int(np.clip(round(keep_frac * PLACE_Q), 1, PLACE_Q - 1))
            z = int(phys_expert[s_cold])
            slot_a[z] = slot_b[z] = -1                 # evicted
            phys_expert[s_cold] = h
            slot_b[h] = s_cold                         # overflow replica
            split_q[h] = q
            moved = counts[h] - counts[h] * q // PLACE_Q
            load[r_hot] -= moved
            load[r_cold] += moved

    return PlacementPlan(E, ep, phys_expert, slot_a, slot_b, split_q)


def apply_placement(params, plan: PlacementPlan):
    """Permute the expert-stacked MoE weight leaves into physical-slot
    order (slot s gets expert ``phys_expert[s]``'s rows).  Returns a new
    params tree sharing every other leaf; the router is NOT permuted —
    routing stays logical, only the dispatch map is physical.  Handles
    weights-only int8 leaves ({"q8", "s8"}: per-tensor scale, so only the
    int8 payload permutes)."""
    idx = np.asarray(plan.phys_expert, np.int64)
    if (idx < 0).any():
        raise ValueError("placement plan leaves a physical slot unassigned")

    def permute(leaf):
        if isinstance(leaf, dict):                     # {"q8", "s8"}
            return dict(leaf, q8=leaf["q8"][:, idx])
        return leaf[:, idx]

    blocks = dict(params["blocks"])
    if "moe" not in blocks:
        raise ValueError("model has no expert-stacked weights to place")
    moe = dict(blocks["moe"])
    for k in ("gate", "up", "down"):
        moe[k] = permute(moe[k])
    blocks["moe"] = moe
    out = dict(params)
    out["blocks"] = blocks
    return out
