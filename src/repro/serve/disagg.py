"""Disaggregated prefill/decode serving: two engines, one page handoff.

Production serving separates the two phases of a request's life because
they stress different resources: prefill is compute-bound (one big batched
forward over the prompt), decode is memory-bandwidth-bound (one token per
tick per slot, KV cache resident).  This module splits
:class:`repro.serve.engine.ServeEngine` into those roles:

* the **prefiller** — ``ServeEngine(prefill_only=True)`` — admits, chunk-
  prefills (with prefix-cache reuse), samples the first token, then
  packages each surviving request as a :class:`repro.serve.pages.KVHandoff`
  instead of decoding;
* the **decoder** — an ordinary ``ServeEngine`` — receives each packet via
  :meth:`~repro.serve.engine.ServeEngine.inject_prefilled`: the gathered KV
  chunk (int8 payloads travel with their scale leaves) is scattered into
  freshly allocated pages of the decoder's own pool, the slot goes LIVE,
  and decode continues exactly where the monolithic engine would have —
  no recompute.

The handoff rule (the invariant the property tests pin):

    gather on the prefiller takes one in-flight reference per source page;
    those references are dropped exactly once — by ``packet.release()``
    after a successful injection — so page conservation holds on both
    pools at every step (free + cached + held partitions exactly, with
    in-flight handoff references counted as held), and a delivery retry
    racing a preemption can never double-free.

Backpressure falls out of the same rule: while packets wait for decoder
capacity they pin prefiller pages, so the prefiller's own admission stalls
when the pipeline is full — no unbounded queue between the roles.

Coordination is the paper's function-centric move: the two roles are plain
zero-arg stage functions handed to :func:`repro.core.runtime.run_stages`,
so the SAME code runs deterministically interleaved on a
:class:`~repro.core.runtime.SerialExecutor` (prefill stage, then decode
stage — the mode the bit-parity tests pin) or genuinely overlapped on a
:class:`~repro.core.runtime.ThreadFarmExecutor` (each stage's jitted calls
release the GIL).  Token streams are identical either way: greedy sampling
ignores the PRNG key and seeded requests fold ``len(output)`` into their
own seed, so a token depends only on the model, the prompt, and the tokens
before it — never on which engine's tick produced it.
"""

from __future__ import annotations

__all__ = ["DisaggServeEngine"]

import threading
from collections import deque

from repro.core.runtime import make_executor, run_stages
from repro.serve.engine import ServeEngine

# engine kwargs that only make sense on the decoder (speculation happens at
# decode; a prefill-only engine refuses them at construction)
_DECODE_ONLY = ("spec_decode", "spec_k", "spec_temperature")


class DisaggServeEngine:
    """Prefiller + decoder pair behind the monolithic engine's interface.

    ``submit`` / ``tick`` / ``run_until_drained`` / ``finished`` mirror
    :class:`~repro.serve.engine.ServeEngine`, so the traffic harness and
    the launcher drive either engine unchanged.

    Args:
      executor: ``"serial"`` (default — deterministic stage order) or
        ``"thread"`` or an :class:`~repro.core.runtime.Executor` instance;
        drives the two role stages each tick via ``run_stages``.
      prefill_slots / prefill_pages: capacity of the prefiller (defaults:
        the decoder's ``max_slots`` / ``num_pages``).  Remaining kwargs are
        shared engine configuration; ``spec_decode`` (and friends) apply to
        the decoder only.
    """

    def __init__(self, model, params, *, executor="serial",
                 max_slots: int = 8, num_pages=None,
                 prefill_slots=None, prefill_pages=None, **kw):
        self.executor = make_executor(executor)
        decode_kw = dict(kw)
        prefill_kw = {k: v for k, v in kw.items() if k not in _DECODE_ONLY}
        self.prefiller = ServeEngine(
            model, params, prefill_only=True,
            max_slots=prefill_slots or max_slots,
            num_pages=prefill_pages or num_pages, **prefill_kw)
        self.decoder = ServeEngine(
            model, params, max_slots=max_slots, num_pages=num_pages,
            **decode_kw)
        # packets in flight between the roles; the lock covers the deque
        # and the prefiller's handoffs list when stages run on farm threads
        self._pending: deque = deque()
        self._lock = threading.Lock()

    # -- the monolithic engine's interface -----------------------------------

    def submit(self, prompt, **kwargs) -> int:
        """Enqueue on the prefill role (same signature as ServeEngine)."""
        return self.prefiller.submit(prompt, **kwargs)

    @property
    def finished(self) -> list:
        """Retired requests from both roles: the prefiller keeps errored /
        instantly-finished requests (EOS or budget at the first token), the
        decoder everything that went through a handoff."""
        return self.prefiller.finished + self.decoder.finished

    @property
    def stats(self) -> dict:
        """Per-role stats plus the count of in-flight KV handoffs."""
        return {"prefill": self.prefiller.stats, "decode": self.decoder.stats,
                "pending_handoffs": len(self._pending)}

    def has_work(self) -> bool:
        """True while either role or the handoff queue holds work."""
        return (self.prefiller.sched.has_work()
                or self.decoder.sched.has_work()
                or bool(self._pending))

    # -- role stages ----------------------------------------------------------

    def _prefill_stage(self) -> bool:
        busy = self.prefiller.tick()
        with self._lock:
            while self.prefiller.handoffs:
                self._pending.append(self.prefiller.handoffs.pop(0))
        return busy

    def _decode_stage(self) -> bool:
        # drain pending packets FIFO; stop at the first that doesn't fit so
        # delivery order (and therefore decoder admission order) is stable
        while True:
            with self._lock:
                packet = self._pending[0] if self._pending else None
            if packet is None:
                break
            if not self.decoder.inject_prefilled(packet):
                break                   # no slot/pages yet: retry next tick
            packet.release()            # idempotent: drops the in-flight refs
            with self._lock:
                self._pending.popleft()
        return self.decoder.tick()

    def tick(self) -> bool:
        """One overlapped prefill+decode step; True if anything ran."""
        busy = run_stages(self.executor,
                          (self._prefill_stage, self._decode_stage))
        return bool(busy) or bool(self._pending)

    def run_until_drained(self, max_ticks: int = 10_000):
        """Tick until both roles idle; returns the finished requests."""
        for _ in range(max_ticks):
            busy = self.tick()
            if not busy and not self.has_work():
                break
        return self.finished

    # -- lifecycle -----------------------------------------------------------

    def close(self):
        """Shut down both engines and the stage executor."""
        self.prefiller.close()
        self.decoder.close()
        shutdown = getattr(self.executor, "shutdown", None)
        if shutdown is not None:
            shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
