"""Serving policy: admission, chunked prefill, and preemption.

Split out of :class:`repro.serve.engine.ServeEngine` so the engine is pure
*execution* (jitted device calls) and this module is pure *policy* (host
bookkeeping) — the same function-centric cut the runtime makes between task
functions and farm machinery.  The scheduler never touches device arrays;
it hands the engine a plan (admissions, prefill chunk jobs, page/offset
targets) and the engine reports back what actually ran.

Five mechanisms:

* **Admission with prefix reuse** — FIFO from the queue into free slots.
  In paged mode the longest cached prefix of the prompt is matched in the
  pool's radix index first (those pages are incref'd, not copied) and only
  the *uncached remainder* is allocated all-or-nothing — deterministic and
  starvation-free: the queue head blocks until pages drain.
* **Chunked prefill from the match boundary** — prompts prefill in
  fixed-size, page-aligned chunks interleaved with decode ticks; fully
  cached pages are skipped entirely (chunking starts where the match
  ends).  When the WHOLE prompt is cached, one *replay* chunk recomputes
  the last page's positions with its K/V writes routed to the trash page —
  attention reads the shared pages, producing the first-token logits
  without recomputing (or mutating) anything cached.  ``chunks_per_tick``
  bounds prefill compute per tick; chunks round-robin across slots.
* **Copy-on-write decode** — a decode write targeting a page with
  refcount > 1 first copies it into a fresh exclusive page (the sharer
  keeps the original); targeting a *registered* page this slot holds alone
  just unregisters it and writes in place.  A shared page is never
  mutated.
* **Speculative verify windows** — ``ensure_decode_pages(extra=...)``
  reserves exclusive write targets for a slot's next 1 + n positions so a
  batched verify can commit draft K/V; the extras are best-effort (never
  preempting — speculation cannot evict a request plain decode would have
  kept) and ``rollback_verify_pages`` returns whatever the accepted
  tokens didn't need straight to the free list.
* **Preemption on page exhaustion** — when a live slot needs a fresh page
  and the pool is dry (after LRU eviction of unreferenced cached pages),
  the youngest-admitted request is evicted (vLLM-style recompute: its
  references are dropped — full clean pages park in the prefix cache —
  and it re-enters the queue head; on re-admission it re-prefills prompt
  *plus* tokens generated so far, usually re-matching its own parked
  pages, which preserves greedy token streams exactly).
"""

from __future__ import annotations

__all__ = ["FREE", "LIVE", "PREFILL",
           "ChunkJob", "EncodeJob", "Scheduler",
           "prefill_tokens"]

import dataclasses
from typing import Optional

import numpy as np

from repro.serve.pages import PagePool

FREE, PREFILL, LIVE = "free", "prefill", "live"


def prefill_tokens(req) -> np.ndarray:
    """The token sequence a (possibly resumed) request must prefill:
    encoder pseudo-tokens (the VLM image prefix — negative ids hashed from
    the embedding content, see
    :func:`repro.serve.engine.encoder_prefix_tokens`), then the prompt,
    then anything generated before a preemption.  Because the pseudo-tokens
    ARE ordinary (negative) int32s, admission, prefix matching, page
    registration, chunking, replay and release all treat an image prefix
    exactly like text — zero special cases downstream."""
    toks = np.asarray(req.prompt, np.int32)
    enc = getattr(req, "encoder_tokens", None)
    if enc is not None:
        toks = np.concatenate([np.asarray(enc, np.int32), toks])
    if req.output:
        toks = np.concatenate([toks, np.asarray(req.output, np.int32)])
    return toks


@dataclasses.dataclass
class ChunkJob:
    """One page-aligned prefill chunk for one slot."""
    slot: int
    req: object
    tokens: np.ndarray          # (C,) int32, right-padded to the chunk size
    start: int                  # absolute position of tokens[0]
    n_valid: int                # real (non-pad) tokens in this chunk
    pages: Optional[np.ndarray]  # (C // page_size,) page ids; None = dense
    is_last: bool
    total: int                  # full prefill length of the request
    embeds: Optional[np.ndarray] = None   # (C, d) rows for pseudo-tokens


@dataclasses.dataclass
class EncodeJob:
    """One audio chunk for the streaming encoder (enc-dec slots only).

    The engine runs the bidirectional encoder over ``frames`` through the
    Executor protocol, projects cross K/V, and scatters it into ``pages``
    of the cross pool; the slot's decoder prefill chunks are held back
    until every encode job has committed."""
    slot: int
    req: object
    frames: np.ndarray          # (Cf, d) f32, right-padded to the chunk
    start: int                  # absolute frame position of frames[0]
    n_valid: int                # real (non-pad) frames in this chunk
    pages: np.ndarray           # (Cf // cross_page_size,) cross page ids


class Scheduler:
    """Serving policy, all host-side numpy: FIFO admission with all-or-
    nothing page allocation (self-KV and, for enc-dec, cross-KV), prefix-
    cache matching, per-tick encode/prefill chunk planning, decode-page
    growth, and recompute-flavor preemption.  The engine executes the
    jobs this class plans; it never touches device memory itself."""

    def __init__(self, *, max_slots: int, max_len: int,
                 pool: Optional[PagePool] = None, prefill_chunk: int = 64,
                 chunks_per_tick: int = 2,
                 cross_pool: Optional[PagePool] = None, max_frames: int = 0):
        self.max_slots, self.max_len = max_slots, max_len
        self.pool = pool
        self.queue: list = []
        self.status = [FREE] * max_slots
        self.slot_req: list = [None] * max_slots
        self.lengths = np.zeros(max_slots, np.int64)
        self.prefill_done = np.zeros(max_slots, np.int64)
        self.prefill_total = np.zeros(max_slots, np.int64)
        self.admitted_at = np.zeros(max_slots, np.int64)
        self._admit_seq = 0
        self._rr = 0
        self.preemptions = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.cow_copies = 0
        self.chunks_per_tick = max(1, chunks_per_tick)
        if pool is not None:
            ps = pool.page_size
            self.page_size = ps
            self.prefill_chunk = max(ps, ((prefill_chunk + ps - 1) // ps) * ps)
            self.pages_per_slot = (max_len + ps - 1) // ps
            if pool.num_pages < self.pages_per_slot:
                raise ValueError(
                    f"pool of {pool.num_pages} pages cannot hold one "
                    f"max_len={max_len} request ({self.pages_per_slot} pages)")
            self.table = np.zeros((max_slots, self.pages_per_slot), np.int32)
            self.n_pages = np.zeros(max_slots, np.int64)
            self.replay = np.zeros(max_slots, bool)
        else:
            self.page_size = None
            self.prefill_chunk = prefill_chunk
            self.table = None
            self.n_pages = None
        # enc-dec: a second, read-only page table per slot for cross-KV
        self.cross_pool = cross_pool
        if cross_pool is not None:
            assert pool is not None, "cross-KV pages require a paged pool"
            cps = cross_pool.page_size
            self.cross_page_size = cps
            self.encode_chunk = max(
                cps, ((self.prefill_chunk + cps - 1) // cps) * cps)
            self.cross_pages_per_slot = max(1, (max_frames + cps - 1) // cps)
            if cross_pool.num_pages < self.cross_pages_per_slot:
                raise ValueError(
                    f"cross pool of {cross_pool.num_pages} pages cannot hold "
                    f"one max_frames={max_frames} request "
                    f"({self.cross_pages_per_slot} pages)")
            self.cross_table = np.zeros(
                (max_slots, self.cross_pages_per_slot), np.int32)
            self.cross_n = np.zeros(max_slots, np.int64)
            self.enc_total = np.zeros(max_slots, np.int64)
            self.enc_done = np.zeros(max_slots, np.int64)

    # -- queries -------------------------------------------------------------

    def live_slots(self) -> list[int]:
        """Slots currently decoding."""
        return [s for s in range(self.max_slots) if self.status[s] == LIVE]

    def prefilling_slots(self) -> list[int]:
        """Slots still consuming prefill (or encode) chunks."""
        return [s for s in range(self.max_slots) if self.status[s] == PREFILL]

    def has_work(self) -> bool:
        """True while anything is queued or resident."""
        return bool(self.queue) or any(s != FREE for s in self.status)

    def held_pages(self) -> int:
        """Page *references* currently held by slots (a page shared by k
        slots counts k times — it equals the sum of pool refcounts).  The
        conservation invariant — checked by the property tests — is
        ``pool.pages_free + pool.pages_cached + pool.pages_in_use ==
        pool.num_pages`` with ``held_pages() == sum of refcounts`` at every
        point where control returns to the caller."""
        return int(self.n_pages.sum()) if self.pool is not None else 0

    def held_cross_pages(self) -> int:
        """Cross-KV page references held by slots.  Cross pages are never
        shared (no prefix cache on the cross pool), so this equals
        ``cross_pool.pages_in_use`` whenever control is with the caller —
        the conservation check the preemption property tests assert."""
        return int(self.cross_n.sum()) if self.cross_pool is not None else 0

    # -- admission -----------------------------------------------------------

    def submit(self, req) -> None:
        """Append to the admission FIFO (no validation here)."""
        self.queue.append(req)

    def admit(self) -> tuple[list[tuple[int, object]], list[object]]:
        """Fill free slots FIFO.  Returns (admitted (slot, req) pairs,
        rejected requests whose prefill can never fit ``max_len`` — these
        bypassed submit()'s validation and must be retired by the caller)."""
        admits, rejects = [], []
        for slot in range(self.max_slots):
            if not self.queue:
                break
            if self.status[slot] != FREE:
                continue
            req = self.queue[0]
            toks = prefill_tokens(req)
            total = len(toks)
            if total == 0 or total >= self.max_len:
                # can never prefill: nothing to chunk / no room to decode
                self.queue.pop(0)
                rejects.append(req)
                continue
            cached_tok = 0
            if self.pool is not None:
                # pages for every prefill position (padded to page_size)
                # plus the first decode token: ceil((total + 1) / page_size)
                ps = self.page_size
                need = (total + ps) // ps
                cached: list[int] = []
                if self.pool.prefix is not None:
                    cached, cached_tok = self.pool.prefix.match(toks)
                # incref BEFORE allocating the tail so the eviction the
                # alloc may trigger can never take our matched pages
                self.pool.incref(cached)
                tail = self.pool.alloc(need - len(cached))
                if tail is None:
                    self.pool.decref(cached)    # back to parked / shared
                    break                       # queue head waits for pages
                if self.cross_pool is not None:
                    # all-or-nothing across BOTH pools: the cross pages for
                    # every audio frame allocate with the self pages or the
                    # whole admission rolls back
                    frames = getattr(req, "encoder_input", None)
                    n_frames = 0 if frames is None else len(frames)
                    cps = self.cross_page_size
                    cneed = (n_frames + cps - 1) // cps
                    cross = self.cross_pool.alloc(cneed)
                    if cross is None:
                        self.pool.decref(cached + tail)
                        break
                    self.cross_table[slot, :cneed] = cross
                    self.cross_n[slot] = cneed
                    self.enc_total[slot] = n_frames
                    self.enc_done[slot] = 0
                self.table[slot, :need] = cached + tail
                self.n_pages[slot] = need
                self.replay[slot] = cached_tok == total
                if cached_tok:
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += cached_tok
            self.queue.pop(0)
            self.status[slot] = PREFILL
            self.slot_req[slot] = req
            self.lengths[slot] = 0
            # chunking starts at the match boundary (page-aligned); a fully
            # cached prompt still replays its last page for the first-token
            # logits (writes routed to the trash page — see _make_job)
            if self.pool is not None and self.replay[slot]:
                self.prefill_done[slot] = (total - 1) // ps * ps
            else:
                self.prefill_done[slot] = cached_tok
            self.prefill_total[slot] = total
            self.admitted_at[slot] = self._admit_seq
            self._admit_seq += 1
            admits.append((slot, req))
        return admits, rejects

    def bind_prefilled(self, slot: int, req, pages, length: int) -> None:
        """Admit an externally prefilled request straight into a LIVE slot —
        the decoder half of a prefill/decode page handoff.  The caller has
        already allocated ``pages`` all-or-nothing (enough for every
        prefilled position plus the next decode write:
        ``(length + page_size) // page_size``) and scattered the KV into the
        leading ``ceil(length / page_size)`` of them; this binds the same
        bookkeeping :meth:`admit` + :meth:`chunk_done` would have, including
        registering the full clean pages in the prefix index so later
        admissions share them and decode writes take the usual
        unregister-or-COW path."""
        assert self.pool is not None, "page handoff requires a paged pool"
        assert self.status[slot] == FREE, (slot, self.status[slot])
        n = len(pages)
        self.table[slot, :n] = pages
        self.n_pages[slot] = n
        self.replay[slot] = False
        self.status[slot] = LIVE
        self.slot_req[slot] = req
        self.lengths[slot] = length
        self.prefill_done[slot] = length
        self.prefill_total[slot] = length
        self.admitted_at[slot] = self._admit_seq
        self._admit_seq += 1
        self._register_pages(slot, length)

    # -- chunked prefill -----------------------------------------------------

    def _padded_total(self, slot: int) -> int:
        if self.pool is None:
            return int(self.prefill_total[slot])
        ps = self.page_size
        return (int(self.prefill_total[slot]) + ps - 1) // ps * ps

    def _make_job(self, slot: int, start: int) -> ChunkJob:
        req = self.slot_req[slot]
        total = int(self.prefill_total[slot])
        padded = self._padded_total(slot)
        C = min(self.prefill_chunk, padded - start) if self.pool is not None \
            else total
        toks = np.zeros(C, np.int32)
        valid = max(0, min(C, total - start))
        toks[:valid] = prefill_tokens(req)[start:start + valid]
        pages = None
        if self.pool is not None:
            ps = self.page_size
            if self.replay[slot]:
                # fully cached prompt: recompute the last page's positions
                # for their logits but write the (identical) K/V to the
                # trash page — the shared pages are read-only to us
                pages = np.full((start + C) // ps - start // ps,
                                self.pool.trash_page, np.int32)
            else:
                pages = self.table[slot, start // ps:(start + C) // ps].copy()
        # VLM image prefix: rows of the precomputed embeddings ride along
        # with the chunk that covers their (negative pseudo-token) positions
        embeds = None
        enc_tok = getattr(req, "encoder_tokens", None)
        enc_inp = getattr(req, "encoder_input", None)
        if enc_tok is not None and enc_inp is not None \
                and start < len(enc_tok):
            enc_inp = np.asarray(enc_inp, np.float32)
            buf = np.zeros((C, enc_inp.shape[-1]), np.float32)
            take = min(C, len(enc_tok) - start)
            buf[:take] = enc_inp[start:start + take]
            embeds = buf
        return ChunkJob(slot=slot, req=req, tokens=toks, start=start,
                        n_valid=valid, pages=pages,
                        is_last=start + C >= padded, total=total,
                        embeds=embeds)

    def _padded_enc_total(self, slot: int) -> int:
        cps = self.cross_page_size
        return (int(self.enc_total[slot]) + cps - 1) // cps * cps

    def _make_encode_job(self, slot: int, start: int) -> EncodeJob:
        req = self.slot_req[slot]
        total = int(self.enc_total[slot])
        padded = self._padded_enc_total(slot)
        cps = self.cross_page_size
        C = min(self.encode_chunk, padded - start)
        frames = np.asarray(req.encoder_input, np.float32)
        valid = max(0, min(C, total - start))
        buf = np.zeros((C, frames.shape[-1]), np.float32)
        buf[:valid] = frames[start:start + valid]
        pages = self.cross_table[slot, start // cps:(start + C) // cps].copy()
        return EncodeJob(slot=slot, req=req, frames=buf, start=start,
                         n_valid=valid, pages=pages)

    def next_chunks(self) -> list:
        """Plan this tick's prefill work.  Dense mode: every prefilling slot
        gets its whole prompt as one job (they run concurrently on the
        engine's farm).  Paged mode: up to ``chunks_per_tick`` page-aligned
        chunks, round-robin across prefilling slots.  Enc-dec slots emit
        their :class:`EncodeJob` audio chunks first (counted against the
        same budget); decoder :class:`ChunkJob` chunks follow only once the
        whole clip is planned — the engine commits encode jobs before
        prompt chunks inside a tick, so cross-KV pages are always written
        before the first decoder read."""
        slots = self.prefilling_slots()
        if not slots:
            return []
        if self.pool is None:
            return [self._make_job(s, 0) for s in slots]
        jobs: list = []
        planned = {s: int(self.prefill_done[s]) for s in slots}
        enc_planned = {s: int(self.enc_done[s]) for s in slots} \
            if self.cross_pool is not None else {}
        i = 0
        order = sorted(slots, key=lambda s: (s - self._rr) % self.max_slots)

        def pending(s):
            if self.cross_pool is not None \
                    and enc_planned[s] < self._padded_enc_total(s):
                return True
            return planned[s] < self._padded_total(s)

        while len(jobs) < self.chunks_per_tick:
            ready = [s for s in order if pending(s)]
            if not ready:
                break
            slot = ready[i % len(ready)]
            if self.cross_pool is not None \
                    and enc_planned[slot] < self._padded_enc_total(slot):
                job = self._make_encode_job(slot, enc_planned[slot])
                enc_planned[slot] += len(job.frames)
            else:
                job = self._make_job(slot, planned[slot])
                planned[slot] += len(job.tokens)
            jobs.append(job)
            i += 1
        if jobs:
            self._rr = (jobs[-1].slot + 1) % self.max_slots
        return jobs

    def _register_pages(self, slot: int, valid: int, start: int = 0) -> None:
        """Insert every FULL page in ``[start, valid)`` whose content is
        final (all ``page_size`` positions written with known tokens) into
        the prefix index.  First registration wins.  Callers pass ``start``
        to cover only newly-written pages — earlier ones were registered
        when their chunk committed (or came from the cache)."""
        pool = self.pool
        if pool is None or pool.prefix is None:
            return
        req = self.slot_req[slot]
        if req is None:
            return
        toks = prefill_tokens(req)
        for i in range(start // self.page_size,
                       min(valid, len(toks)) // self.page_size):
            pool.prefix.insert(toks, i, int(self.table[slot, i]))

    def encode_done(self, job: EncodeJob) -> None:
        """An audio chunk's cross K/V has been scattered into its pages."""
        self.enc_done[job.slot] = job.start + len(job.frames)

    def chunk_done(self, job: ChunkJob) -> None:
        """Commit one prefill chunk: advance progress, register now-full
        clean pages for prefix sharing, flip the slot LIVE on the last."""
        slot = job.slot
        self.prefill_done[slot] = job.start + len(job.tokens)
        if self.pool is not None and not self.replay[slot]:
            self._register_pages(slot, job.start + job.n_valid,
                                 start=job.start)
        if job.is_last:
            self.status[slot] = LIVE
            self.lengths[slot] = job.total

    # -- decode page accounting: growth, COW, preemption ---------------------

    def _alloc_or_preempt(self, slot: int,
                          preempted: list) -> Optional[list[int]]:
        """One page for ``slot``, preempting youngest-admitted requests
        (never ``slot`` itself) until the pool yields.  Returns None only
        in the COW retry loop's favor: after a preemption the caller must
        re-check sharing, since the victim's release may have dropped the
        refcount that made the copy necessary."""
        page = self.pool.alloc(1)
        if page is not None:
            return page
        victim = self._youngest_victim(exclude=slot)
        if victim is None:
            raise RuntimeError(
                "page pool exhausted with a single request resident; "
                "num_pages is too small for max_len")
        preempted.append((victim, self.preempt(victim)))
        return None

    def _ensure_exclusive(self, slot: int, idx: int, preempted, cow,
                          allow_preempt: bool) -> bool:
        """Make page ``idx`` of ``slot`` an exclusive write target.  Three
        cases: the index is past the slot's last page (allocate fresh), the
        page is shared with another holder (copy-on-write: allocate a copy,
        drop our reference to the original), or it is a registered page we
        hold alone (unregister and write in place — no copy needed).
        ``allow_preempt=False`` makes allocation best-effort (returns False
        on pool exhaustion instead of evicting a victim) — speculative
        verify windows never preempt anyone for their extra positions."""
        if idx >= int(self.n_pages[slot]):
            assert idx == int(self.n_pages[slot]), (slot, idx)
            if allow_preempt:
                page = None
                while page is None:
                    page = self._alloc_or_preempt(slot, preempted)
            else:
                page = self.pool.alloc(1)
                if page is None:
                    return False
            self.table[slot, idx] = page[0]
            self.n_pages[slot] += 1
            return True                         # fresh page: exclusive
        p = int(self.table[slot, idx])
        while self.pool.ref(p) > 1:             # shared: copy before writing
            if allow_preempt:
                dst = self._alloc_or_preempt(slot, preempted)
                if dst is None:
                    continue        # a victim released; re-check the ref
            else:
                dst = self.pool.alloc(1)
                if dst is None:
                    return False
            cow.append((slot, p, dst[0]))
            self.pool.decref([p])               # sharers keep the original
            self.table[slot, idx] = dst[0]
            self.cow_copies += 1
            p = dst[0]
        if self.pool.prefix is not None and p in self.pool.prefix:
            # sole holder of a registered page: writing would corrupt
            # future matches — drop it (and descendants) from the index
            self.pool.unregister(p)
        return True

    def ensure_decode_pages(self, extra=None):
        """Guarantee every live slot owns — *exclusively* — the page its
        next token writes into, preempting the youngest-admitted request
        when the pool runs dry (see :meth:`_ensure_exclusive` for the
        allocate / copy-on-write / unregister cases).

        ``extra`` ({slot: n}) additionally secures exclusive write targets
        for ``n`` positions beyond the next token — a speculative verify
        window.  Extras are strictly best-effort: they never preempt and
        never raise, they just stop when the pool runs dry, so turning
        speculation on can never evict a request that plain decode would
        have kept resident.

        Returns (preempted (slot, req) pairs, COW (slot, src_page,
        dst_page) triples whose device copies the engine must apply before
        this tick's writes, granted {slot: m <= n} extra positions secured
        — the engine trims each slot's draft window to it; zero for every
        slot when ``extra`` is None)."""
        if self.pool is None:
            return [], [], {}
        want = extra or {}
        preempted: list[tuple[int, object]] = []
        cow: list[tuple[int, int, int]] = []
        granted: dict[int, int] = {}
        order = sorted(self.live_slots(), key=lambda s: self.admitted_at[s])
        # pass 1: every live slot's MANDATORY next-token page first, so a
        # speculative window can never consume the free page a younger
        # slot's plain decode write was entitled to
        for slot in order:
            if self.status[slot] != LIVE:       # preempted earlier this pass
                continue
            idx = int(self.lengths[slot]) // self.page_size
            if idx < self.pages_per_slot:
                self._ensure_exclusive(slot, idx, preempted, cow,
                                       allow_preempt=True)
        # pass 2: speculative extras, strictly best-effort (no preemption)
        for slot in order:
            if self.status[slot] != LIVE:
                continue
            got = 0
            for j in range(1, 1 + int(want.get(slot, 0))):
                pos = int(self.lengths[slot]) + j
                idx = pos // self.page_size
                if idx >= self.pages_per_slot:
                    break           # table capacity: window ends at max_len
                if not self._ensure_exclusive(slot, idx, preempted, cow,
                                              allow_preempt=False):
                    break
                got += 1
            granted[slot] = got
        return preempted, cow, granted

    def rollback_verify_pages(self, slot: int) -> int:
        """Return the pages a speculative verify window reserved beyond
        what the ACCEPTED tokens (plus the next decode write) need.  Called
        after the engine commits a verify's emitted tokens, with
        ``lengths[slot]`` already advanced; trimmed pages are exclusively
        held and unregistered (``_ensure_exclusive`` made them so and
        nothing registers mid-tick), so their decref goes straight to the
        free list — rejected-draft K/V is never parked in the prefix cache.
        Returns the number of pages released."""
        if self.pool is None or self.status[slot] != LIVE:
            return 0
        needed = int(self.lengths[slot]) // self.page_size + 1
        n = int(self.n_pages[slot])
        if n <= needed:
            return 0
        self.pool.decref(self.table[slot, needed:n].tolist())
        self.table[slot, needed:n] = 0
        self.n_pages[slot] = needed
        return n - needed

    def _youngest_victim(self, exclude: int) -> Optional[int]:
        cands = [s for s in range(self.max_slots)
                 if s != exclude and self.status[s] in (PREFILL, LIVE)]
        if not cands:
            return None
        return max(cands, key=lambda s: self.admitted_at[s])

    def preempt(self, slot: int):
        """Evict a request (recompute flavor): drop its page references,
        requeue it at the head.  Generated tokens stay on ``req.output``
        and are re-prefilled on re-admission — usually re-matching the
        pages it just parked — so its token stream continues exactly where
        it stopped."""
        req = self.slot_req[slot]
        self.release(slot)
        self.queue.insert(0, req)
        self.preemptions += 1
        return req

    def release(self, slot: int) -> None:
        """Walker ``delete``: the slot's page references return to the
        pool.  Full clean pages (every position written with known tokens)
        are registered first, so decref *parks* them in the prefix cache —
        a retired request's prompt stays matchable — while partial or
        shared-elsewhere pages take their usual decref path (free list /
        still held by the other sharers)."""
        if self.pool is not None and self.n_pages[slot]:
            n = int(self.n_pages[slot])
            if self.status[slot] == LIVE:
                valid = int(self.lengths[slot])
            else:       # mid-prefill: only committed chunks hold real K/V
                valid = min(int(self.prefill_done[slot]),
                            int(self.prefill_total[slot]))
            self._register_pages(slot, valid)
            self.pool.decref(self.table[slot, :n].tolist())
            self.table[slot, :n] = 0
            self.n_pages[slot] = 0
            self.replay[slot] = False
        if self.cross_pool is not None and self.cross_n[slot]:
            # cross pages are never registered/shared: decref -> free list
            nc = int(self.cross_n[slot])
            self.cross_pool.decref(self.cross_table[slot, :nc].tolist())
            self.cross_table[slot, :nc] = 0
            self.cross_n[slot] = 0
            self.enc_total[slot] = 0
            self.enc_done[slot] = 0
        self.status[slot] = FREE
        self.slot_req[slot] = None
        self.lengths[slot] = 0
        self.prefill_done[slot] = 0
        self.prefill_total[slot] = 0
