"""Serving policy: admission, chunked prefill, and preemption.

Split out of :class:`repro.serve.engine.ServeEngine` so the engine is pure
*execution* (jitted device calls) and this module is pure *policy* (host
bookkeeping) — the same function-centric cut the runtime makes between task
functions and farm machinery.  The scheduler never touches device arrays;
it hands the engine a plan (admissions, prefill chunk jobs, page/offset
targets) and the engine reports back what actually ran.

Three mechanisms:

* **Admission** — FIFO from the queue into free slots.  In paged mode a
  request is admitted only when the pool can cover its whole prompt plus
  the first decode token (allocate-all-or-nothing keeps admission
  deterministic and starvation-free: the queue head blocks until pages
  drain).
* **Chunked prefill** — prompts prefill in fixed-size, page-aligned chunks
  interleaved with decode ticks, so a 2k-token prompt no longer stalls
  token emission for live slots.  ``chunks_per_tick`` bounds prefill
  compute per tick; chunks round-robin across prefilling slots.
* **Preemption on page exhaustion** — when a live slot needs a fresh page
  and the pool is dry, the youngest-admitted request is evicted
  (vLLM-style recompute: its pages are freed and it re-enters the queue
  head; on re-admission it re-prefills prompt *plus* tokens generated so
  far, which preserves greedy token streams exactly).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.serve.pages import PagePool

FREE, PREFILL, LIVE = "free", "prefill", "live"


def prefill_tokens(req) -> np.ndarray:
    """The token sequence a (possibly resumed) request must prefill:
    prompt plus anything generated before a preemption."""
    toks = np.asarray(req.prompt, np.int32)
    if req.output:
        toks = np.concatenate([toks, np.asarray(req.output, np.int32)])
    return toks


@dataclasses.dataclass
class ChunkJob:
    """One page-aligned prefill chunk for one slot."""
    slot: int
    req: object
    tokens: np.ndarray          # (C,) int32, right-padded to the chunk size
    start: int                  # absolute position of tokens[0]
    n_valid: int                # real (non-pad) tokens in this chunk
    pages: Optional[np.ndarray]  # (C // page_size,) page ids; None = dense
    is_last: bool
    total: int                  # full prefill length of the request


class Scheduler:
    def __init__(self, *, max_slots: int, max_len: int,
                 pool: Optional[PagePool] = None, prefill_chunk: int = 64,
                 chunks_per_tick: int = 2):
        self.max_slots, self.max_len = max_slots, max_len
        self.pool = pool
        self.queue: list = []
        self.status = [FREE] * max_slots
        self.slot_req: list = [None] * max_slots
        self.lengths = np.zeros(max_slots, np.int64)
        self.prefill_done = np.zeros(max_slots, np.int64)
        self.prefill_total = np.zeros(max_slots, np.int64)
        self.admitted_at = np.zeros(max_slots, np.int64)
        self._admit_seq = 0
        self._rr = 0
        self.preemptions = 0
        self.chunks_per_tick = max(1, chunks_per_tick)
        if pool is not None:
            ps = pool.page_size
            self.page_size = ps
            self.prefill_chunk = max(ps, ((prefill_chunk + ps - 1) // ps) * ps)
            self.pages_per_slot = (max_len + ps - 1) // ps
            if pool.num_pages < self.pages_per_slot:
                raise ValueError(
                    f"pool of {pool.num_pages} pages cannot hold one "
                    f"max_len={max_len} request ({self.pages_per_slot} pages)")
            self.table = np.zeros((max_slots, self.pages_per_slot), np.int32)
            self.n_pages = np.zeros(max_slots, np.int64)
        else:
            self.page_size = None
            self.prefill_chunk = prefill_chunk
            self.table = None
            self.n_pages = None

    # -- queries -------------------------------------------------------------

    def live_slots(self) -> list[int]:
        return [s for s in range(self.max_slots) if self.status[s] == LIVE]

    def prefilling_slots(self) -> list[int]:
        return [s for s in range(self.max_slots) if self.status[s] == PREFILL]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s != FREE for s in self.status)

    def held_pages(self) -> int:
        """Pages currently reserved by slots.  The pool conservation
        invariant — checked by the property tests — is
        ``pool.pages_free + held_pages() == pool.num_pages`` at every
        point where control returns to the caller."""
        return int(self.n_pages.sum()) if self.pool is not None else 0

    # -- admission -----------------------------------------------------------

    def submit(self, req) -> None:
        self.queue.append(req)

    def admit(self) -> tuple[list[tuple[int, object]], list[object]]:
        """Fill free slots FIFO.  Returns (admitted (slot, req) pairs,
        rejected requests whose prefill can never fit ``max_len`` — these
        bypassed submit()'s validation and must be retired by the caller)."""
        admits, rejects = [], []
        for slot in range(self.max_slots):
            if not self.queue:
                break
            if self.status[slot] != FREE:
                continue
            req = self.queue[0]
            total = len(prefill_tokens(req))
            if total == 0 or total >= self.max_len:
                # can never prefill: nothing to chunk / no room to decode
                self.queue.pop(0)
                rejects.append(req)
                continue
            if self.pool is not None:
                # pages for every prefill position (padded to page_size)
                # plus the first decode token: ceil((total + 1) / page_size)
                need = (total + self.page_size) // self.page_size
                pages = self.pool.alloc(need)
                if pages is None:
                    break                       # queue head waits for pages
                self.table[slot, :need] = pages
                self.n_pages[slot] = need
            self.queue.pop(0)
            self.status[slot] = PREFILL
            self.slot_req[slot] = req
            self.lengths[slot] = 0
            self.prefill_done[slot] = 0
            self.prefill_total[slot] = total
            self.admitted_at[slot] = self._admit_seq
            self._admit_seq += 1
            admits.append((slot, req))
        return admits, rejects

    # -- chunked prefill -----------------------------------------------------

    def _padded_total(self, slot: int) -> int:
        if self.pool is None:
            return int(self.prefill_total[slot])
        ps = self.page_size
        return (int(self.prefill_total[slot]) + ps - 1) // ps * ps

    def _make_job(self, slot: int, start: int) -> ChunkJob:
        req = self.slot_req[slot]
        total = int(self.prefill_total[slot])
        padded = self._padded_total(slot)
        C = min(self.prefill_chunk, padded - start) if self.pool is not None \
            else total
        toks = np.zeros(C, np.int32)
        valid = max(0, min(C, total - start))
        toks[:valid] = prefill_tokens(req)[start:start + valid]
        pages = None
        if self.pool is not None:
            ps = self.page_size
            pages = self.table[slot, start // ps:(start + C) // ps].copy()
        return ChunkJob(slot=slot, req=req, tokens=toks, start=start,
                        n_valid=valid, pages=pages,
                        is_last=start + C >= padded, total=total)

    def next_chunks(self) -> list[ChunkJob]:
        """Plan this tick's prefill work.  Dense mode: every prefilling slot
        gets its whole prompt as one job (they run concurrently on the
        engine's farm).  Paged mode: up to ``chunks_per_tick`` page-aligned
        chunks, round-robin across prefilling slots."""
        slots = self.prefilling_slots()
        if not slots:
            return []
        if self.pool is None:
            return [self._make_job(s, 0) for s in slots]
        jobs: list[ChunkJob] = []
        planned = {s: int(self.prefill_done[s]) for s in slots}
        order = sorted(slots, key=lambda s: (s - self._rr) % self.max_slots)
        i = 0
        while len(jobs) < self.chunks_per_tick:
            ready = [s for s in order if planned[s] < self._padded_total(s)]
            if not ready:
                break
            slot = ready[i % len(ready)]
            job = self._make_job(slot, planned[slot])
            planned[slot] += len(job.tokens)
            jobs.append(job)
            i += 1
        if jobs:
            self._rr = (jobs[-1].slot + 1) % self.max_slots
        return jobs

    def chunk_done(self, job: ChunkJob) -> None:
        slot = job.slot
        self.prefill_done[slot] = job.start + len(job.tokens)
        if job.is_last:
            self.status[slot] = LIVE
            self.lengths[slot] = job.total

    # -- decode page accounting + preemption ---------------------------------

    def ensure_decode_pages(self) -> list[tuple[int, object]]:
        """Guarantee every live slot owns the page for its next token,
        preempting the youngest-admitted request when the pool runs dry.
        Returns the preempted (slot, req) pairs."""
        if self.pool is None:
            return []
        preempted: list[tuple[int, object]] = []
        order = sorted(self.live_slots(), key=lambda s: self.admitted_at[s])
        for slot in order:
            if self.status[slot] != LIVE:       # preempted earlier this pass
                continue
            idx = int(self.lengths[slot]) // self.page_size
            if idx < int(self.n_pages[slot]):
                continue
            page = self.pool.alloc(1)
            while page is None:
                victim = self._youngest_victim(exclude=slot)
                if victim is None:
                    raise RuntimeError(
                        "page pool exhausted with a single request resident; "
                        "num_pages is too small for max_len")
                preempted.append((victim, self.preempt(victim)))
                page = self.pool.alloc(1)
            self.table[slot, idx] = page[0]
            self.n_pages[slot] += 1
        return preempted

    def _youngest_victim(self, exclude: int) -> Optional[int]:
        cands = [s for s in range(self.max_slots)
                 if s != exclude and self.status[s] in (PREFILL, LIVE)]
        if not cands:
            return None
        return max(cands, key=lambda s: self.admitted_at[s])

    def preempt(self, slot: int):
        """Evict a request (recompute flavor): free its pages, requeue it at
        the head.  Generated tokens stay on ``req.output`` and are
        re-prefilled on re-admission, so its token stream continues
        exactly where it stopped."""
        req = self.slot_req[slot]
        self.release(slot)
        self.queue.insert(0, req)
        self.preemptions += 1
        return req

    def release(self, slot: int) -> None:
        """Walker ``delete``: the slot's capacity returns to the pool."""
        if self.pool is not None and self.n_pages[slot]:
            n = int(self.n_pages[slot])
            self.pool.free(self.table[slot, :n].tolist())
            self.table[slot, :n] = 0
            self.n_pages[slot] = 0
        self.status[slot] = FREE
        self.slot_req[slot] = None
        self.lengths[slot] = 0
        self.prefill_done[slot] = 0
        self.prefill_total[slot] = 0
