"""Token sampling heads (jit-friendly, vocab-padding aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _mask_pad(logits, true_vocab):
    if true_vocab is not None and true_vocab < logits.shape[-1]:
        pad = jnp.arange(logits.shape[-1]) >= true_vocab
        logits = jnp.where(pad, -1e30, logits)
    return logits


def greedy(logits, *, true_vocab=None):
    """logits (..., V) -> (...,) int32."""
    return jnp.argmax(_mask_pad(logits, true_vocab), axis=-1).astype(jnp.int32)


def sample_top_k(key, logits, *, k: int = 40, temperature: float = 1.0,
                 true_vocab=None):
    logits = _mask_pad(logits, true_vocab).astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    top_v, top_i = jax.lax.top_k(logits, k)
    gs = jax.random.categorical(key, top_v / temperature)
    return jnp.take_along_axis(top_i, gs[..., None], axis=-1)[..., 0].astype(
        jnp.int32)


def sample_temperature(key, logits, *, temperature: float = 1.0,
                       true_vocab=None):
    """Plain categorical sampling at a temperature (0 -> greedy)."""
    logits = _mask_pad(logits, true_vocab).astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def sample_top_p(key, logits, *, p: float = 0.9, temperature: float = 1.0,
                 true_vocab=None):
    """Nucleus sampling: keep the smallest prefix of the sorted distribution
    whose mass reaches ``p`` (the top token always survives), renormalize,
    sample.  0 temperature -> greedy."""
    logits = _mask_pad(logits, true_vocab).astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    scaled = logits / temperature
    order = jnp.argsort(-scaled, axis=-1)
    sorted_logits = jnp.take_along_axis(scaled, order, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    # cumulative mass *before* each token: token i survives iff the nucleus
    # isn't already full without it — keeps the top token unconditionally
    before = jnp.cumsum(probs, axis=-1) - probs
    sorted_logits = jnp.where(before < p, sorted_logits, -1e30)
    gs = jax.random.categorical(key, sorted_logits)
    return jnp.take_along_axis(order, gs[..., None], axis=-1)[..., 0].astype(
        jnp.int32)
