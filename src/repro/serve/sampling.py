"""Token sampling heads (jit-friendly, vocab-padding aware) and the
speculative-decode acceptance rules (host-side, per slot)."""

from __future__ import annotations

__all__ = ["greedy", "sample_temperature", "sample_top_k",
           "sample_top_p", "spec_rejection_sample", "spec_verify_greedy"]

import jax
import jax.numpy as jnp
import numpy as np


def _mask_pad(logits, true_vocab):
    if true_vocab is not None and true_vocab < logits.shape[-1]:
        pad = jnp.arange(logits.shape[-1]) >= true_vocab
        logits = jnp.where(pad, -1e30, logits)
    return logits


def greedy(logits, *, true_vocab=None):
    """logits (..., V) -> (...,) int32."""
    return jnp.argmax(_mask_pad(logits, true_vocab), axis=-1).astype(jnp.int32)


def sample_top_k(key, logits, *, k: int = 40, temperature: float = 1.0,
                 true_vocab=None):
    """Sample from the ``k`` highest logits at ``temperature`` (greedy
    when temperature <= 0); pad-vocab rows are masked out first."""
    logits = _mask_pad(logits, true_vocab).astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    top_v, top_i = jax.lax.top_k(logits, k)
    gs = jax.random.categorical(key, top_v / temperature)
    return jnp.take_along_axis(top_i, gs[..., None], axis=-1)[..., 0].astype(
        jnp.int32)


def sample_temperature(key, logits, *, temperature: float = 1.0,
                       true_vocab=None):
    """Plain categorical sampling at a temperature (0 -> greedy)."""
    logits = _mask_pad(logits, true_vocab).astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Speculative decode: acceptance rules
#
# ``logits[i]`` is the target model's distribution for the token FOLLOWING
# verify position i (position 0 = the slot's next input token, positions
# 1..n = the drafts), as produced by one batched verify forward.  Both rules
# return ``(accepted, emitted)``: how many drafts the target agreed with and
# the tokens to emit — the accepted drafts plus exactly one more (the
# correction on the first rejection, or the bonus token when every draft
# survived).  ``emitted`` is therefore never empty: a verify step always
# makes at least the progress a plain decode step would.
# ---------------------------------------------------------------------------


def spec_verify_greedy(row_argmax, draft) -> tuple[int, list[int]]:
    """Greedy acceptance: draft i survives iff it IS the target argmax at
    its position.  The emitted tokens are exactly the prefix sequential
    greedy decode would have produced, so speculative greedy streams are
    bit-identical to non-speculative ones.

    ``row_argmax``: (C,) per-position argmax of the target verify logits
    (pad-vocab already masked); ``draft``: (n,) proposed tokens, n < C.
    """
    emitted: list[int] = []
    for i, d in enumerate(draft):
        t = int(row_argmax[i])
        emitted.append(t)                  # == d when accepted
        if t != int(d):
            return i, emitted              # correction token, stop
    emitted.append(int(row_argmax[len(draft)]))     # bonus token
    return len(draft), emitted


def spec_rejection_sample(keys, logits, draft, *, temperature: float = 1.0,
                          true_vocab=None) -> tuple[int, list[int]]:
    """Standard speculative rejection sampling against a deterministic
    drafter (draft distribution = one-hot at the proposed token).

    Draft ``d`` at position ``i`` is accepted with probability
    ``min(1, p(d)/q(d)) = p_i(d)`` (``q`` is one-hot); on rejection the
    correction is drawn from the residual ``norm(max(p - q, 0))``, which
    for one-hot ``q`` is ``p`` with ``d`` zeroed and renormalized.  The
    marginal of every emitted token is exactly the target distribution
    ``softmax(logits / temperature)`` — speculation changes latency, never
    the sampled distribution.

    ``keys``: one PRNG key per verify position (seeded requests pass their
    per-stream-index keys, so streams stay reproducible); ``logits``:
    (C, V) target logits; ``draft``: (n,) tokens, n < C.
    ``temperature <= 0`` degenerates to the greedy rule.
    """
    logits = np.asarray(logits, np.float32)
    v = logits.shape[-1]
    pad = true_vocab is not None and true_vocab < v
    if temperature <= 0:
        masked = logits.copy()
        if pad:
            masked[..., true_vocab:] = -1e30
        return spec_verify_greedy(masked.argmax(-1), draft)

    def probs(i):
        # pure numpy: this runs per position in the verify commit loop,
        # so no per-row device round-trips
        row = logits[i].astype(np.float64) / temperature
        if pad:
            row[true_vocab:] = -np.inf
        row -= row.max()
        e = np.exp(row)
        return e / e.sum()

    emitted: list[int] = []
    for i, d in enumerate(draft):
        d = int(d)
        p = probs(i)
        if float(jax.random.uniform(keys[i])) < p[d]:
            emitted.append(d)
            continue
        residual = p.copy()
        residual[d] = 0.0
        residual = residual / max(residual.sum(), 1e-30)
        gs = jax.random.categorical(jax.random.fold_in(keys[i], 1),
                                    jnp.log(jnp.asarray(residual) + 1e-30))
        emitted.append(int(gs))
        return i, emitted
    n = len(draft)
    gs = jax.random.categorical(keys[n],
                                jnp.log(jnp.asarray(probs(n)) + 1e-30))
    emitted.append(int(gs))
    return n, emitted


def sample_top_p(key, logits, *, p: float = 0.9, temperature: float = 1.0,
                 true_vocab=None):
    """Nucleus sampling: keep the smallest prefix of the sorted distribution
    whose mass reaches ``p`` (the top token always survives), renormalize,
    sample.  0 temperature -> greedy."""
    logits = _mask_pad(logits, true_vocab).astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    scaled = logits / temperature
    order = jnp.argsort(-scaled, axis=-1)
    sorted_logits = jnp.take_along_axis(scaled, order, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    # cumulative mass *before* each token: token i survives iff the nucleus
    # isn't already full without it — keeps the top token unconditionally
    before = jnp.cumsum(probs, axis=-1) - probs
    sorted_logits = jnp.where(before < p, sorted_logits, -1e30)
    gs = jax.random.categorical(key, sorted_logits)
    return jnp.take_along_axis(order, gs[..., None], axis=-1)[..., 0].astype(
        jnp.int32)
