"""Token sampling heads (jit-friendly, vocab-padding aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _mask_pad(logits, true_vocab):
    if true_vocab is not None and true_vocab < logits.shape[-1]:
        pad = jnp.arange(logits.shape[-1]) >= true_vocab
        logits = jnp.where(pad, -1e30, logits)
    return logits


def greedy(logits, *, true_vocab=None):
    """logits (..., V) -> (...,) int32."""
    return jnp.argmax(_mask_pad(logits, true_vocab), axis=-1).astype(jnp.int32)


def sample_top_k(key, logits, *, k: int = 40, temperature: float = 1.0,
                 true_vocab=None):
    logits = _mask_pad(logits, true_vocab).astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    top_v, top_i = jax.lax.top_k(logits, k)
    gs = jax.random.categorical(key, top_v / temperature)
    return jnp.take_along_axis(top_i, gs[..., None], axis=-1)[..., 0].astype(
        jnp.int32)
