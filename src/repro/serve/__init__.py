"""The serving stack: paged-KV continuous batching and everything that
rides on it.

Layering, bottom to top (see ``docs/ARCHITECTURE.md`` for the full map):

* :mod:`repro.serve.pages` — refcounted page pool, radix prefix cache,
  read-only cross-KV pool, scatter/gather kernel entry points.
* :mod:`repro.serve.quant` / :mod:`repro.serve.sampling` /
  :mod:`repro.serve.spec` / :mod:`repro.serve.placement` — orthogonal
  policies: int8 KV pages, token sampling, speculative drafting,
  load-aware expert placement.
* :mod:`repro.serve.scheduler` — admission, chunked prefill planning,
  encode-chunk planning (enc-dec audio), preemption.
* :mod:`repro.serve.engine` — the tick loop tying the above to a model's
  paged decode/prefill/verify functions; multimodal ``encoder_input``
  enters here.
* :mod:`repro.serve.disagg` — disaggregated prefill/decode over a KV
  handoff.
* :mod:`repro.serve.traffic` / :mod:`repro.serve.metrics` — seeded
  open-loop workloads (text + audio + image bands) and SLO reporting.
"""
from repro.serve.pages import (CrossKVPool, KVHandoff, PagePool,
                               PagedLeafSpec, PrefixCache)
from repro.serve.sampling import (greedy, sample_temperature, sample_top_k,
                                  sample_top_p, spec_rejection_sample,
                                  spec_verify_greedy)
from repro.serve.quant import (Int8KVQuant, dequantize_params,
                               kv_bytes_per_token, make_kv_quant,
                               quantize_leaf_specs, quantize_params)
from repro.serve.placement import (PlacementPlan, apply_placement,
                                   identity_plan, imbalance, plan_placement)
from repro.serve.scheduler import Scheduler
from repro.serve.spec import (Drafter, NgramDrafter, TruncatedSelfDrafter,
                              make_drafter)
from repro.serve.engine import ServeEngine, Request, encoder_prefix_tokens
from repro.serve.disagg import DisaggServeEngine
from repro.serve.metrics import compute_report, nearest_rank, percentiles
from repro.serve.traffic import (TrafficHarness, TrafficRequest,
                                 bursty_arrivals, make_workload,
                                 poisson_arrivals, record_trace, run_traffic,
                                 workload_from_trace)

__all__ = ["CrossKVPool", "DisaggServeEngine", "Drafter",
           "Int8KVQuant", "KVHandoff", "NgramDrafter",
           "PagePool", "PagedLeafSpec", "PlacementPlan",
           "PrefixCache", "Request", "Scheduler",
           "ServeEngine", "TrafficHarness", "TrafficRequest",
           "TruncatedSelfDrafter", "apply_placement", "bursty_arrivals",
           "compute_report", "dequantize_params", "encoder_prefix_tokens",
           "greedy", "identity_plan", "imbalance",
           "kv_bytes_per_token", "make_drafter", "make_kv_quant",
           "make_workload", "nearest_rank", "percentiles",
           "plan_placement", "poisson_arrivals", "quantize_leaf_specs",
           "quantize_params", "record_trace", "run_traffic",
           "sample_temperature", "sample_top_k", "sample_top_p",
           "spec_rejection_sample", "spec_verify_greedy",
           "workload_from_trace"]
