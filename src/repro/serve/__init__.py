from repro.serve.pages import PagePool, PagedLeafSpec, PrefixCache
from repro.serve.sampling import (greedy, sample_temperature, sample_top_k,
                                  sample_top_p)
from repro.serve.scheduler import Scheduler
from repro.serve.engine import ServeEngine, Request
