from repro.serve.pages import (KVHandoff, PagePool, PagedLeafSpec,
                               PrefixCache)
from repro.serve.sampling import (greedy, sample_temperature, sample_top_k,
                                  sample_top_p, spec_rejection_sample,
                                  spec_verify_greedy)
from repro.serve.quant import (Int8KVQuant, dequantize_params,
                               kv_bytes_per_token, make_kv_quant,
                               quantize_leaf_specs, quantize_params)
from repro.serve.placement import (PlacementPlan, apply_placement,
                                   identity_plan, imbalance, plan_placement)
from repro.serve.scheduler import Scheduler
from repro.serve.spec import (Drafter, NgramDrafter, TruncatedSelfDrafter,
                              make_drafter)
from repro.serve.engine import ServeEngine, Request
from repro.serve.disagg import DisaggServeEngine
from repro.serve.metrics import compute_report, nearest_rank, percentiles
from repro.serve.traffic import (TrafficHarness, TrafficRequest,
                                 bursty_arrivals, make_workload,
                                 poisson_arrivals, record_trace, run_traffic,
                                 workload_from_trace)
