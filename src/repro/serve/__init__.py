from repro.serve.sampling import greedy, sample_top_k
from repro.serve.engine import ServeEngine, Request
