"""Serving metrics from engine-emitted event records.

Every number here is a pure function of the event log the traffic harness
records (``submit`` / ``tokens`` / ``done`` events with timestamps), so
under the virtual clock the whole report is deterministic: same seed, same
engine configuration → bit-identical percentiles, which is what lets CI
gate p99 TTFT and goodput without noise allowances for load generation.

Definitions (times are in the harness clock's units — engine ticks for the
virtual clock, seconds for the wall clock):

* **TTFT** — time to first token: first ``tokens`` event minus ``submit``.
* **ITL** — inter-token latency: gaps between a request's consecutive
  token-emission times, pooled across requests before taking percentiles
  (a request emitting several tokens in one tick — speculation, chunk
  completion — contributes zero-gaps, as it should: they arrived together).
* **e2e** — end-to-end latency: ``done`` minus ``submit``.
* **Percentiles** — nearest-rank (``sorted[ceil(q/100 * n) - 1]``): no
  interpolation, so reports are exactly reproducible and robust to the
  tiny sample counts of smoke runs.
* **Goodput** — tokens/s produced by SLO-compliant requests only, over the
  span from first submit to last completion.  A request is compliant iff
  every threshold present in the ``slo`` dict holds: ``ttft``, ``e2e``,
  and ``itl`` (its *worst* gap).  Errored requests are never compliant.
"""

from __future__ import annotations

__all__ = ["compute_report", "nearest_rank", "percentiles"]

import math
from typing import Optional


def nearest_rank(xs, q: float) -> Optional[float]:
    """Nearest-rank percentile of ``xs`` (None for an empty sample)."""
    if not xs:
        return None
    s = sorted(xs)
    k = max(1, math.ceil(q / 100.0 * len(s)))
    return float(s[k - 1])


def percentiles(xs) -> dict:
    """p50/p95/p99 (nearest-rank) plus the sample count."""
    return {"p50": nearest_rank(xs, 50), "p95": nearest_rank(xs, 95),
            "p99": nearest_rank(xs, 99), "n": len(xs)}


def _per_request(events) -> dict:
    """Fold the flat event log into per-rid lifecycle records."""
    per: dict = {}
    for e in events:
        r = per.setdefault(e["rid"], {"submit": None, "tok_times": [],
                                      "done": None, "error": False})
        if e["kind"] == "submit":
            r["submit"] = e["t"]
        elif e["kind"] == "tokens":
            r["tok_times"].extend([e["t"]] * int(e["n"]))
        elif e["kind"] == "done":
            r["done"] = e["t"]
            r["error"] = bool(e.get("error", False))
    return per


def compute_report(events, *, slo: Optional[dict] = None) -> dict:
    """The metric report for one harness run.  ``slo`` may hold any of
    ``{"ttft": ..., "e2e": ..., "itl": ...}`` thresholds in clock units;
    with no SLO every non-errored request counts as compliant, so goodput
    equals throughput."""
    per = _per_request(events)
    slo = dict(slo or {})
    inf = float("inf")
    ttft, itl, e2e = [], [], []
    total_tokens = good_tokens = good_requests = measured = errors = 0
    t0 = min((r["submit"] for r in per.values()
              if r["submit"] is not None), default=0.0)
    t1 = t0
    for rid in sorted(per):
        r = per[rid]
        if r["done"] is not None:
            t1 = max(t1, r["done"])
        if r["error"] or r["submit"] is None or not r["tok_times"]:
            errors += r["error"]
            continue
        measured += 1
        tt = r["tok_times"][0] - r["submit"]
        gaps = [b - a for a, b in zip(r["tok_times"], r["tok_times"][1:])]
        end = r["done"] if r["done"] is not None else r["tok_times"][-1]
        t1 = max(t1, end)
        ee = end - r["submit"]
        ttft.append(tt)
        itl.extend(gaps)
        e2e.append(ee)
        total_tokens += len(r["tok_times"])
        ok = (tt <= slo.get("ttft", inf) and ee <= slo.get("e2e", inf)
              and (max(gaps) if gaps else 0.0) <= slo.get("itl", inf))
        if ok:
            good_tokens += len(r["tok_times"])
            good_requests += 1
    span = max(t1 - t0, 1e-9)
    return {
        "n_requests": len(per),
        "n_measured": measured,
        "n_errors": errors,
        "tokens": total_tokens,
        "span": span,
        "tok_per_s": total_tokens / span,
        "ttft": percentiles(ttft),
        "itl": percentiles(itl),
        "e2e": percentiles(e2e),
        "slo": slo,
        "goodput": {
            "tok_per_s": good_tokens / span,
            "req_per_s": good_requests / span,
            "slo_attainment": good_requests / measured if measured else 0.0,
        },
    }
