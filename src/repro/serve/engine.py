"""Continuous-batching serving engine over a paged KV cache.

This is the paper's §3.2 *dynamic population* pattern applied to inference
twice over: requests are walkers that enter (prefill), live (decode steps),
and leave (EOS / length), and — since this engine went paged — **memory** is
a population too: fixed-size KV pages are allocated as requests enter and
grow, and freed as they leave, so the device footprint is ``pages_in_use``
instead of ``max_slots x max_len``.

Layering (see README "Serving architecture"):

* :mod:`repro.serve.pages`   — refcounted `PagePool` storage + radix
  `PrefixCache` + pure scatter/gather/copy device ops; model-agnostic
  (parameterized by each model's cache leaf specs).
* :mod:`repro.serve.scheduler` — host-side policy: FIFO admission that
  matches the longest cached prompt prefix and reserves only the uncached
  remainder (all-or-nothing), **chunked prefill** starting at the match
  boundary (long prompts prefill in page-aligned chunks interleaved with
  decode ticks, so one 2k prompt never stalls token emission for live
  slots), **copy-on-write** when a decode write targets a shared page,
  and preemption of the youngest request when the pool runs dry
  (recompute-style: generated tokens are re-prefilled on re-admission,
  preserving greedy streams; full clean pages park in the prefix cache).
* :mod:`repro.serve.spec`  — speculative decode drafters: plain functions
  ``propose(tokens, k)`` guessing continuation tokens.  When a drafter is
  configured, decode ticks with proposals run ONE batched verify forward
  (``paged_verify``) scoring every slot's window, emit the accepted
  prefix + one correction/bonus token each (greedy acceptance keeps
  streams bit-identical to per-token decode; ``spec_temperature > 0``
  rejection-samples without changing the target distribution), and roll
  over-reserved pages back to the pool.
* this module — pure execution: jitted device calls driven by the
  scheduler's plan.  ``paged_decode_step`` writes each slot's token K/V
  through (page, offset) targets and attends through the page table
  (Pallas kernel :mod:`repro.kernels.paged_attention` or jnp gather
  fallback); dead slots write to the pool's trash page so the SPMD tick
  keeps static shapes.

Families whose decode state is per-token KV (dense / MoE / VLM stacked
caches) run paged; recurrent-state families (rwkv6, mamba2/zamba) and
mixed window/ring caches (gemma3) keep the dense per-slot path — their
state is O(1) or ring-shaped, so there is nothing to page.  Both paths
share the scheduler; the dense path prefills whole prompts concurrently on
the :class:`repro.core.runtime.ThreadFarmExecutor`.

A failed prefill retires its request with ``req.error`` set and never
aborts the tick (pass ``strict=True`` to re-raise after the tick's healthy
work is committed).
"""

from __future__ import annotations

__all__ = ["Request", "ServeEngine", "encoder_prefix_tokens"]

import dataclasses
import functools
import itertools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import comm as CC
from repro.core.comm import Comm
from repro.core.runtime import ThreadFarmExecutor
from repro.serve import pages as PG
from repro.serve import quant as QZ
from repro.serve import spec as SP
from repro.serve.pages import PagePool
from repro.serve.sampling import (greedy, spec_rejection_sample,
                                  spec_verify_greedy)
from repro.serve.scheduler import (EncodeJob, FREE, Scheduler,
                                   prefill_tokens)


@dataclasses.dataclass
class Request:
    """One generation request: identity, prompt, sampling policy, optional
    encoder payload — plus the engine-side bookkeeping of its progress."""
    rid: int
    prompt: np.ndarray                     # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    sampler: Optional[Callable] = None     # per-request (key, logits) -> tok
    seed: Optional[int] = None             # per-request RNG stream: token i
    #                                        is sampled with
    #                                        fold_in(PRNGKey(seed), i), so a
    #                                        sampled stream reproduces
    #                                        independent of admission order
    encoder_input: Optional[np.ndarray] = None
    #                                        precomputed encoder embeddings:
    #                                        (n_image_tokens, d_model) patch
    #                                        embeds for a VLM, (n_frames,
    #                                        d_model) audio frames for enc-dec
    # filled by the engine:
    encoder_tokens: Optional[np.ndarray] = None
    #                                        VLM only: strictly-negative
    #                                        pseudo-tokens hashing the image
    #                                        content (see
    #                                        encoder_prefix_tokens)
    output: list = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    error: Optional[BaseException] = None  # set if prefill failed


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


def encoder_prefix_tokens(enc: np.ndarray) -> np.ndarray:
    """Deterministic strictly-negative pseudo-tokens for an image prefix.

    The prefix cache keys pages on token bytes, so an image prefix needs a
    token sequence that (a) can never collide with real vocab ids — every
    real token is >= 0, every pseudo-token strictly negative — and (b) is a
    pure content hash of the embeddings: the same image always maps to the
    same pseudo-tokens, so shared-image chats hit the radix index exactly
    like shared text prompts, while distinct images collide with
    probability ~2**-128 (blake2b seeds the token draw)."""
    import hashlib
    enc = np.ascontiguousarray(np.asarray(enc, np.float32))
    digest = hashlib.blake2b(enc.tobytes(), digest_size=16).digest()
    rng = np.random.default_rng(int.from_bytes(digest, "little"))
    draw = rng.integers(0, 2**31 - 1, size=len(enc), dtype=np.int64)
    return (-1 - draw).astype(np.int32)


class ServeEngine:
    """Continuous-batching engine: ``submit()`` requests, ``tick()`` the
    serving loop (admission → encode/prefill chunks → batched decode or
    spec-verify → retire), collect :attr:`finished`.  One instance per
    role; see the module docstring for the layer map and
    ``docs/ARCHITECTURE.md`` for the request lifecycle."""

    def __init__(self, model, params, *, max_slots: int = 8,
                 max_len: int = 512, rules=None, sampler: Callable = None,
                 prefill_workers: int = 4, paged: Optional[bool] = None,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: int = 64, chunks_per_tick: int = 2,
                 prefix_cache: bool = True,
                 spec_decode=None, spec_k: int = 4,
                 spec_temperature: float = 0.0,
                 strict: bool = False, use_pallas_attention: bool = False,
                 mesh=None, kv_quant=None, weight_quant=None,
                 prefill_only: bool = False, placement_interval: int = 0):
        self.model, self.params, self.rules = model, params, rules
        self.max_slots, self.max_len = max_slots, max_len
        self.strict = strict
        if paged is None:
            paged = model.supports_paged_decode()
        elif paged and not model.supports_paged_decode():
            raise ValueError(
                f"{model.cfg.name} ({model.cfg.family}) has no paged KV "
                "cache; construct with paged=False")
        self.paged = bool(paged)
        self.prefix_cache = bool(prefix_cache) and self.paged
        # -- encoder-attached serving (VLM image prefixes, enc-dec audio) ----
        # An enc-dec family serves paged-only: the dense per-slot path has
        # nowhere to hold the cross-attention K/V.  Prefix caching is
        # silently DISABLED for enc-dec — decoder self-KV depends on the
        # audio through cross-attention, so token-keyed page sharing would
        # alias different clips (documented in docs/ARCHITECTURE.md).
        if not self.paged and getattr(model.cfg, "is_encoder_decoder", False):
            raise ValueError(
                f"{model.cfg.name} ({model.cfg.family}) is encoder-decoder "
                "and serves through the paged engine only (cross-KV pages); "
                "drop paged=False")
        self._enc_dec = self.paged and bool(
            getattr(model.cfg, "is_encoder_decoder", False))
        if self._enc_dec:
            self.prefix_cache = False
        self._n_image = int(getattr(model.cfg, "n_image_tokens", 0) or 0) \
            if self.paged and model.cfg.family == "vlm" else 0
        if self._enc_dec or self._n_image:
            model.validate_serve_encoder(page_size=page_size,
                                         max_len=max_len,
                                         prefix_cache=self.prefix_cache)
        self.cross_pool = None

        # -- flag validation (one place, construction time) ------------------
        # Every engine-level capability flag is checked here so misuse fails
        # fast with one clear error instead of surfacing mid-tick inside a
        # jitted call.  The fused paged-attention kernel serves all three
        # paged phases (decode W=1, speculative verify windows, chunked
        # prefill), so ``use_pallas_attention`` composes freely with
        # ``spec_decode`` — but it has no meaning for families whose decode
        # state is not paged KV (recurrent rwkv6/mamba2 scans, sliding-window
        # ring caches, or ``paged=False``), and silently ignoring it there
        # would misreport what kernel actually ran.
        self.use_pallas_attention = bool(use_pallas_attention)
        if self.use_pallas_attention and not self.paged:
            raise ValueError(
                f"use_pallas_attention requires the paged KV engine: "
                f"{model.cfg.name} ({model.cfg.family}) "
                + ("was constructed with paged=False"
                   if model.supports_paged_decode() else
                   "is a recurrent/window family with no paged KV cache, "
                   "so no paged-attention kernel can ever apply")
                + "; drop the flag or use a paged family")
        if spec_decode not in (None, "off", False) and self.paged \
                and sampler is not None:
            raise ValueError(
                "spec_decode supports the default greedy sampler "
                "(spec_temperature=0, bit-identical streams) or "
                "built-in temperature rejection sampling "
                "(spec_temperature > 0); a custom engine-wide sampler "
                "cannot be verified and would be silently ignored — "
                "drop it (per-request samplers remain supported)")
        # A prefill-only engine is the producer half of disaggregated
        # serving (repro.serve.disagg): it admits and chunk-prefills as
        # usual, but instead of decoding it packages each completed
        # prefill's pages as a KVHandoff for a decoder to inject.  Handoff
        # moves whole refcounted pages, so it only exists in paged mode —
        # and speculation is meaningless on an engine that never decodes.
        self.prefill_only = bool(prefill_only)
        self.handoffs: list[PG.KVHandoff] = []
        if self.prefill_only and not self.paged:
            raise ValueError(
                f"prefill_only requires the paged KV engine: "
                f"{model.cfg.name} ({model.cfg.family}) has no pages to "
                "hand off; drop the flag or use a paged family")
        if self.prefill_only and spec_decode not in (None, "off", False):
            raise ValueError(
                "spec_decode on a prefill_only engine would never run "
                "(speculation happens at decode); configure the drafter on "
                "the decoder side")
        if self.prefill_only and self._enc_dec:
            raise ValueError(
                f"prefill_only on {model.cfg.name} (enc-dec) has no cross-KV "
                "handoff: the decoder half could never read the audio pages; "
                "serve enc-dec monolithic")
        # KV quantization (int8 pages + per-row scale leaves) is a property
        # of the PAGED storage layout; the dense per-slot path has no pool
        # to hold the scale leaves in.
        self.kv_quant = QZ.make_kv_quant(kv_quant)
        if self.kv_quant is not None and not self.paged:
            raise ValueError(
                f"kv_quant={getattr(self.kv_quant, 'name', kv_quant)!r} "
                f"requires the paged KV engine: {model.cfg.name} "
                f"({model.cfg.family}) "
                + ("was constructed with paged=False"
                   if model.supports_paged_decode() else
                   "has no paged KV cache to quantize")
                + "; drop the flag or use a paged family")
        # Weights-only int8 (dequant-on-apply) is wired through the paged
        # serving wrappers only; the self-K drafter slices raw float param
        # leaves and cannot see through {"q8","s8"} payloads.
        if weight_quant in (None, "off", False):
            self.weight_quant = None
        elif weight_quant == "int8":
            if not self.paged:
                raise ValueError(
                    "weight_quant='int8' is wired through the paged serving "
                    "path only; drop the flag or use a paged family")
            if isinstance(spec_decode, str) \
                    and spec_decode.partition("-")[0] == "self":
                raise ValueError(
                    "weight_quant='int8' cannot build the self-K drafter "
                    "(it slices raw float param leaves); use the ngram "
                    "drafter or pass a pre-built drafter object")
            self.weight_quant = "int8"
        else:
            raise ValueError(
                f"unknown weight_quant {weight_quant!r}; want 'int8' or "
                "'off'")

        # -- weights-only int8 ------------------------------------------------
        # Quantize BEFORE any device placement so only the int8 payload ever
        # lands in HBM; the full-precision weights are rebuilt transiently
        # inside each jitted call (dequant-on-apply).
        if self.weight_quant:
            flt = [a for a in jax.tree_util.tree_leaves(params)
                   if hasattr(a, "dtype")
                   and jnp.issubdtype(a.dtype, jnp.floating)]
            wq_dtype = flt[0].dtype if flt else jnp.dtype(jnp.float32)
            wq_src = params                    # pre-quant tree for spec mirroring
            params = QZ.quantize_params(params)
            deq = functools.partial(QZ.dequantize_params, dtype=wq_dtype)
        else:
            wq_src = None
            deq = lambda p: p                                   # noqa: E731
        self.params = params
        kvq = self.kv_quant

        # -- device mesh (tensor/expert-parallel serving) --------------------
        # ``mesh=None`` keeps every code path byte-identical to the
        # single-device engine.  With a 1-D ("model",) mesh, paged families
        # run head-sharded TP under shard_map (params + KV pages partitioned
        # per ``model.serve_param_specs()`` / ``paged_storage_specs()``);
        # a 2-D ("expert", "model") mesh additionally PARTITIONS whole
        # experts over the "expert" axis (all-to-all dispatch/combine, see
        # moe_apply_expert_parallel);  dense-state families run
        # slot-parallel (params replicated, decode batch sharded).  The
        # scheduler and page tables stay host-side and replicated either way.
        self.mesh = mesh
        self._param_shardings = None
        if mesh is not None:
            if rules is not None:
                raise ValueError(
                    "pass either mesh= (serving TP) or rules=, not both")
            if "model" not in mesh.axis_names:
                raise ValueError(
                    f"serving mesh needs a 'model' axis, got {mesh.axis_names}")
            self.tp = int(mesh.shape["model"])
            self.ep = int(mesh.shape["expert"]) \
                if "expert" in mesh.axis_names else 1
            if self.paged:
                # head-sharded TP (+ expert-partitioned EP): family specs
                model.validate_serve_mesh(tp=self.tp, ep=self.ep)
                pspecs = model.serve_param_specs(ep=self.ep)
                if self.weight_quant:
                    # int8 payload keeps the weight's spec; scalar scales
                    # replicate — dequant commutes with sharding, so tp=N
                    # streams stay equal to tp=1
                    pspecs = QZ.quantize_param_specs(pspecs, wq_src)
            else:
                if self.ep > 1:
                    raise ValueError(
                        f"expert-parallel serving needs the paged MoE path: "
                        f"{model.cfg.name} ({model.cfg.family}) is serving "
                        "non-paged (slot-parallel); drop the expert axis")
                # slot-parallel: the step fn runs unchanged per shard, so
                # params must be REPLICATED whatever the family's TP specs
                # would say (a dense-forced DecoderLM included)
                pspecs = jax.tree_util.tree_map(
                    lambda a: P(*([None] * jnp.ndim(a))), params)
            self._param_shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), pspecs)
            self.params = params = jax.device_put(params,
                                                  self._param_shardings)
        else:
            self.tp = 1
            self.ep = 1

        # -- load-aware expert placement -------------------------------------
        # Dispatch goes through a (3, E) expert->physical-slot map passed as
        # a TRACED argument to every jitted step (re-placement never
        # recompiles); weights are permuted host-side to match.  The
        # identity map reproduces the unplaced integer slot indices exactly.
        from repro.serve import placement as PL
        n_exp = model.cfg.n_experts if (self.paged and model.cfg.n_experts) \
            else 0
        self.placement = None                   # PlacementPlan once updated
        self.placement_interval = int(placement_interval)
        self._params_unplaced = self.params     # pristine expert order
        self._id_plan = PL.identity_plan(n_exp, self.ep) if n_exp else None
        self._place_arr = jnp.asarray(
            self._id_plan.dispatch_arrays() if n_exp
            else np.zeros((3, 0), np.int32))
        self._expert_tokens = np.zeros(n_exp, np.int64)   # lifetime
        self._expert_window = np.zeros(n_exp, np.int64)   # since re-place

        self._prefill_farm = ThreadFarmExecutor(
            num_workers=max(1, prefill_workers))
        self.sampler = sampler or (lambda key, logits: greedy(
            logits, true_vocab=model.cfg.vocab))

        # -- speculative decode ----------------------------------------------
        # A drafter proposes up to spec_k continuation tokens per live slot;
        # one batched verify forward scores every proposal and the engine
        # emits the accepted prefix + one correction/bonus token.  Families
        # without a paged KV cache fall back to plain per-token decode (the
        # drafter is simply never consulted).
        self.spec_k = max(1, int(spec_k))
        self.spec_temperature = float(spec_temperature)
        if spec_decode in (None, "off", False):
            self.drafter = None
        elif not self.paged:
            self.drafter = None          # recurrent/window family fallback
        else:
            self.drafter = spec_decode if not isinstance(spec_decode, str) \
                else SP.make_drafter(spec_decode, model=model, params=params)
        # the per-position argmax the greedy acceptance rule scores against
        # (jitted: it runs on every verify tick)
        self._verify_argmax = jax.jit(functools.partial(
            greedy, true_vocab=model.cfg.vocab))
        # jitted logits head so weight dequant-on-apply also covers the
        # host-driven prefill tail (identity deq when weights are float)
        self._lm_head = jax.jit(
            lambda p, h: model.lm_head(deq(p), h, rules))

        self.last_token = np.zeros(max_slots, np.int32)
        self.finished: list[Request] = []
        self._rid = itertools.count()
        self._key = jax.random.PRNGKey(0)
        self.stats = {"ticks": 0, "tokens": 0, "prefills": 0,
                      "chunk_prefills": 0, "preemptions": 0,
                      "prefix_hits": 0, "prefix_hit_tokens": 0,
                      "cow_copies": 0, "evictions": 0, "pages_high_water": 0,
                      "draft_proposed": 0, "draft_accepted": 0,
                      "acceptance_rate": 0.0,
                      "kv_handoffs": 0, "kv_injections": 0,
                      "encode_chunks": 0,
                      "kv_quant": self.kv_quant.name if self.kv_quant
                      else "off",
                      "weight_quant": self.weight_quant or "off",
                      "kv_bytes_per_token": QZ.kv_bytes_per_token(
                          model.paged_leaf_specs(kvq)) if self.paged else 0,
                      "moe_tokens_routed": 0, "moe_dropped_tokens": 0,
                      "expert_tokens": [0] * n_exp,
                      "expert_imbalance": 0.0, "placement_updates": 0}

        # donate the state/storage argument so XLA updates the KV buffers in
        # place (no full-pool copy per tick); CPU has no donation support
        # and would only warn
        donate = () if jax.default_backend() == "cpu" else (1,)
        rep = P()
        if self.paged:
            if num_pages is None:       # dense-equivalent budget by default
                num_pages = -(-max_slots * max_len // page_size)
            cow_donate = () if jax.default_backend() == "cpu" else (0,)
            if mesh is None:
                self.pool = PagePool(model.paged_leaf_specs(kvq),
                                     num_pages=num_pages, page_size=page_size,
                                     prefix_cache=self.prefix_cache)
                self._cow_copy = jax.jit(
                    lambda st, s, d: PG.copy_pages(st, self.pool.leaf_specs,
                                                   s, d),
                    donate_argnums=cow_donate)
                self._decode_paged = jax.jit(
                    lambda p, st, tb, ln, t, wp, wo, pl:
                    model.paged_decode_step(
                        deq(p), st, tb, ln, t, wp, wo, rules,
                        use_pallas=use_pallas_attention, quant=kvq,
                        placement=pl),
                    donate_argnums=donate)
                self._prefill_chunk = jax.jit(
                    lambda p, st, row, pg, s0, t, pl:
                    model.paged_prefill_chunk(
                        deq(p), st, row, pg, s0, t, rules,
                        use_pallas=use_pallas_attention, quant=kvq,
                        placement=pl),
                    donate_argnums=donate)
                self._verify_paged = jax.jit(
                    lambda p, st, tb, ln, t, wp, wo, pl: model.paged_verify(
                        deq(p), st, tb, ln, t, wp, wo, rules,
                        use_pallas=use_pallas_attention, quant=kvq,
                        placement=pl),
                    donate_argnums=donate)
                if self._n_image:
                    # separate jit so the embeds-free path stays byte-
                    # identical to the text-only engine (same program)
                    self._prefill_chunk_embeds = jax.jit(
                        lambda p, st, row, pg, s0, t, em, pl:
                        model.paged_prefill_chunk(
                            deq(p), st, row, pg, s0, t, rules,
                            use_pallas=use_pallas_attention, quant=kvq,
                            placement=pl, embeds=em),
                        donate_argnums=donate)
                if self._enc_dec:
                    # cross storage is READ-ONLY in these calls and not
                    # returned, so it must NOT be donated (donation would
                    # delete the live buffers); only the self-KV storage
                    # (argnum 1) is donated as usual
                    self._decode_paged = jax.jit(
                        lambda p, st, tb, ln, t, wp, wo, pl, cst, ctb, fl:
                        model.paged_decode_step(
                            deq(p), st, tb, ln, t, wp, wo, rules,
                            use_pallas=use_pallas_attention, quant=kvq,
                            placement=pl,
                            cross=dict(storage=cst, tables=ctb,
                                       frames_len=fl)),
                        donate_argnums=donate)
                    self._prefill_chunk = jax.jit(
                        lambda p, st, row, pg, s0, t, pl, cst, ctb, fl:
                        model.paged_prefill_chunk(
                            deq(p), st, row, pg, s0, t, rules,
                            use_pallas=use_pallas_attention, quant=kvq,
                            placement=pl,
                            cross=dict(storage=cst, tables=ctb,
                                       frames_len=fl)),
                        donate_argnums=donate)
                    self._verify_paged = jax.jit(
                        lambda p, st, tb, ln, t, wp, wo, pl, cst, ctb, fl:
                        model.paged_verify(
                            deq(p), st, tb, ln, t, wp, wo, rules,
                            use_pallas=use_pallas_attention, quant=kvq,
                            placement=pl,
                            cross=dict(storage=cst, tables=ctb,
                                       frames_len=fl)),
                        donate_argnums=donate)
            else:
                sspecs = model.paged_storage_specs(kvq)
                self.pool = PagePool(
                    model.paged_leaf_specs(kvq), num_pages=num_pages,
                    page_size=page_size,
                    shardings=jax.tree_util.tree_map(
                        lambda s: NamedSharding(mesh, s), sspecs,
                        is_leaf=lambda x: isinstance(x, P)),
                    prefix_cache=self.prefix_cache)
                comm = Comm("model")
                ep_comm = Comm("expert") if "expert" in mesh.axis_names \
                    else None
                # COW copies move whole pages along the (replicated) page
                # axis — each shard copies its local heads independently
                self._cow_copy = jax.jit(CC.shard_map(
                    lambda st, s, d: PG.copy_pages(st, self.pool.leaf_specs,
                                                   s, d),
                    mesh=mesh, in_specs=(sspecs, rep, rep),
                    out_specs=sspecs, check_vma=False),
                    donate_argnums=cow_donate)
                self._decode_paged = jax.jit(CC.shard_map(
                    lambda p, st, tb, ln, t, wp, wo, pl:
                    model.paged_decode_step(
                        deq(p), st, tb, ln, t, wp, wo, None,
                        use_pallas=use_pallas_attention, comm=comm,
                        quant=kvq, ep_comm=ep_comm, placement=pl),
                    mesh=mesh,
                    in_specs=(pspecs, sspecs, rep, rep, rep, rep, rep, rep),
                    out_specs=(sspecs, rep, rep), check_vma=False),
                    donate_argnums=donate)
                self._prefill_chunk = jax.jit(CC.shard_map(
                    lambda p, st, row, pg, s0, t, pl:
                    model.paged_prefill_chunk(
                        deq(p), st, row, pg, s0, t, None,
                        use_pallas=use_pallas_attention, comm=comm,
                        quant=kvq, ep_comm=ep_comm, placement=pl),
                    mesh=mesh,
                    in_specs=(pspecs, sspecs, rep, rep, rep, rep, rep),
                    out_specs=(sspecs, rep, rep), check_vma=False),
                    donate_argnums=donate)
                self._verify_paged = jax.jit(CC.shard_map(
                    lambda p, st, tb, ln, t, wp, wo, pl: model.paged_verify(
                        deq(p), st, tb, ln, t, wp, wo, None,
                        use_pallas=use_pallas_attention, comm=comm,
                        quant=kvq, ep_comm=ep_comm, placement=pl),
                    mesh=mesh,
                    in_specs=(pspecs, sspecs, rep, rep, rep, rep, rep, rep),
                    out_specs=(sspecs, rep, rep), check_vma=False),
                    donate_argnums=donate)
                if self._n_image:
                    # image embeds replicate like the token chunk (the
                    # prefix rides the replicated activation path; heads
                    # shard inside the model as usual)
                    self._prefill_chunk_embeds = jax.jit(CC.shard_map(
                        lambda p, st, row, pg, s0, t, em, pl:
                        model.paged_prefill_chunk(
                            deq(p), st, row, pg, s0, t, None,
                            use_pallas=use_pallas_attention, comm=comm,
                            quant=kvq, ep_comm=ep_comm, placement=pl,
                            embeds=em),
                        mesh=mesh,
                        in_specs=(pspecs, sspecs, rep, rep, rep, rep, rep,
                                  rep),
                        out_specs=(sspecs, rep, rep), check_vma=False),
                        donate_argnums=donate)
            cross_kw = {}
            if self._enc_dec:
                # one read-only cross-KV pool sized for every slot holding
                # a full-length clip; per-request allocation is
                # ceil(n_frames / page_size), so shorter clips leave slack
                F = int(model.cfg.n_audio_frames)
                self.cross_pool = PG.CrossKVPool(
                    model.cross_leaf_specs(kvq),
                    num_pages=max_slots * (-(-F // page_size)),
                    page_size=page_size)
                cross_kw = dict(cross_pool=self.cross_pool, max_frames=F)
                # encoder + cross-KV projection: pure compute (no donated
                # state), farmed over the ThreadFarmExecutor like dense
                # prefills; the scatter into pool pages is applied
                # serially afterwards (cross storage donated HERE only)
                self._encode_chunk = jax.jit(
                    lambda p, fr, s0, nv: model.cross_kv_chunk(
                        deq(p),
                        model.encode_chunk(deq(p), fr, s0, nv, rules)))
                cdonate = () if jax.default_backend() == "cpu" else (0,)
                self._scatter_cross = jax.jit(
                    lambda st, pg, k, v: model.scatter_cross(
                        st, pg, k, v, page_size=page_size, quant=kvq),
                    donate_argnums=cdonate)
            self.sched = Scheduler(max_slots=max_slots, max_len=max_len,
                                   pool=self.pool,
                                   prefill_chunk=prefill_chunk,
                                   chunks_per_tick=chunks_per_tick,
                                   **cross_kw)
        else:
            self.pool = None
            self.sched = Scheduler(max_slots=max_slots, max_len=max_len)
            if mesh is None:
                self._fresh_state = lambda: model.init_decode_state(
                    max_slots, max_len)
                self.state = self._fresh_state()
                self._decode = jax.jit(
                    lambda p, s, t, pos: model.decode_step(p, s, t, pos,
                                                           rules),
                    donate_argnums=donate)
            else:
                if max_slots % self.tp:
                    raise ValueError(
                        f"slot-parallel serving shards slots over the mesh: "
                        f"max_slots={max_slots} must divide by tp={self.tp}")
                st_specs = model.serve_state_specs(max_slots, max_len)
                st_sh = jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), st_specs,
                    is_leaf=lambda x: isinstance(x, P))
                self._fresh_state = lambda: jax.device_put(
                    model.init_decode_state(max_slots, max_len), st_sh)
                self.state = self._fresh_state()
                self._decode = jax.jit(CC.shard_map(
                    lambda p, s, t, pos: model.decode_step(p, s, t, pos,
                                                           None),
                    mesh=mesh,
                    in_specs=(pspecs, st_specs, P("model", None), P("model")),
                    out_specs=(st_specs, P("model", None, None)),
                    check_vma=False),
                    donate_argnums=donate)
            self._prefill = jax.jit(
                lambda p, b: model.prefill(p, b, rules, max_len))

    # -- compat views --------------------------------------------------------

    @property
    def queue(self) -> list:
        """Requests admitted-but-waiting (scheduler FIFO view)."""
        return self.sched.queue

    @property
    def slot_req(self) -> list:
        """Per-slot resident request (None for a free slot)."""
        return self.sched.slot_req

    @property
    def storage(self):
        """The paged KV storage pytree (lives on the pool — there is only
        one copy; every tick writes its functional update back)."""
        return self.pool.storage if self.pool is not None else None

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 32,
               eos_id: Optional[int] = None,
               sampler: Optional[Callable] = None,
               seed: Optional[int] = None,
               encoder_input=None) -> int:
        """Enqueue one request; returns its rid.

        ``encoder_input`` attaches precomputed encoder embeddings: for a
        VLM, the ``(n_image_tokens, d_model)`` image-patch embeddings
        (served as a pseudo-token prefix — see
        :func:`encoder_prefix_tokens`); for an enc-dec audio family, the
        ``(n_frames, d_model)`` audio frames (``1 <= n_frames <=
        n_audio_frames``), encoded in streaming chunks into read-only
        cross-KV pages.  Text-only families reject it."""
        prompt = np.asarray(prompt, np.int32)
        enc_tok = None
        if encoder_input is not None:
            cfg = self.model.cfg
            if not self.paged:
                raise ValueError(
                    "encoder_input requires the paged engine (the dense "
                    "path prefills token batches only)")
            encoder_input = np.asarray(encoder_input, np.float32)
            if encoder_input.ndim != 2 \
                    or encoder_input.shape[-1] != cfg.d_model:
                raise ValueError(
                    f"encoder_input must be (n, d_model={cfg.d_model}), "
                    f"got {encoder_input.shape}")
            if self._enc_dec:
                F = int(cfg.n_audio_frames)
                if not 1 <= len(encoder_input) <= F:
                    raise ValueError(
                        f"{cfg.name}: encoder_input carries "
                        f"{len(encoder_input)} audio frames; want 1..{F} "
                        "(n_audio_frames)")
            elif self._n_image:
                if len(encoder_input) != self._n_image:
                    raise ValueError(
                        f"{cfg.name}: encoder_input carries "
                        f"{len(encoder_input)} image tokens; want exactly "
                        f"n_image_tokens={self._n_image}")
                enc_tok = encoder_prefix_tokens(encoder_input)
            else:
                raise ValueError(
                    f"{cfg.name} ({cfg.family}) takes no encoder_input: "
                    "only VLM and enc-dec audio families are "
                    "encoder-attached")
        elif self._enc_dec:
            raise ValueError(
                f"{self.model.cfg.name} (enc-dec) requires encoder_input: "
                "the decoder cross-attends into the audio's cross-KV pages")
        total = len(prompt) + (0 if enc_tok is None else len(enc_tok))
        if total >= self.max_len:
            # reject at the source: an oversized prompt can never decode
            what = "prompt length" if enc_tok is None \
                else "image prefix + prompt length"
            raise ValueError(
                f"{what} {total} >= max_len {self.max_len}")
        req = Request(next(self._rid), prompt, max_new_tokens, eos_id,
                      sampler, seed, encoder_input=encoder_input)
        req.encoder_tokens = enc_tok
        req.submitted_at = time.perf_counter()
        self.sched.submit(req)
        return req.rid

    # -- sampling ------------------------------------------------------------

    @staticmethod
    def _seeded_key(req: Request):
        """A seeded request's key for its NEXT token depends only on
        (seed, tokens emitted so far) — never on tick count, slot id or
        admission order, so sampled streams reproduce run to run."""
        return jax.random.fold_in(jax.random.PRNGKey(req.seed),
                                  len(req.output))

    def _sample_batch(self, logits_last, slots):
        """Sample every live slot: one batched draw with the engine default,
        overridden row-wise for requests carrying their own sampler and/or
        seed.  A per-row draw that raises is isolated — returns (tokens,
        [(slot, error), ...]); the engine's own batched sampler failing
        raises."""
        self._key, sub = jax.random.split(self._key)
        nxt = np.array(jax.device_get(self.sampler(sub, logits_last)))
        errors = []
        for slot in slots:
            req = self.sched.slot_req[slot]
            if req is None or (req.sampler is None and req.seed is None):
                continue
            k = self._seeded_key(req) if req.seed is not None \
                else jax.random.fold_in(sub, slot)
            fn = req.sampler or self.sampler
            try:
                nxt[slot] = int(jax.device_get(fn(k, logits_last[slot])))
            except BaseException as e:              # noqa: BLE001
                errors.append((slot, e))
        return nxt, errors

    def _sample_one(self, req: Request, logits_row) -> int:
        if req.seed is not None:
            sub = self._seeded_key(req)
        else:
            self._key, sub = jax.random.split(self._key)
        fn = req.sampler or self.sampler
        return int(jax.device_get(fn(sub, logits_row)))

    # -- retirement ----------------------------------------------------------

    def _retire(self, slot: int):
        """Walker ``delete``: slot capacity (and its pages) return to the
        pool."""
        req = self.sched.slot_req[slot]
        req.done_at = time.perf_counter()
        self.finished.append(req)
        self.sched.release(slot)

    def _check_retire(self, slot: int, tok: int) -> bool:
        req = self.sched.slot_req[slot]
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if (hit_eos or len(req.output) >= req.max_new_tokens
                or self.sched.lengths[slot] >= self.max_len - 1):
            self._retire(slot)
            return True
        return False

    def _emit_first_token(self, slot: int, tok: int):
        """Bookkeeping for the token sampled off a completed prefill
        (EOS / budget checked immediately — a request may finish here).
        A prefill-only engine hands surviving requests off to a decoder
        instead of keeping the slot live."""
        req = self.sched.slot_req[slot]
        if req.first_token_at is None:
            req.first_token_at = time.perf_counter()
        req.output.append(tok)
        self.last_token[slot] = tok
        self.stats["tokens"] += 1
        self.stats["prefills"] += 1
        if self.prefill_only:
            # instant EOS / one-token budget / max_len still retire here —
            # there is nothing left for a decoder to do
            if not self._check_retire(slot, tok):
                self._handoff(slot)
            return
        self._check_retire(slot, tok)

    # -- disaggregated prefill/decode: page handoff ---------------------------

    def _gather_slot_kv(self, row: np.ndarray):
        """Gather one slot's pages into a contiguous chunk per pool leaf
        (``prefix + (n * page_size,) + suffix``).  Runs eagerly, not jitted:
        the page count varies per request, and a jit here would compile one
        program per count; the gathered buffers are independent of the
        pool's (possibly donated) storage, so a later storage recovery
        cannot invalidate an in-flight handoff."""
        tables = jnp.asarray(np.asarray(row, np.int32)[None])

        def leaf(st, spec):
            n = len(spec.prefix)
            return jnp.squeeze(
                PG.gather_pages(st, tables, n_prefix=n), axis=n)

        return jax.tree_util.tree_map(leaf, self.pool.storage,
                                      self.pool.leaf_specs)

    def _handoff(self, slot: int):
        """Package a completed prefill for a decoder: gather the slot's KV,
        take one in-flight reference per source page (they may stay
        registered and be re-shared by the prefix cache meanwhile, but a
        referenced page can never be evicted or reallocated), then release
        the slot — full clean pages also park in the prefix index exactly
        as a monolithic retirement would.  The KVHandoff owns the in-flight
        references until its ``release()``."""
        req = self.sched.slot_req[slot]
        total = int(self.sched.lengths[slot])
        n_kv = -(-total // self.pool.page_size)
        pages = [int(p) for p in self.sched.table[slot, :n_kv]]
        kv = self._gather_slot_kv(self.sched.table[slot, :n_kv])
        self.pool.incref(pages)
        self.sched.release(slot)
        self.stats["kv_handoffs"] += 1
        self.handoffs.append(PG.KVHandoff(req=req, length=total, kv=kv,
                                          pages=pages, pool=self.pool))

    def inject_prefilled(self, handoff: PG.KVHandoff) -> bool:
        """Decoder half of the page handoff: bind a prefilled request into
        a LIVE slot by scattering the gathered KV chunk into freshly
        allocated pages — no recompute.  All-or-nothing like admission:
        returns False (taking nothing) when no slot is free or the pool
        cannot yield ``(length + page_size) // page_size`` pages right now;
        the caller retries after a tick drains capacity.  On success the
        handoff's source references are NOT dropped — the caller owns
        ``handoff.release()`` (idempotent), which lets delivery race
        preemption without a double-free."""
        if not self.paged:
            raise ValueError("page handoff requires the paged KV engine")
        req, total = handoff.req, handoff.length
        assert req.output, "handoff carries the prefill's first token"
        slot = next((s for s in range(self.max_slots)
                     if self.sched.status[s] == FREE), None)
        if slot is None:
            return False
        ps = self.pool.page_size
        pages = self.pool.alloc((total + ps) // ps)
        if pages is None:
            return False
        n_kv = -(-total // ps)
        pg = jnp.asarray(np.asarray(pages[:n_kv], np.int32))

        def leaf(st, spec, chunk):
            return PG.scatter_chunk(st, pg, chunk, page_size=ps,
                                    n_prefix=len(spec.prefix))

        self.pool.storage = jax.tree_util.tree_map(
            leaf, self.pool.storage, self.pool.leaf_specs, handoff.kv)
        self.sched.bind_prefilled(slot, req, pages, total)
        self.last_token[slot] = req.output[-1]
        self.stats["kv_injections"] += 1
        return True

    def _retire_error(self, req: Request, err: BaseException):
        req.error = err
        req.done_at = time.perf_counter()
        self.finished.append(req)

    def _reject_errors(self, rejects) -> list:
        def why(r):
            if len(r.prompt) == 0:
                return "empty prompt has nothing to prefill"
            return f"prompt length {len(r.prompt)} >= max_len {self.max_len}"
        return [(r, ValueError(why(r))) for r in rejects]

    def _commit_decode(self, live, logits) -> list:
        """Sample + book one decoded token for every live slot.  Slots
        whose per-request sampler raised are retired instead (their pages
        return to the pool); returns their (req, error) pairs."""
        self.stats["ticks"] += 1
        nxt, sample_errors = self._sample_batch(logits[:, -1], live)
        bad = {slot for slot, _ in sample_errors}
        for slot in live:
            if slot in bad:
                continue
            req = self.sched.slot_req[slot]
            tok = int(nxt[slot])
            req.output.append(tok)
            self.last_token[slot] = tok
            self.sched.lengths[slot] += 1
            self.stats["tokens"] += 1
            self._check_retire(slot, tok)
        errors = []
        for slot, e in sample_errors:
            req = self.sched.slot_req[slot]
            self.sched.release(slot)
            errors.append((req, e))
        return errors

    def _evict_residents(self):
        """Preempt every resident request — youngest first, so the OLDEST
        lands back at the queue head and FIFO order resumes intact."""
        resident = [s for s in range(self.max_slots)
                    if self.sched.slot_req[s] is not None]
        for slot in sorted(resident,
                           key=lambda s: -int(self.sched.admitted_at[s])):
            self.sched.preempt(slot)

    def _recover_donated_storage(self):
        """A raising jitted call may already have CONSUMED the donated
        storage buffers (non-CPU backends donate them for in-place KV
        updates).  The KV contents are unrecoverable, so evict every
        resident request — recompute flavor: their generated tokens
        re-prefill on re-admission, so greedy streams survive — and rebuild
        zeroed storage with the original shapes/shardings.  On CPU
        (donation disabled) this is a no-op and the healthy slots keep
        their caches."""
        if self.pool is None or not self.pool.storage_deleted():
            return
        self._evict_residents()
        self.pool.reset_storage()

    def _recover_donated_cross(self):
        """Cross-KV twin of :meth:`_recover_donated_storage`: a raising
        scatter may have consumed the donated cross storage.  Evicted
        residents re-encode on re-admission (recompute flavor — same
        contract as self-KV recovery)."""
        if self.cross_pool is None or not self.cross_pool.storage_deleted():
            return
        self._evict_residents()
        self.cross_pool.reset_storage()

    def _recover_donated_state(self):
        """Dense-path twin of :meth:`_recover_donated_storage`: a raising
        donated decode call may have consumed the per-slot state buffers."""
        if not PG.tree_deleted(self.state):
            return
        self._evict_residents()
        self.state = self._fresh_state()

    def _raise_or_record(self, errors):
        """Errored requests are always retired with ``req.error`` set; under
        ``strict=True`` the tick then raises (healthy work is already
        committed)."""
        for req, err in errors:
            self._retire_error(req, err)
        if errors and self.strict:
            rids = [req.rid for req, _ in errors]
            raise RuntimeError(
                f"prefill failed for request(s) {rids}; each request's "
                f".error holds its exception") from errors[0][1]

    # -- paged tick ----------------------------------------------------------

    def _tick_paged(self) -> bool:
        _, rejects = self.sched.admit()
        errors = self._reject_errors(rejects)

        failed = set()
        jobs = self.sched.next_chunks()
        enc_jobs = [j for j in jobs if isinstance(j, EncodeJob)]
        jobs = [j for j in jobs if not isinstance(j, EncodeJob)]
        if enc_jobs:
            # streaming chunked encode: the bidirectional encoder + cross-KV
            # projection are pure compute with no donated state, so chunks
            # for different requests overlap on the prefill farm (Executor
            # protocol); the scatter into cross pages applies serially,
            # BEFORE any decoder chunk of the same tick reads them
            def enc_guarded(job):
                try:
                    return self._encode_chunk(
                        self.params, jnp.asarray(job.frames[None]),
                        np.int32(job.start), np.int32(job.n_valid))
                except BaseException as e:                  # noqa: BLE001
                    return e
            results, _ = self._prefill_farm.map_callables(
                [functools.partial(enc_guarded, j) for j in enc_jobs])
            for job, res in zip(enc_jobs, results):
                if job.slot in failed \
                        or self.sched.slot_req[job.slot] is not job.req:
                    continue
                try:
                    if isinstance(res, BaseException):
                        raise res
                    k, v = res
                    self.cross_pool.storage = self._scatter_cross(
                        self.cross_pool.storage, jnp.asarray(job.pages),
                        k, v)
                    self.sched.encode_done(job)
                    self.stats["encode_chunks"] += 1
                except BaseException as e:                  # noqa: BLE001
                    failed.add(job.slot)
                    self.sched.release(job.slot)
                    errors.append((job.req, e))
                    self._recover_donated_cross()
        for job in jobs:
            # skip slots that failed earlier this tick — or whose request
            # was evicted by a storage recovery (slot freed or re-assigned)
            if job.slot in failed or self.sched.slot_req[job.slot] is not job.req:
                continue
            # the WHOLE per-job path is error-isolated: a request that dies
            # mid-chunked-prefill — in the device call, the lm head or its
            # own sampler — must hand every reserved page back to the pool
            # (release) instead of aborting the tick holding them
            try:
                if job.embeds is not None:
                    storage, hidden, tel = self._prefill_chunk_embeds(
                        self.params, self.pool.storage,
                        jnp.asarray(self.sched.table[job.slot]),
                        jnp.asarray(job.pages), np.int32(job.start),
                        jnp.asarray(job.tokens[None]),
                        jnp.asarray(job.embeds[None]), self._place_arr)
                elif self._enc_dec:
                    storage, hidden, tel = self._prefill_chunk(
                        self.params, self.pool.storage,
                        jnp.asarray(self.sched.table[job.slot]),
                        jnp.asarray(job.pages), np.int32(job.start),
                        jnp.asarray(job.tokens[None]), self._place_arr,
                        self.cross_pool.storage,
                        jnp.asarray(self.sched.cross_table[job.slot]),
                        np.int32(self.sched.enc_total[job.slot]))
                else:
                    storage, hidden, tel = self._prefill_chunk(
                        self.params, self.pool.storage,
                        jnp.asarray(self.sched.table[job.slot]),
                        jnp.asarray(job.pages), np.int32(job.start),
                        jnp.asarray(job.tokens[None]), self._place_arr)
                self.pool.storage = storage
                self._account_moe(tel)
                self.sched.chunk_done(job)
                self.stats["chunk_prefills"] += 1
                if job.is_last:
                    i = job.n_valid - 1
                    logits = self._lm_head(self.params, hidden[:, i:i + 1])
                    tok = self._sample_one(job.req, logits[0, -1])
            except BaseException as e:                      # noqa: BLE001
                failed.add(job.slot)
                self.sched.release(job.slot)
                errors.append((job.req, e))
                self._recover_donated_storage()
                continue
            if job.is_last:
                self._emit_first_token(job.slot, tok)

        live = self.sched.live_slots()
        cow = []
        drafts = {}
        if live and self.drafter is not None:
            drafts = self._propose_drafts(live)
        if live:
            # may preempt the youngest and/or schedule copy-on-write moves;
            # draft windows reserve their extra write pages best-effort
            # (never preempting — speculation can't evict anyone)
            _, cow, granted = self.sched.ensure_decode_pages(
                extra={s: len(d) for s, d in drafts.items()} or None)
            drafts = {s: d[:granted.get(s, 0)]
                      for s, d in drafts.items()
                      if self.sched.slot_req[s] is not None
                      and granted.get(s, 0) > 0}
            live = self.sched.live_slots()
            # a COW'd slot preempted later in the same pass already gave
            # its copy page back — don't write into it
            cow = [(s, a, b) for s, a, b in cow
                   if self.sched.slot_req[s] is not None]
        self.stats["preemptions"] = self.sched.preemptions
        if live:
            ps = self.pool.page_size
            B = self.max_slots
            # verify width: the widest granted draft + 1, bucketed to two
            # compile shapes (half / full window) so a tick whose drafts
            # are short doesn't pay the full spec_k+1-wide forward
            C = self._spec_width(max(len(d) for d in drafts.values())
                                 + 1) if drafts else 1
            wpages = np.full((B, C), self.pool.trash_page, np.int32)
            woffs = np.zeros((B, C), np.int32)
            lens = np.zeros(B, np.int32)
            toks = np.zeros((B, C), np.int32)
            for slot in live:
                ln = int(self.sched.lengths[slot])
                lens[slot] = ln
                toks[slot, 0] = self.last_token[slot]
                d = drafts.get(slot)
                nd = 0 if d is None else len(d)
                if nd:
                    toks[slot, 1:1 + nd] = d
                for i in range(nd + 1):
                    wpages[slot, i] = self.sched.table[slot, (ln + i) // ps]
                    woffs[slot, i] = (ln + i) % ps
            # sampled speculation (spec_temperature > 0) must route EVERY
            # tick through the verify commit — otherwise no-draft ticks
            # would fall back to the engine's greedy sampler and the
            # stream would mix greedy and temperature-sampled tokens
            spec_sampled = self.drafter is not None and \
                self.spec_temperature > 0
            cross_args = ()
            if self._enc_dec:
                # dead slots keep frames_len=0: every cross read is fully
                # masked (attention renormalizes to zeros), so their stale
                # table rows are never observable
                cflens = np.zeros(B, np.int32)
                for slot in live:
                    cflens[slot] = self.sched.enc_total[slot]
                cross_args = (self.cross_pool.storage,
                              jnp.asarray(self.sched.cross_table),
                              jnp.asarray(cflens))
            try:
                if cow:         # copies strictly before this tick's writes
                    self.pool.storage = self._cow_copy(
                        self.pool.storage,
                        jnp.asarray([a for _, a, _ in cow], jnp.int32),
                        jnp.asarray([b for _, _, b in cow], jnp.int32))
                if drafts or spec_sampled:
                    self.pool.storage, logits, tel = self._verify_paged(
                        self.params, self.pool.storage,
                        jnp.asarray(self.sched.table), jnp.asarray(lens),
                        jnp.asarray(toks), jnp.asarray(wpages),
                        jnp.asarray(woffs), self._place_arr, *cross_args)
                    self._account_moe(tel)
                    errors += self._commit_verify(live, drafts, logits)
                else:
                    self.pool.storage, logits, tel = self._decode_paged(
                        self.params, self.pool.storage,
                        jnp.asarray(self.sched.table), jnp.asarray(lens),
                        jnp.asarray(toks), jnp.asarray(wpages[:, 0]),
                        jnp.asarray(woffs[:, 0]), self._place_arr,
                        *cross_args)
                    self._account_moe(tel)
                    errors += self._commit_decode(live, logits)
            except BaseException:
                # a decode/commit failure still raises (engine-level, not
                # one request's fault) — but first un-brick the engine if
                # the raising call consumed the donated storage (evicted
                # residents resume recompute-style on the next tick), and
                # retire this tick's already-released prefill failures so
                # their clients see req.error instead of a vanished request
                self._recover_donated_storage()
                for req, err in errors:
                    self._retire_error(req, err)
                raise

        self.stats.update(
            prefix_hits=self.sched.prefix_hits,
            prefix_hit_tokens=self.sched.prefix_hit_tokens,
            cow_copies=self.sched.cow_copies,
            evictions=self.pool.evictions,
            pages_high_water=self.pool.high_water)
        proposed = self.stats["draft_proposed"]
        self.stats["acceptance_rate"] = (
            self.stats["draft_accepted"] / proposed if proposed else 0.0)
        if (self.placement_interval and self._expert_tokens.size
                and self.stats["ticks"] % self.placement_interval == 0
                and self._expert_window.sum()):
            self.update_placement()
        self._raise_or_record(errors)
        return bool(live) or self.sched.has_work()

    # -- expert telemetry + load-aware placement -----------------------------

    def _account_moe(self, tel) -> None:
        """Fold one step's per-expert telemetry into engine stats (counts
        are replicated across the mesh, so any shard's copy is global)."""
        if self._expert_tokens.size == 0:
            return
        t = np.asarray(jax.device_get(tel["expert_tokens"]), np.int64)
        d = np.asarray(jax.device_get(tel["expert_dropped"]), np.int64)
        self._expert_tokens += t
        self._expert_window += t
        self.stats["moe_tokens_routed"] += int(t.sum())
        self.stats["moe_dropped_tokens"] += int(d.sum())
        self.stats["expert_tokens"] = self._expert_tokens.tolist()
        if self._expert_window.sum():
            from repro.serve import placement as PL
            plan = self.placement or self._id_plan
            self.stats["expert_imbalance"] = PL.imbalance(
                plan.rank_loads(self._expert_window))

    def update_placement(self, plan=None):
        """Re-place experts between ticks from the measured token window.

        ``plan=None`` computes one with
        :func:`repro.serve.placement.plan_placement` (hot-expert
        replication on); an explicit :class:`PlacementPlan` is applied
        as-is.  The expert-stacked weight leaves are permuted from the
        PRISTINE (identity-order) params — plans never compose — and the
        dispatch map swaps in as a traced argument, so no recompile.
        Returns the active plan (``None`` when the window was empty)."""
        from repro.serve import placement as PL
        if self._expert_tokens.size == 0:
            raise ValueError(
                f"{self.model.cfg.name}: expert placement needs a paged "
                "MoE model")
        if plan is None:
            if not self._expert_window.sum():
                return None
            plan = PL.plan_placement(self._expert_window, self.ep)
        params = PL.apply_placement(self._params_unplaced, plan)
        if self._param_shardings is not None:
            params = jax.device_put(params, self._param_shardings)
        self.params = params
        self.placement = plan
        self._place_arr = jnp.asarray(plan.dispatch_arrays())
        self._expert_window[:] = 0
        self.stats["placement_updates"] += 1
        return plan

    # -- speculative decode --------------------------------------------------

    def _spec_width(self, need: int) -> int:
        half = 1 + (self.spec_k + 1) // 2
        return half if need <= half else self.spec_k + 1

    def _propose_drafts(self, live) -> dict:
        """Ask the drafter for up to ``spec_k`` continuation tokens per
        spec-eligible live slot.  The budget caps keep parity with plain
        decode position-exact: never draft past the request's remaining
        token budget or into ``max_len``'s last writable position.  A
        drafter raising (or proposing nothing) just means no drafts for
        that slot this tick — proposals are best-effort by contract."""
        drafts = {}
        for slot in live:
            req = self.sched.slot_req[slot]
            if req.sampler is not None:
                continue        # black-box per-request sampler: unverifiable
            budget = min(self.spec_k,
                         req.max_new_tokens - len(req.output) - 1,
                         self.max_len - 2 - int(self.sched.lengths[slot]))
            if budget <= 0:
                continue
            try:
                prop = np.asarray(self.drafter.propose(
                    prefill_tokens(req), budget), np.int32).reshape(-1)
            except BaseException:                       # noqa: BLE001
                continue        # a sloppy drafter costs nothing
            if prop.size:
                drafts[slot] = prop[:budget]
        return drafts

    def _commit_verify(self, live, drafts, logits) -> list:
        """Book a verify forward's emitted tokens for every live slot:
        the accepted draft prefix + one correction/bonus each (a slot
        without drafts emits exactly its plain decoded token).  Per-token
        bookkeeping mirrors :meth:`_commit_decode`, so retirement (EOS /
        budget / max_len) happens at the same stream position speculation
        on or off; afterwards every surviving slot hands its
        over-reserved verify pages back to the pool."""
        self.stats["ticks"] += 1
        greedy_mode = self.spec_temperature <= 0
        if greedy_mode:         # the rejection path never reads the argmax
            rows = np.array(jax.device_get(self._verify_argmax(logits)))
        else:
            self._key, tick_key = jax.random.split(self._key)
            logits_np = np.asarray(jax.device_get(logits))
        errors = []
        for slot in live:
            req = self.sched.slot_req[slot]
            d = drafts.get(slot)
            nd = 0 if d is None else len(d)
            draft = [] if d is None else [int(t) for t in d]
            if req.sampler is not None:
                # black-box sampler (no drafts were proposed for it):
                # one token off position 0, error-isolated like
                # _sample_batch's per-row draws
                try:
                    accepted, emitted = 0, [self._sample_one(req,
                                                             logits[slot, 0])]
                except BaseException as e:              # noqa: BLE001
                    self.sched.release(slot)
                    errors.append((req, e))
                    continue
            elif not greedy_mode:
                if req.seed is not None:
                    base = jax.random.PRNGKey(req.seed)
                    keys = [jax.random.fold_in(base, len(req.output) + i)
                            for i in range(nd + 1)]
                else:
                    keys = [jax.random.fold_in(tick_key,
                                               slot * (self.spec_k + 2) + i)
                            for i in range(nd + 1)]
                accepted, emitted = spec_rejection_sample(
                    keys, logits_np[slot, :nd + 1], draft,
                    temperature=self.spec_temperature,
                    true_vocab=self.model.cfg.vocab)
            else:
                accepted, emitted = spec_verify_greedy(rows[slot], draft)
            self.stats["draft_proposed"] += nd
            self.stats["draft_accepted"] += accepted
            for tok in emitted:
                tok = int(tok)
                req.output.append(tok)
                self.last_token[slot] = tok
                self.sched.lengths[slot] += 1
                self.stats["tokens"] += 1
                if self._check_retire(slot, tok):
                    break
            self.sched.rollback_verify_pages(slot)
        return errors

    # -- dense tick (recurrent / window-cache families) ----------------------

    def _prefill_one(self, job, key):
        """One request's whole-prompt prefill + first token — a
        self-contained farm task (pure device work; jitted dispatch releases
        the GIL, so bucketed prefills for different requests overlap)."""
        L = job.n_valid
        bucket = min(_bucket(L), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :L] = job.tokens[:L]                 # right-pad into bucket
        cache, hidden = self._prefill(self.params,
                                      {"tokens": jnp.asarray(toks)})
        # right-padding: cache rows beyond L hold pad garbage, but
        # lengths[slot] = L masks them out (kv_valid_len) and later decode
        # tokens overwrite them in order.
        logits = self._lm_head(self.params, hidden[:, L - 1:L])
        fn = job.req.sampler or self.sampler
        tok = int(jax.device_get(fn(key, logits[0, -1])))
        return cache, tok

    def _tick_dense(self) -> bool:
        _, rejects = self.sched.admit()
        errors = self._reject_errors(rejects)

        jobs = self.sched.next_chunks()          # dense: whole-prompt jobs
        if jobs:
            keys = []
            for _ in jobs:                       # keys drawn in slot order
                self._key, sub = jax.random.split(self._key)
                keys.append(sub)

            def guarded(job, key):
                # isolate failures so one bad request cannot drop the
                # other concurrently admitted requests
                try:
                    return self._prefill_one(job, key)
                except BaseException as e:                  # noqa: BLE001
                    return e

            results, _ = self._prefill_farm.map_callables(
                [functools.partial(guarded, job, key)
                 for job, key in zip(jobs, keys)])
            for job, res in zip(jobs, results):
                if isinstance(res, BaseException):
                    self.sched.release(job.slot)
                    errors.append((job.req, res))
                    continue
                cache, tok = res
                self.state = PG.write_slot(self.state, cache, job.slot)
                self.sched.chunk_done(job)
                self._emit_first_token(job.slot, tok)

        live = self.sched.live_slots()
        if live:
            toks = jnp.asarray(self.last_token.reshape(-1, 1))
            pos = jnp.asarray(self.sched.lengths.astype(np.int32))
            try:
                self.state, logits = self._decode(self.params, self.state,
                                                  toks, pos)
                errors += self._commit_decode(live, logits)
            except BaseException:
                self._recover_donated_state()
                for req, err in errors:
                    self._retire_error(req, err)
                raise

        self._raise_or_record(errors)
        return bool(live) or self.sched.has_work()

    # -- the tick: one SPMD decode step for all live slots --------------------

    def tick(self) -> bool:
        """One serving step; True while the engine still has work."""
        return self._tick_paged() if self.paged else self._tick_dense()

    def run_until_drained(self, max_ticks: int = 10_000):
        """Tick until idle; returns the finished requests."""
        for _ in range(max_ticks):
            busy = self.tick()
            if not busy and not self.sched.has_work():
                break
        return self.finished

    # -- lifecycle -----------------------------------------------------------

    def close(self):
        """Release the prefill farm's worker threads.  The engine stays
        usable — the pool is transparently recreated on the next admit."""
        self._prefill_farm.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:       # interpreter teardown: best effort only
            pass
