"""Continuous-batching serving engine.

This is the paper's §3.2 *dynamic population* pattern applied to inference:
decode **slots** are the processors' capacity, **requests** are walkers that
enter (prefill), live (decode steps), and leave (EOS / length) — the
engine's admission loop is ``do_timestep`` plus the append/delete walker
operations, and the host-side queue bookkeeping is the ``finalize_timestep``
analogue.

Mechanics:

* One fixed-capacity batched decode state (``B = max_slots``) lives on
  device; slots are admitted/retired with masked writes (static shapes — the
  TPU constraint that rules out Python list surgery on device data).
* Prefill runs per request (shape-bucketed to limit recompilation) through
  the :class:`repro.core.runtime.ThreadFarmExecutor`, so prefills for
  different admitted requests overlap on the host instead of running
  one-by-one; each resulting cache is spliced into the slot's rows of the
  batched cache in deterministic slot order.
* Every engine tick decodes ONE token for ALL live slots in a single SPMD
  step with **ragged positions** — slot i attends to its own ``pos[i]``-long
  prefix (the per-batch kv_valid_len path in :mod:`repro.models.attention`).
* Retired slots are immediately refillable: walkers deleted, capacity
  reclaimed — the population stays balanced exactly like the DMC rebalancer
  keeps walker counts balanced.

The engine is family-generic for models whose decode state has the batch on
a known axis (axis 1 for the stacked dense/MoE/VLM caches; declared by
``state_batch_axes``).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime import ThreadFarmExecutor
from repro.serve.sampling import greedy


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                     # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    error: Optional[BaseException] = None  # set if prefill failed


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


class ServeEngine:
    def __init__(self, model, params, *, max_slots: int = 8,
                 max_len: int = 512, rules=None, sampler: Callable = None,
                 prefill_workers: int = 4):
        self.model, self.params, self.rules = model, params, rules
        self.max_slots, self.max_len = max_slots, max_len
        self._prefill_farm = ThreadFarmExecutor(
            num_workers=max(1, prefill_workers))
        self.sampler = sampler or (lambda key, logits: greedy(
            logits, true_vocab=model.cfg.vocab))
        self.state = model.init_decode_state(max_slots, max_len)
        self.pos = np.zeros(max_slots, np.int32)        # per-slot lengths
        self.live = np.zeros(max_slots, bool)
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.last_token = np.zeros(max_slots, np.int32)
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._rid = itertools.count()
        self._key = jax.random.PRNGKey(0)
        self.stats = {"ticks": 0, "tokens": 0, "prefills": 0}

        self._decode = jax.jit(
            lambda p, s, t, pos: model.decode_step(p, s, t, pos, rules))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, rules, max_len),
            static_argnames=())

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) >= self.max_len:
            # reject at the source: an oversized prompt can never decode
            raise ValueError(
                f"prompt length {len(prompt)} >= max_len {self.max_len}")
        req = Request(next(self._rid), prompt, max_new_tokens, eos_id)
        req.submitted_at = time.perf_counter()
        self.queue.append(req)
        return req.rid

    def _prefill_one(self, req: Request, key):
        """One request's prefill + first token — a self-contained farm task
        (pure device work; jitted dispatch releases the GIL, so bucketed
        prefills for different requests overlap)."""
        L = len(req.prompt)
        bucket = min(_bucket(L), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :L] = req.prompt                      # right-pad into bucket
        cache, hidden = self._prefill(self.params,
                                      {"tokens": jnp.asarray(toks)})
        # right-padding: cache rows beyond L hold pad garbage, but
        # pos[slot] = L masks them out (kv_valid_len) and later decode
        # tokens overwrite them in order.
        logits = self.model.lm_head(self.params, hidden[:, L - 1:L],
                                    self.rules)
        tok = int(jax.device_get(self.sampler(key, logits[0, -1])))
        return cache, tok

    def _admit(self):
        """Fill free slots from the queue (walker ``append``).

        Prefills for all admitted requests run concurrently on the thread
        farm; state mutation (cache splice + slot bookkeeping) stays on this
        thread, in slot order, so admission is deterministic.
        """
        admits: list[tuple[int, Request]] = []
        for slot in range(self.max_slots):
            if self.live[slot] or not self.queue:
                continue
            admits.append((slot, self.queue.pop(0)))
        if not admits:
            return
        keys = []
        for _ in admits:                    # keys drawn in slot order
            self._key, sub = jax.random.split(self._key)
            keys.append(sub)

        def guarded(req, key):
            # isolate failures so one bad request (e.g. prompt > max_len)
            # cannot drop the other concurrently admitted requests
            try:
                return self._prefill_one(req, key)
            except BaseException as e:                  # noqa: BLE001
                return e

        results, _ = self._prefill_farm.map_callables(
            [functools.partial(guarded, req, key)
             for (_, req), key in zip(admits, keys)])
        errors = []
        for (slot, req), res in zip(admits, results):
            if isinstance(res, BaseException):
                # retire the failed request with its error so clients
                # tracking the rid see a terminal state, not a black hole
                req.error = res
                req.done_at = time.perf_counter()
                self.finished.append(req)
                errors.append((req.rid, res))
                continue
            cache, tok = res
            self._splice(cache, slot)
            self.pos[slot] = len(req.prompt)
            self.live[slot] = True
            self.slot_req[slot] = req
            self.last_token[slot] = tok
            req.first_token_at = time.perf_counter()
            req.output.append(tok)
            self.stats["prefills"] += 1
        if errors:
            rids = [rid for rid, _ in errors]
            raise RuntimeError(
                f"prefill failed for request(s) {rids} "
                f"({len(errors)} of {len(admits)} admitted); "
                f"each request's .error holds its exception") from errors[0][1]

    def _splice(self, cache, slot: int):
        """Write a (B=1) prefill cache into the batched state's slot rows."""
        def splice_leaf(dst, src):
            # dst (..., B, S, ...), src (..., 1, S', ...): batch axis = 1
            # for every stacked family cache in this repo.
            pad = [(0, 0)] * src.ndim
            pad[2] = (0, dst.shape[2] - src.shape[2])
            src = jnp.pad(src, pad)
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=1)

        self.state = jax.tree_util.tree_map(splice_leaf, self.state, cache)

    def _retire(self, slot: int):
        """Walker ``delete``: slot capacity returns to the pool."""
        req = self.slot_req[slot]
        req.done_at = time.perf_counter()
        self.finished.append(req)
        self.live[slot] = False
        self.slot_req[slot] = None

    # -- the tick: one SPMD decode step for all live slots --------------------

    def tick(self):
        self._admit()
        if not self.live.any():
            return False
        toks = jnp.asarray(self.last_token.reshape(-1, 1))
        pos = jnp.asarray(self.pos)
        self.state, logits = self._decode(self.params, self.state, toks, pos)
        self._key, sub = jax.random.split(self._key)
        nxt = np.asarray(jax.device_get(self.sampler(sub, logits[:, -1])))
        self.stats["ticks"] += 1
        for slot in range(self.max_slots):
            if not self.live[slot]:
                continue
            req = self.slot_req[slot]
            tok = int(nxt[slot])
            req.output.append(tok)
            self.pos[slot] += 1
            self.last_token[slot] = tok
            self.stats["tokens"] += 1
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if (hit_eos or len(req.output) >= req.max_new_tokens
                    or self.pos[slot] >= self.max_len - 1):
                self._retire(slot)
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        for _ in range(max_ticks):
            busy = self.tick()
            if not busy and not self.queue:
                break
        return self.finished

    # -- lifecycle -----------------------------------------------------------

    def close(self):
        """Release the prefill farm's worker threads.  The engine stays
        usable — the pool is transparently recreated on the next admit."""
        self._prefill_farm.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:       # interpreter teardown: best effort only
            pass
