"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function is the direct mathematical definition with no blocking tricks —
tests sweep shapes/dtypes and ``assert_allclose`` kernel vs oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm(x, scale, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window=None):
    """Plain masked softmax attention.  q (B,Sq,Hq,D); k,v (B,Sk,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def paged_attention(q, k_pages, v_pages, tables, lengths):
    """Paged decode attention by explicit gather (the kernel's ground truth).

    q: (B, Hq, D); k_pages/v_pages: (N, page_size, Hkv, D);
    tables: (B, P) int32; lengths: (B,) int32 valid-KV counts (including the
    current token).  Returns (B, Hq, D); length-0 rows are zero.
    """
    B, Hq, D = q.shape
    N, ps, Hkv, _ = k_pages.shape
    P = tables.shape[1]
    G = Hq // Hkv
    k = k_pages[tables].reshape(B, P * ps, Hkv, D).astype(jnp.float32)
    v = v_pages[tables].reshape(B, P * ps, Hkv, D).astype(jnp.float32)
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k)
    ok = jnp.arange(P * ps)[None, :] < lengths[:, None]          # (B, Sk)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v)
    o = jnp.where((lengths > 0)[:, None, None, None], o, 0.0)
    return o.reshape(B, Hq, D).astype(q.dtype)


def paged_attention_mq(q, k_pages, v_pages, tables, lengths,
                       k_scale=None, v_scale=None):
    """Multi-query paged attention by explicit gather (the kernel's oracle).

    q: (B, W, Hq, D); k_pages/v_pages: (N, page_size, Hkv, D);
    tables: (B, P) int32; lengths: (B,) int32 valid-KV counts for window
    position 0 (including its own token).  Window position w attends to KV
    positions < lengths + w.  Returns (B, W, Hq, D); rows with no valid KV
    (dead slots) are zero.

    ``k_scale``/``v_scale``: optional (N, page_size, Hkv) per-(row, head)
    scales for int8 pages — the oracle dequantizes the gathered cache
    before the plain softmax (the kernel fuses the same multiply in VMEM).
    """
    B, W, Hq, D = q.shape
    N, ps, Hkv, _ = k_pages.shape
    P = tables.shape[1]
    G = Hq // Hkv
    k = k_pages[tables].reshape(B, P * ps, Hkv, D).astype(jnp.float32)
    v = v_pages[tables].reshape(B, P * ps, Hkv, D).astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[tables].reshape(B, P * ps, Hkv, 1).astype(jnp.float32)
        v = v * v_scale[tables].reshape(B, P * ps, Hkv, 1).astype(jnp.float32)
    qg = q.reshape(B, W, Hkv, G, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bwhgd,bkhd->bhgwk", qg, k)
    limit = lengths[:, None] + jnp.arange(W)[None, :]            # (B, W)
    ok = jnp.arange(P * ps)[None, None, :] < limit[..., None]    # (B, W, Sk)
    s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgwk,bkhd->bwhgd", p, v)
    o = jnp.where((limit > 0)[:, :, None, None, None], o, 0.0)
    return o.reshape(B, W, Hq, D).astype(q.dtype)


def rwkv6_scan(r, k, v, w, u, state0=None):
    """RWKV-6 time mixing recurrence.

    r,k,w: (B,S,H,K); v: (B,S,H,V); u: (H,K); state0: (B,H,K,V) f32.
    y_t = r_t . (S_{t-1} + u * k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (y (B,S,H,V) f32, final_state).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, K, V), jnp.float32)
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S_, xs):
        r_t, k_t, v_t, w_t = xs
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S_ + uf[..., None] * kv)
        S_ = w_t[..., None] * S_ + kv
        return S_, y

    xs = (rf.swapaxes(0, 1), kf.swapaxes(0, 1), vf.swapaxes(0, 1),
          wf.swapaxes(0, 1))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1), state


def ssd_scan(xdt, la, Bm, Cm, state0=None):
    """Mamba-2 SSD recurrence (per-step, unchunked — the oracle).

    xdt: (B,S,H,P) x*dt;  la: (B,S,H) log-decay;  Bm,Cm: (B,S,N).
    state_t = exp(la_t) state_{t-1} + B_t (outer) xdt_t
    y_t = C_t . state_t
    Returns (y (B,S,H,P) f32, final state (B,H,N,P) f32).
    """
    B, S, H, Pd = xdt.shape
    N = Bm.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, N, Pd), jnp.float32)
    xf = xdt.astype(jnp.float32)
    lf = la.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    def step(state, xs):
        x_t, l_t, B_t, C_t = xs           # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(l_t)[:, :, None, None]
        state = state * decay + jnp.einsum("bn,bhp->bhnp", B_t, x_t)
        y = jnp.einsum("bn,bhnp->bhp", C_t, state)
        return state, y

    xs = (xf.swapaxes(0, 1), lf.swapaxes(0, 1), Bf.swapaxes(0, 1),
          Cf.swapaxes(0, 1))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1), state
