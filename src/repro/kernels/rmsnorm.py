"""Fused RMSNorm kernel: one pass, row-tiled.

The fusion saves one full HBM round-trip versus the naive
``mean-square -> rsqrt -> scale`` chain (3 reads + 1 write becomes 1+1):
at (B*S, d) activations this layer is pure memory-bound, so the kernel's
value is bandwidth, not FLOPs.  Rows are tiled (rows_blk x d) into VMEM;
the reduction runs in f32 regardless of the storage dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, rows_blk: int = 256,
            interpret: bool = False):
    """x: (..., d); scale: (d,)."""
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    rows = x2.shape[0]
    rows_blk = min(rows_blk, rows)
    pad = (-rows) % rows_blk
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = x2.shape[0] // rows_blk

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((rows_blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
