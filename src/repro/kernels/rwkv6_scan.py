"""RWKV-6 recurrence kernel: the (K x V) per-head wkv state stays resident in
VMEM scratch across the whole sequence.

TPU adaptation of the CUDA wkv kernel (which holds the state in registers per
thread): the Pallas grid is (B*H, S/t_blk) with time innermost, so grid steps
execute sequentially and the f32 state scratch carries over — the state never
round-trips to HBM between timesteps (the jnp ``lax.scan`` fallback writes it
back every step).  Inside a tile the t_blk timesteps run as a ``fori_loop``
over rows already resident in VMEM.

Layout: r/k/w (BH, S, K), v (BH, S, V), u (H, K); state (K, V) f32 scratch;
outputs y (BH, S, V) and the final state (BH, K, V).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, sT_ref, state_ref, *, t_blk: int, n_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                       # (K,)

    def step(t, _):
        r_t = r_ref[0, t].astype(jnp.float32)              # (K,)
        k_t = k_ref[0, t].astype(jnp.float32)              # (K,)
        v_t = v_ref[0, t].astype(jnp.float32)              # (V,)
        w_t = w_ref[0, t].astype(jnp.float32)              # (K,)
        kv = k_t[:, None] * v_t[None, :]                   # (K, V)
        S_ = state_ref[...]
        y = ((S_ + u[:, None] * kv) * r_t[:, None]).sum(axis=0)   # (V,)
        y_ref[0, t] = y.astype(y_ref.dtype)
        state_ref[...] = w_t[:, None] * S_ + kv
        return 0

    jax.lax.fori_loop(0, t_blk, step, 0)

    @pl.when(ti == n_t - 1)
    def _finish():
        sT_ref[0] = state_ref[...]


def rwkv6_scan(r, k, v, w, u, state0=None, *, t_blk: int = 64,
               interpret: bool = False):
    """r,k,w: (B,S,H,K); v: (B,S,H,V); u: (H,K); state0: (B,H,K,V) f32.

    Returns (y (B,S,H,V) f32, final_state (B,H,K,V) f32).
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, K, V), jnp.float32)
    t_blk = min(t_blk, S)
    assert S % t_blk == 0, (S, t_blk)
    n_t = S // t_blk

    def bh(x):                                             # (B,S,H,C)->(BH,S,C)
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, x.shape[-1])

    rh, kh, vh, wh = bh(r), bh(k), bh(v), bh(w)
    s0 = state0.reshape(B * H, K, V)

    def x_index(b, t):
        return (b, t, 0)

    def u_index(b, t):
        return (b % H, 0)

    def s_index(b, t):
        return (b, 0, 0)

    y, sT = pl.pallas_call(
        functools.partial(_rwkv_kernel, t_blk=t_blk, n_t=n_t),
        grid=(B * H, n_t),
        in_specs=[
            pl.BlockSpec((1, t_blk, K), x_index),
            pl.BlockSpec((1, t_blk, K), x_index),
            pl.BlockSpec((1, t_blk, V), x_index),
            pl.BlockSpec((1, t_blk, K), x_index),
            pl.BlockSpec((1, K), u_index),
            pl.BlockSpec((1, K, V), s_index),
        ],
        out_specs=[
            pl.BlockSpec((1, t_blk, V), x_index),
            pl.BlockSpec((1, K, V), s_index),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, V), jnp.float32),
            jax.ShapeDtypeStruct((B * H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rh, kh, vh, wh, u, s0)
    y = y.reshape(B, H, S, V).transpose(0, 2, 1, 3)
    return y, sT.reshape(B, H, K, V)
