"""Blocked online-softmax (flash) attention for TPU.

TPU adaptation of the GPU flash-attention idea: instead of a
warp-cooperative SRAM tile, blocks are VMEM tiles driven by the sequential
Pallas grid.  Grid = (B*Hq, Sq/q_blk, Sk/kv_blk) with the KV dimension
innermost, so the (acc, m, l) running state for one q tile lives in VMEM
scratch across the KV sweep — the online-softmax recurrence never touches
HBM.  Q/K/V tiles stream HBM->VMEM via BlockSpec; MXU sees (q_blk x D) @
(D x kv_blk) contractions with D = head_dim (128/256: hardware-aligned).

Causal/sliding-window masking is applied per tile; fully-masked KV tiles are
skipped with ``pl.when`` (this is what makes the causal kernel ~2x the naive
cost model and the gemma3 local layers O(S*window)).

GQA is handled by an index map: query head h reads KV head h // group.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window, q_blk: int,
                  kv_blk: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
    k_pos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)

    # tile-level skip: any (q,k) pair in this tile live?
    live = True
    if causal:
        live = jnp.logical_and(live, qi * q_blk + q_blk - 1 >= ki * kv_blk)
    if window is not None:
        # fully dead only when even the smallest q - largest k >= window
        live = jnp.logical_and(live,
                               qi * q_blk - (ki * kv_blk + kv_blk - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # (q_blk, D)
        k = k_ref[0].astype(jnp.float32)                    # (kv_blk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        ok = jnp.ones((q_blk, kv_blk), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, q_pos >= k_pos)
        if window is not None:
            ok = jnp.logical_and(ok, q_pos - k_pos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                                  # (q_blk,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    q_blk: int = 256, kv_blk: int = 256,
                    interpret: bool = False):
    """q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D) -> (B,Sq,Hq,D)."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    q_blk = min(q_blk, Sq)
    kv_blk = min(kv_blk, Sk)
    assert Sq % q_blk == 0 and Sk % kv_blk == 0, (Sq, q_blk, Sk, kv_blk)
    n_q, n_kv = Sq // q_blk, Sk // kv_blk
    scale = D ** -0.5

    # (B,S,H,D) -> (B*H, S, D) head-major streams
    qh = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_blk=q_blk, kv_blk=kv_blk, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, q_blk, D), q_index),
            pl.BlockSpec((1, kv_blk, D), kv_index),
            pl.BlockSpec((1, kv_blk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, q_blk, D), q_index),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, D), jnp.float32),
            pltpu.VMEM((q_blk,), jnp.float32),
            pltpu.VMEM((q_blk,), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
