"""Paged decode attention: gather K/V through a page table, on-chip.

One decode query per sequence attends to a KV prefix that lives in
non-contiguous fixed-size pages (:mod:`repro.serve.pages`).  Instead of
materializing the gathered (B, S, Hkv, D) cache in HBM — the jnp fallback in
:mod:`repro.models.attention` — the kernel streams each sequence's pages
HBM->VMEM directly via a scalar-prefetched page table: BlockSpec index maps
read ``table[b, p]`` to pick the page, so the DMA engine performs the gather
and the online-softmax state (acc, m, l) never leaves VMEM scratch.

Grid = (B, Hkv, pages_per_seq) with pages innermost: one (G, page_size)
score tile per step (G = grouped q heads per KV head).  Pages past a
sequence's length are skipped with ``pl.when`` — cost is O(lengths), not
O(pages_per_seq), which is the whole point of paging.  Dead slots
(length 0) produce zero outputs.

``lengths`` counts valid KV entries *including* the current token (whose
K/V must be written to its page before the call); causality is implicit —
every cached position is <= the query position.

Tensor-parallel serving runs this kernel INSIDE a ``shard_map`` body: q and
the page storage arrive head-sharded (Hq/tp, Hkv/tp local heads), the page
table and lengths replicated, and the grid's Hkv extent is the local head
count — each device streams only its own head shard's pages, which is what
makes the paged decode step's HBM traffic scale 1/tp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float, page_size: int,
                  n_pages: int):
    b = pl.program_id(0)
    p = pl.program_id(2)
    length = len_ref[b]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(p * page_size < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (page_size, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pr = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + pr.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(pr, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, tables, lengths, *,
                    interpret: bool = False):
    """q: (B, Hq, D); k_pages/v_pages: (N, page_size, Hkv, D);
    tables: (B, P) int32 page ids; lengths: (B,) int32 -> (B, Hq, D)."""
    B, Hq, D = q.shape
    N, page_size, Hkv, _ = k_pages.shape
    P = tables.shape[1]
    G = Hq // Hkv
    assert Hq % Hkv == 0, (Hq, Hkv)
    scale = D ** -0.5

    qg = q.reshape(B, Hkv, G, D)
    tables = tables.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    def q_index(b, h, p, tbl, ln):
        return (b, h, 0, 0)

    def kv_index(b, h, p, tbl, ln):
        return (tbl[b, p], 0, h, 0)

    kernel = functools.partial(_paged_kernel, scale=scale,
                               page_size=page_size, n_pages=P)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, P),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), q_index),
            pl.BlockSpec((1, page_size, 1, D), kv_index),
            pl.BlockSpec((1, page_size, 1, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), q_index),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(tables, lengths, qg, k_pages, v_pages)
    return out.reshape(B, Hq, D)
