"""Paged multi-query attention: gather K/V through a page table, on-chip.

A window of W queries per sequence attends to a KV prefix that lives in
non-contiguous fixed-size pages (:mod:`repro.serve.pages`).  Instead of
materializing the gathered (B, S, Hkv, D) cache in HBM — the jnp fallback in
:mod:`repro.models.attention` — the kernel streams each sequence's pages
HBM->VMEM directly via a scalar-prefetched page table: BlockSpec index maps
read ``table[b, p]`` to pick the page, so the DMA engine performs the gather
and the online-softmax state (acc, m, l) never leaves VMEM scratch.

Grid = (B, Hkv, pages_per_seq) with pages innermost: one (W*G, page_size)
score tile per step (G = grouped q heads per KV head, W query rows stacked
head-major so row r serves window position ``r // G``).  The causal rule is
per row: window position w may read KV positions ``< lengths[b] + w`` —
``lengths`` counts valid KV entries *including* window position 0's token
(all W tokens' K/V must be written to their pages before the call).  Pages
past the LAST row's limit are skipped with ``pl.when`` — cost is
O(lengths + W), not O(pages_per_seq), which is the whole point of paging.
Rows whose limit ends before a visited page contribute nothing (their
probabilities are zeroed, not renormalized with exp(0)); dead slots
(length 0) produce a zero row 0.

W = 1 is exactly the decode kernel this file used to ship: same grid, same
block shapes, same page gate and mask, so single-token decode stays
bit-identical.

Tensor-parallel serving runs this kernel INSIDE a ``shard_map`` body: q and
the page storage arrive head-sharded (Hq/tp, Hkv/tp local heads), the page
table and lengths replicated, and the grid's Hkv extent is the local head
count — each device streams only its own head shard's pages, which is what
makes the paged step's HBM traffic scale 1/tp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _paged_mq_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                     scale: float, page_size: int,
                     n_pages: int, window: int, group: int,
                     quantized: bool = False):
    if quantized:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(2)
    length = len_ref[b]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Skip pages no row can see: the deepest-reaching row (w = window-1)
    # reads KV positions < length + window - 1.
    @pl.when(p * page_size < length + window - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (W*G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (page_size, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:
            # fused dequant: the int8 page tile was DMA'd HBM->VMEM (the
            # bandwidth win) and the per-(row, head) scale is applied here
            # in VMEM — the full-precision K/V never exists in HBM
            k = k * ks_ref[0, :, 0][:, None]              # (page_size, 1)
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        w_row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        valid = k_pos < length + w_row
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pr = jnp.exp(s - m_new[:, None])
        # A row may be fully masked on a visited page (its limit ends on an
        # earlier page): m_new stays NEG_INF and exp(s - m_new) would be
        # exp(0) = 1.  Zero masked probabilities explicitly — a bitwise
        # no-op for live rows, where exp(NEG_INF - finite) underflows to 0.
        pr = jnp.where(valid, pr, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + pr.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(pr, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention_mq(q, k_pages, v_pages, tables, lengths, *,
                       k_scale=None, v_scale=None,
                       interpret: bool = False):
    """q: (B, W, Hq, D); k_pages/v_pages: (N, page_size, Hkv, D);
    tables: (B, P) int32 page ids; lengths: (B,) int32 valid-KV counts for
    window position 0 (including its own token) -> (B, W, Hq, D).

    Window position w attends to KV positions < lengths + w (per-row causal
    offset); rows past a sequence's data (pad rows, dead slots) are never
    read by callers and may hold garbage softmaxed over trash pages.

    ``k_scale``/``v_scale``: optional (N, page_size, Hkv) per-(row, head)
    dequantization scales for int8 pages.  They ride the same
    scalar-prefetched page-table index map as their value pages and are
    applied to the K/V tile in VMEM right after the DMA — the page stream
    out of HBM stays int8, which is where the 4x bandwidth cut happens.
    """
    B, W, Hq, D = q.shape
    N, page_size, Hkv, _ = k_pages.shape
    P = tables.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    assert (k_scale is None) == (v_scale is None), "pass both scales or none"
    quantized = k_scale is not None
    G = Hq // Hkv
    scale = D ** -0.5

    # (B, W, Hkv, G, D) -> (B, Hkv, W, G, D) -> rows stacked head-major:
    # row r of the (W*G, D) tile is window position r // G, grouped head r % G.
    qg = (q.reshape(B, W, Hkv, G, D).transpose(0, 2, 1, 3, 4)
          .reshape(B, Hkv, W * G, D))
    tables = tables.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    def q_index(b, h, p, tbl, ln):
        return (b, h, 0, 0)

    def kv_index(b, h, p, tbl, ln):
        return (tbl[b, p], 0, h, 0)

    def scale_index(b, h, p, tbl, ln):
        return (tbl[b, p], 0, h)

    kernel = functools.partial(_paged_mq_kernel, scale=scale,
                               page_size=page_size, n_pages=P,
                               window=W, group=G, quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, 1, W * G, D), q_index),
        pl.BlockSpec((1, page_size, 1, D), kv_index),
        pl.BlockSpec((1, page_size, 1, D), kv_index),
    ]
    operands = [qg, k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec((1, page_size, 1), scale_index),
                     pl.BlockSpec((1, page_size, 1), scale_index)]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, W * G, D), q_index),
        scratch_shapes=[
            pltpu.VMEM((W * G, D), jnp.float32),
            pltpu.VMEM((W * G,), jnp.float32),
            pltpu.VMEM((W * G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, W * G, D), q.dtype),
        interpret=interpret,
    )(tables, lengths, *operands)
    return (out.reshape(B, Hkv, W, G, D).transpose(0, 2, 1, 3, 4)
            .reshape(B, W, Hq, D))


def paged_attention(q, k_pages, v_pages, tables, lengths, *,
                    k_scale=None, v_scale=None, interpret: bool = False):
    """Single-query decode: q (B, Hq, D) -> (B, Hq, D).  W=1 window of
    :func:`paged_attention_mq` (bit-identical to the original decode
    kernel); ``lengths`` includes the current token."""
    return paged_attention_mq(q[:, None], k_pages, v_pages, tables, lengths,
                              k_scale=k_scale, v_scale=v_scale,
                              interpret=interpret)[:, 0]
