"""Mamba-2 SSD kernel: chunked state-space dual form with the inter-chunk
state carried in VMEM scratch.

TPU adaptation: the CUDA SSD kernel splits work across warps
with the state in shared memory; here each (batch, head) runs a sequential
chunk sweep — grid (B*H, S/Q) with chunks innermost — holding the (N x P)
state in f32 VMEM scratch.  The *intra*-chunk part is the quadratic
``(C B^T ∘ decay-mask) @ x`` form: three (Q x N)/(Q x Q)/(Q x P) GEMMs that
feed the MXU, which is the whole point of the SSD reformulation — the
recurrence only crosses chunk boundaries.

Layout: xdt (BH, S, P), la (BH, S), Bm/Cm (B, S, N) (single B/C group shared
across heads, as in Mamba-2).  Outputs y (BH, S, P) f32 + final state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, s0_ref, y_ref, sT_ref,
                state_ref, *, q_blk: int, n_c: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)                       # (Q, P)
    la = la_ref[0].astype(jnp.float32)                     # (Q,)
    Bk = b_ref[0].astype(jnp.float32)                      # (Q, N)
    Ck = c_ref[0].astype(jnp.float32)                      # (Q, N)

    cs = jnp.cumsum(la)                                    # inclusive
    total = cs[-1]

    # intra-chunk: y_i = sum_{j<=i} (C_i . B_j) exp(cs_i - cs_j) x_j
    G = jax.lax.dot_general(Ck, Bk, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q_blk, q_blk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q_blk, q_blk), 1)
    dec = jnp.exp(cs[:, None] - cs[None, :])
    M = jnp.where(ii >= jj, G * dec, 0.0)
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # inter-chunk: y_i += (C_i @ state) * exp(cs_i)
    state = state_ref[...]                                 # (N, P)
    y = y + jax.lax.dot_general(Ck, state, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * jnp.exp(cs)[:, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: state = exp(total) state + B^T @ (exp(total - cs) * x)
    wx = x * jnp.exp(total - cs)[:, None]                  # (Q, P)
    state_ref[...] = state * jnp.exp(total) + jax.lax.dot_general(
        Bk, wx, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == n_c - 1)
    def _finish():
        sT_ref[0] = state_ref[...]


def ssd_scan(xdt, la, Bm, Cm, state0=None, *, q_blk: int = 128,
             interpret: bool = False):
    """xdt: (B,S,H,P); la: (B,S,H); Bm,Cm: (B,S,N); state0 (B,H,N,P) f32.

    Returns (y (B,S,H,P) f32, final_state (B,H,N,P) f32).
    """
    B, S, H, Pd = xdt.shape
    N = Bm.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, N, Pd), jnp.float32)
    q_blk = min(q_blk, S)
    assert S % q_blk == 0, (S, q_blk)
    n_c = S // q_blk

    xh = xdt.transpose(0, 2, 1, 3).reshape(B * H, S, Pd)
    lah = la.transpose(0, 2, 1).reshape(B * H, S)
    s0 = state0.reshape(B * H, N, Pd)

    def x_index(bh, ci):
        return (bh, ci, 0)

    def la_index(bh, ci):
        return (bh, ci)

    def bc_index(bh, ci):
        return (bh // H, ci, 0)

    def s_index(bh, ci):
        return (bh, 0, 0)

    y, sT = pl.pallas_call(
        functools.partial(_ssd_kernel, q_blk=q_blk, n_c=n_c),
        grid=(B * H, n_c),
        in_specs=[
            pl.BlockSpec((1, q_blk, Pd), x_index),
            pl.BlockSpec((1, q_blk), la_index),
            pl.BlockSpec((1, q_blk, N), bc_index),
            pl.BlockSpec((1, q_blk, N), bc_index),
            pl.BlockSpec((1, N, Pd), s_index),
        ],
        out_specs=[
            pl.BlockSpec((1, q_blk, Pd), x_index),
            pl.BlockSpec((1, N, Pd), s_index),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, Pd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, N, Pd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, Pd), jnp.float32)],
        interpret=interpret,
    )(xh, lah, Bm, Cm, s0)
    return (y.reshape(B, H, S, Pd).transpose(0, 2, 1, 3),
            sT.reshape(B, H, N, Pd))
