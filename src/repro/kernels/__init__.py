"""Pallas TPU kernels for the compute hot-spots.

Each kernel ships three layers: ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jitted public wrapper, interpret=True off-TPU), ``ref.py``
(pure-jnp oracle used by the allclose tests).
"""
from repro.kernels import ops, ref
