"""Jitted public wrappers around the Pallas kernels.

On TPU runtimes the kernels run compiled; everywhere else (this CPU container,
unit tests) they execute with ``interpret=True`` — same kernel body, Python
evaluation, bit-compatible blocking — which is how the per-kernel allclose
tests validate them against :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import rmsnorm as _rn
from repro.kernels import rwkv6_scan as _rw
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_blk",
                                             "kv_blk"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    q_blk: int = 256, kv_blk: int = 256):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_blk=q_blk, kv_blk=kv_blk,
                               interpret=_interpret())


@jax.jit
def paged_attention(q, k_pages, v_pages, tables, lengths):
    return _pa.paged_attention(q, k_pages, v_pages, tables, lengths,
                               interpret=_interpret())


@jax.jit
def paged_attention_mq(q, k_pages, v_pages, tables, lengths,
                       k_scale=None, v_scale=None):
    # k_scale/v_scale: optional (N, page_size, Hkv) int8-page dequant
    # scales, fused into the kernel's VMEM tile right after the page DMA
    return _pa.paged_attention_mq(q, k_pages, v_pages, tables, lengths,
                                  k_scale=k_scale, v_scale=v_scale,
                                  interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "rows_blk"))
def rmsnorm(x, scale, *, eps: float = 1e-6, rows_blk: int = 256):
    return _rn.rmsnorm(x, scale, eps=eps, rows_blk=rows_blk,
                       interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("t_blk",))
def rwkv6_scan(r, k, v, w, u, state0=None, *, t_blk: int = 64):
    return _rw.rwkv6_scan(r, k, v, w, u, state0, t_blk=t_blk,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("q_blk",))
def ssd_scan(xdt, la, Bm, Cm, state0=None, *, q_blk: int = 128):
    return _ssd.ssd_scan(xdt, la, Bm, Cm, state0, q_blk=q_blk,
                         interpret=_interpret())
