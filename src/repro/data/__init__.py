from repro.data.pipeline import (SyntheticTask, make_batch_fn, make_data_iter,
                                 host_shard_batch)
