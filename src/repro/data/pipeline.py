"""Deterministic synthetic data pipeline, sharded at birth.

Design goals (the same ones a real cluster loader has):

* **Deterministic + restartable**: batch ``i`` is a pure function of
  ``(seed, i)`` — restoring a checkpoint at step ``i`` reproduces the exact
  stream with no loader state to checkpoint.
* **Sharded at birth**: batches are *generated inside jit* with
  ``out_shardings`` matching the train step's expected input sharding, so no
  host->device broadcast of the global batch ever happens (on a real pod each
  host generates only its addressable shard — same code path via GSPMD).
* **Learnable**: tokens follow a noisy affine bigram chain
  (``next = (31 * prev + 7) mod V`` with prob. 0.9, uniform otherwise), so a
  real model trained on it shows a decreasing loss (used by the end-to-end
  example and the trainer integration test).

Modality stubs per the assignment brief: VLM batches carry precomputed patch
embeddings, audio batches precomputed frame embeddings (deterministic
projections of a class id, so they are informative features, not noise).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.mesh.axes import AxisRules, logical_to_sharding


@dataclasses.dataclass(frozen=True)
class SyntheticTask:
    """A deterministic synthetic "dataset" for one (arch, shape) cell."""
    cfg: ModelConfig
    batch: int
    seq_len: int
    seed: int = 0
    noise: float = 0.1

    def _chain(self, key, B, S, vocab):
        """Noisy affine bigram chain — learnable structure."""
        k0, k1, k2 = jax.random.split(key, 3)
        start = jax.random.randint(k0, (B,), 0, vocab)
        flip = jax.random.uniform(k1, (B, S)) < self.noise
        rand = jax.random.randint(k2, (B, S), 0, vocab)

        def step(prev, xs):
            f, r = xs
            nxt = jnp.where(f, r, (31 * prev + 7) % vocab)
            return nxt, nxt

        _, toks = jax.lax.scan(step, start, (flip.T, rand.T))
        return toks.T                                       # (B, S)

    def batch_at(self, step: int) -> dict:
        """Pure function (seed, step) -> batch pytree (host/jit agnostic)."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        B = self.batch

        if cfg.family == "vlm":
            I = cfg.n_image_tokens
            S_txt = self.seq_len - I
            toks = self._chain(key, B, S_txt + 1, cfg.vocab)
            k_img = jax.random.fold_in(key, 1)
            cls = jax.random.randint(k_img, (B, 1, 1), 0, 64)
            d = cfg.d_model
            img = jnp.sin(cls * 0.1 + jnp.arange(I)[None, :, None] * 0.01
                          + jnp.arange(d)[None, None, :] * 0.05)
            labels = jnp.concatenate(
                [jnp.full((B, I), -1, jnp.int32), toks[:, 1:]], axis=1)
            return {"tokens": toks[:, :-1],
                    "image_embeds": img.astype(jnp.dtype(cfg.dtype)),
                    "labels": labels}

        if cfg.family == "audio":
            toks = self._chain(key, B, self.seq_len + 1, cfg.vocab)
            k_f = jax.random.fold_in(key, 1)
            cls = jax.random.randint(k_f, (B, 1, 1), 0, 64)
            F, d = cfg.n_audio_frames, cfg.d_model
            frames = jnp.sin(cls * 0.1 + jnp.arange(F)[None, :, None] * 0.01
                             + jnp.arange(d)[None, None, :] * 0.05)
            return {"frames": frames.astype(jnp.dtype(cfg.dtype)),
                    "tokens": toks[:, :-1], "labels": toks[:, 1:]}

        toks = self._chain(key, B, self.seq_len + 1, cfg.vocab)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch_fn(task: SyntheticTask, mesh=None, rules: AxisRules | None = None,
                  batch_specs: Optional[dict] = None):
    """Jit the generator; with a mesh, outputs are sharded at birth."""
    if mesh is None:
        return jax.jit(task.batch_at)
    shardings = {name: logical_to_sharding(sp.spec, mesh, rules)
                 for name, sp in batch_specs.items()}
    return jax.jit(task.batch_at, out_shardings=shardings)


def make_data_iter(task: SyntheticTask, mesh=None, rules=None,
                   batch_specs=None, start_step: int = 0) -> Iterator[dict]:
    fn = make_batch_fn(task, mesh, rules, batch_specs)
    step = start_step
    while True:
        yield fn(step)
        step += 1


def host_shard_batch(batch: dict, my_rank: int, num_procs: int) -> dict:
    """Paper-faithful host-side split (``get_subproblem_input_args`` on the
    batch axis) — used when data arrives as host numpy, e.g. file loaders."""
    def split(x):
        n = x.shape[0]
        per = n // num_procs
        return x[my_rank * per:(my_rank + 1) * per]

    return jax.tree_util.tree_map(split, batch)
