"""Docs gate: broken intra-repo markdown links and missing docstrings.

Two checks, both enforced by CI (the ``docs`` job) and by
``tests/test_docs.py`` in tier-1:

* **links** — every relative link in a tracked ``*.md`` file must resolve
  to a file or directory inside the repo.  External schemes
  (``http(s)://``, ``mailto:``) and pure in-page anchors (``#...``) are
  skipped; an intra-repo link's ``#fragment`` is stripped before the
  existence check (heading anchors are not validated).
* **docstrings** — every public module in the serving stack
  (``src/repro/serve/*.py`` plus ``src/repro/models/api.py``) must carry a
  module docstring and an ``__all__``, and every public module-level
  ``def`` / ``class`` (and public method of a public class) must carry its
  own docstring.  A method overriding a documented method of a base class
  defined in the same module inherits that documentation (``help()`` walks
  the MRO) and is not flagged.  One-line docstrings count.

Run it directly::

    python tools/check_docs.py            # check everything
    python tools/check_docs.py --links    # markdown links only
    python tools/check_docs.py --docstrings

Exit status 0 = clean, 1 = findings (one per line on stdout).
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — target captured lazily so ")" inside text can't bleed in;
# image links (![alt](target)) match the same way
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

# modules whose public surface must be documented
DOC_MODULES = ("src/repro/serve", "src/repro/models/api.py")


def iter_markdown(repo: Path):
    skip = {".git", ".venv", "node_modules", "__pycache__"}
    for p in sorted(repo.rglob("*.md")):
        if not any(part in skip for part in p.parts):
            yield p


def check_links(repo: Path) -> list[str]:
    problems = []
    for md in iter_markdown(repo):
        text = md.read_text(encoding="utf-8")
        # fenced code blocks hold example syntax, not real links
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if target.startswith("#"):         # in-page anchor
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                problems.append(
                    f"{md.relative_to(repo)}: broken link -> {target}")
    return problems


def _documented_methods(cls: ast.ClassDef, classes: dict) -> set[str]:
    """Method names documented on ``cls`` or any same-module ancestor."""
    out = set()
    stack, seen = [cls], set()
    while stack:
        c = stack.pop()
        if c.name in seen:
            continue
        seen.add(c.name)
        for sub in c.body:
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and ast.get_docstring(sub):
                out.add(sub.name)
        for base in c.bases:
            if isinstance(base, ast.Name) and base.id in classes:
                stack.append(classes[base.id])
    return out


def _missing_docstrings(tree: ast.Module, rel: str) -> list[str]:
    problems = []
    if not ast.get_docstring(tree):
        problems.append(f"{rel}: missing module docstring")
    has_all = any(
        isinstance(n, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "__all__" for t in n.targets)
        for n in tree.body)
    if not has_all:
        problems.append(f"{rel}: missing __all__")
    classes = {n.name: n for n in tree.body if isinstance(n, ast.ClassDef)}
    for node in tree.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            continue
        if node.name.startswith("_"):
            continue
        if not ast.get_docstring(node):
            problems.append(
                f"{rel}:{node.lineno}: public "
                f"{'class' if isinstance(node, ast.ClassDef) else 'function'}"
                f" {node.name!r} has no docstring")
        if isinstance(node, ast.ClassDef):
            inherited = set()
            for base in node.bases:
                if isinstance(base, ast.Name) and base.id in classes:
                    inherited |= _documented_methods(classes[base.id],
                                                     classes)
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and not sub.name.startswith("_") \
                        and not ast.get_docstring(sub) \
                        and sub.name not in inherited:
                    problems.append(
                        f"{rel}:{sub.lineno}: public method "
                        f"{node.name}.{sub.name} has no docstring")
    return problems


def check_docstrings(repo: Path) -> list[str]:
    problems = []
    for entry in DOC_MODULES:
        root = repo / entry
        if root.is_dir():
            files = sorted(root.rglob("*.py"))
        elif root.is_file():
            files = [root]
        else:
            continue
        for f in files:
            rel = str(f.relative_to(repo))
            tree = ast.parse(f.read_text(encoding="utf-8"), filename=rel)
            problems.extend(_missing_docstrings(tree, rel))
    return problems


def main(argv=None) -> int:
    """CLI entry point; returns the process exit status."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--links", action="store_true",
                    help="markdown link check only")
    ap.add_argument("--docstrings", action="store_true",
                    help="docstring/__all__ check only")
    ap.add_argument("--repo", type=Path, default=REPO)
    args = ap.parse_args(argv)
    run_all = not (args.links or args.docstrings)
    problems = []
    if args.links or run_all:
        problems += check_links(args.repo)
    if args.docstrings or run_all:
        problems += check_docstrings(args.repo)
    for p in problems:
        print(p)
    if problems:
        print(f"\n{len(problems)} documentation problem(s)", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
