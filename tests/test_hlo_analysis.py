"""The roofline engine's HLO analyzer, validated on programs with known
analytic costs (this is the instrument every §Roofline number flows through,
so it gets its own tests)."""
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def test_shape_bytes():
    assert H._shape_bytes("f32[256,256]{1,0}") == 256 * 256 * 4
    assert H._shape_bytes("bf16[2,3]{1,0}") == 12
    assert H._shape_bytes("(f32[4]{0}, s32[])") == 20
    assert H._shape_bytes("pred[]") == 1


def test_group_size_parsing():
    assert H._group_size("replica_groups=[4,2]<=[8]", 8) == 2
    assert H._group_size("replica_groups=[2,4]<=[4,2]T(1,0)", 8) == 4
    assert H._group_size("replica_groups={{0,1,2,3}}", 8) == 4
    assert H._group_size("no groups here", 16) == 16


SCAN_HLO = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (t: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %t = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%t), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups=[4,2]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (t: (s32[], f32[8,16])) -> pred[] {
  %t = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t = (s32[], f32[8,16]) tuple(%z, %x)
  %w2 = (s32[], f32[8,16]) while(%t), condition=%cond, body=%body
  ROOT %r = f32[8,16]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_while_trip_count_and_flops():
    st = H.analyze(SCAN_HLO, n_devices=8)
    assert st.while_trips == {"w2": 5}
    # dot: 2 * 8*16 * 16 = 4096 flops per iteration, 5 iterations
    assert st.flops == 5 * 2 * 8 * 16 * 16
    # all-reduce f32[8,16] group 2: wire = 2*(1/2)*512 = 512 bytes x5
    assert st.collective_bytes == pytest.approx(5 * 512)
    assert st.collective_by_type["all-reduce"]["count"] == 5


def test_collective_wire_formulas():
    base = """
HloModule t
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
ENTRY %main (x: f32[4,8]) -> f32[4,8] {
  %x = f32[4,8]{1,0} parameter(0)
  %OP
  ROOT %r = f32[4,8]{1,0} get-tuple-element(%o), index=0
}
"""
    # all-gather result 4x global: per-device result f32[16,8] with g=4
    ag = base.replace("%OP", "%o = (f32[16,8]{1,0}) all-gather(%x), "
                      "replica_groups=[2,4]<=[8], dimensions={0}")
    st = H.analyze(ag, n_devices=8)
    assert st.collective_bytes == pytest.approx((3 / 4) * 16 * 8 * 4)

    # reduce-scatter: result f32[1,8], g=4 -> wire = (g-1) * result
    rs = base.replace("%OP", "%o = (f32[1,8]{1,0}) reduce-scatter(%x), "
                      "replica_groups=[2,4]<=[8], to_apply=%add")
    st = H.analyze(rs, n_devices=8)
    assert st.collective_bytes == pytest.approx(3 * 1 * 8 * 4)

    # collective-permute: wire = size
    cp = base.replace("%OP", "%o = (f32[4,8]{1,0}) collective-permute(%x), "
                      "source_target_pairs={{0,1}}")
    st = H.analyze(cp, n_devices=8)
    assert st.collective_bytes == pytest.approx(4 * 8 * 4)


def test_fusion_bodies_excluded_from_bytes_but_dots_counted():
    hlo = """
HloModule t
%fused (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8]{1,0} parameter(0)
  %w = f32[8,8]{1,0} constant({...})
  ROOT %d = f32[8,8]{1,0} dot(%p, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  ROOT %f = f32[8,8]{1,0} fusion(%x), kind=kOutput, calls=%fused
}
"""
    st = H.analyze(hlo, n_devices=1)
    assert st.flops == 2 * 8 * 8 * 8            # dot inside fusion counted
    # bytes: only the fusion line (result + operand), not the internal dot
    assert st.bytes_accessed == pytest.approx(2 * 8 * 8 * 4)


def test_real_program_flops_match_analytic():
    """End-to-end: compiled scan-of-matmuls in a subprocess with 8 devices."""
    import os
    import subprocess
    import sys
    import textwrap
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        W = jax.ShapeDtypeStruct((6, 512, 256), jnp.bfloat16,
                                 sharding=NamedSharding(mesh, P(None, "model", None)))
        A = jax.ShapeDtypeStruct((64, 512), jnp.bfloat16,
                                 sharding=NamedSharding(mesh, P("data", "model")))
        def f(a, w):
            def body(x, wi):
                y = x @ wi
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, P("data", None)))
                return jnp.pad(y, ((0,0),(0,256)))[:, :512].astype(x.dtype), None
            x, _ = jax.lax.scan(body, a, w)
            return x.sum()
        comp = jax.jit(f).lower(A, W).compile()
        st = analyze(comp.as_text(), n_devices=8)
        expect = 6 * 2 * 64 * 512 * 256 / 8
        assert abs(st.flops - expect) / expect < 0.01, (st.flops, expect)
        assert st.while_trips and list(st.while_trips.values())[0] == 6
        print("OK", st.flops)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300,
                         env=dict(os.environ, PYTHONPATH=os.path.join(root, "src")))
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
