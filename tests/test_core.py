"""Unit + property tests for the paper's generic layer (repro.core)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (balanced_counts, collect_subproblem_output_args,
                        find_optimal_workload, get_subproblem_input_args,
                        pad_to_multiple, simple_partitioning, solve_problem,
                        time_integration, vmap_solve_problem)
from repro.core.comm import SerialComm
from repro.core.load_balance import redistribute_plan, redistribute_work
from repro.core.functional import host_task_farm


# ---------------------------------------------------------------------------
# simple_partitioning — the paper's ±1 rule (property tests)
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(1, 64))
def test_partitioning_conserves_and_balances(length, procs):
    parts = simple_partitioning(length, procs)
    assert parts.sum() == length                     # nothing lost
    assert parts.max() - parts.min() <= 1            # ±1 balance
    assert (parts >= 0).all()


@given(st.integers(0, 500), st.integers(1, 16))
def test_get_subproblem_input_args_partitions_exactly(n, procs):
    items = list(range(n))
    chunks = [get_subproblem_input_args(items, r, procs)
              for r in range(procs)]
    flat = [x for c in chunks for x in c]
    assert flat == items                             # order-preserving cover


@given(st.integers(0, 1000), st.integers(1, 64))
def test_pad_to_multiple(n, m):
    p = pad_to_multiple(n, m)
    assert p >= n and p % m == 0 and p - n < m


# ---------------------------------------------------------------------------
# find_optimal_workload — paper-faithful timing-proportional balance
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(0.1, 100.0), min_size=1, max_size=12),
       st.lists(st.integers(0, 500), min_size=1, max_size=12))
def test_find_optimal_workload_conserves(timings, work):
    n = min(len(timings), len(work))
    timings, work = timings[:n], work[:n]
    out = find_optimal_workload(timings, work)
    assert out.sum() == sum(work)                    # work conserved
    assert (out >= 0).all()


def test_find_optimal_workload_inverse_to_time():
    # a 2x slower worker gets ~half the items
    out = find_optimal_workload([1.0, 2.0], [50, 50])
    assert out[0] > out[1]
    assert abs(out[0] - 2 * out[1]) <= 2


@given(st.lists(st.integers(0, 100), min_size=2, max_size=8))
def test_redistribute_plan_reaches_target(work):
    target = np.asarray(
        find_optimal_workload([1.0] * len(work), work))
    plan = redistribute_plan(work, target)
    cur = np.asarray(work, np.int64)
    for src, dst, n in plan:
        assert n > 0
        cur[src] -= n
        cur[dst] += n
    assert (cur == target).all()


# ---------------------------------------------------------------------------
# solve_problem tiers — the paper's §2 parabola example, verbatim
# ---------------------------------------------------------------------------

class Parabola:
    """The paper's motivating example."""

    def __init__(self, m, n, L):
        self.m, self.n, self.L = m, n, L

    def initialize(self):
        x = np.linspace(0, self.L, self.n)
        a_vals = np.linspace(-1, 1, self.m)
        b_vals = np.linspace(-1, 1, self.m)
        self.input_args = []
        for a in a_vals:
            for b in b_vals:
                self.input_args.append(((x,), {"a": a, "b": b, "c": 5}))
        return self.input_args

    def func(self, x, a=0, b=0, c=1):
        return a * x ** 2 + b * x + c

    def finalize(self, output):
        self.ab = []
        for inp, result in zip(self.input_args, output):
            if min(result) < 0:
                self.ab.append((inp[1]["a"], inp[1]["b"]))
        return self.ab


def test_solve_problem_parabola():
    p = Parabola(10, 20, 10)
    ab = solve_problem(p.initialize, p.func, p.finalize)
    # every flagged (a, b) really does go negative somewhere
    x = np.linspace(0, 10, 20)
    for a, b in ab:
        assert (a * x ** 2 + b * x + 5).min() < 0
    assert len(ab) > 0


def test_vmap_solve_problem_matches_serial():
    m, n, L = 8, 16, 10.0

    def initialize():
        a = jnp.linspace(-1, 1, m)
        b = jnp.linspace(-1, 1, m)
        aa, bb = jnp.meshgrid(a, b, indexing="ij")
        return {"a": aa.ravel(), "b": bb.ravel()}

    x = jnp.linspace(0, L, n)

    def func(task):
        return task["a"] * x ** 2 + task["b"] * x + 5

    got = vmap_solve_problem(initialize, func, lambda o: o)
    tasks = initialize()
    want = jnp.stack([func({"a": a, "b": b})
                      for a, b in zip(tasks["a"], tasks["b"])])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_time_integration_contract():
    class Counter:
        def __init__(self):
            self.n = 3
            self.finalized = []

        def __len__(self):
            return self.n

        def finalize_timestep(self, old, new):
            self.finalized.append((old, new))

    def initialize():
        return Counter(), 4

    def do_timestep(c):
        c.n += 1
        return c.n

    out = time_integration(initialize, do_timestep,
                           lambda res: res)
    assert out == [4, 5, 6, 7]


def test_host_task_farm_straggler_redispatch():
    import time as _t
    calls = {"n": 0}

    def slow():
        calls["n"] += 1
        _t.sleep(0.05 if calls["n"] == 1 else 0.0)
        return 42

    tasks = [lambda: 1] * 6 + [slow]
    results, stats = host_task_farm(tasks, deadline_factor=3.0)
    assert results[:6] == [1] * 6 and results[6] == 42
    assert stats["stragglers"] == [6]       # re-dispatched once
    assert calls["n"] == 2


# ---------------------------------------------------------------------------
# SPMD count-based rebalancing (single-shard semantics via SerialComm)
# ---------------------------------------------------------------------------

@given(st.integers(1, 32), st.integers(0, 32))
@settings(max_examples=20, deadline=None)
def test_redistribute_work_serial_identity(cap, count):
    count = min(count, cap)
    data = jnp.arange(cap * 2.0).reshape(cap, 2)
    comm = SerialComm()
    new_data, new_count = redistribute_work(data, jnp.asarray(count), comm)
    assert int(new_count) == count
    np.testing.assert_allclose(new_data[:count], data[:count])
    # dead slots zeroed
    np.testing.assert_allclose(new_data[count:], 0.0)


@given(st.integers(0, 100), st.integers(1, 9))
@settings(deadline=None)
def test_balanced_counts(total, n):
    c = np.asarray(balanced_counts(jnp.asarray(total), n))
    assert c.sum() == total and c.max() - c.min() <= 1


def test_collect_serial():
    out = collect_subproblem_output_args({"x": jnp.arange(4.0)}, SerialComm(),
                                         tiled=True)
    np.testing.assert_allclose(out["x"], np.arange(4.0))
