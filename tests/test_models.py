"""Per-architecture smoke tests (assignment requirement): every assigned arch
instantiates its REDUCED config, runs one forward/train step on CPU, asserts
output shapes and finiteness; decode/prefill consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.models.api import build_model

ARCHS = list_archs()


class _Shape:
    global_batch, seq_len = 2, 32
    name, kind = "smoke", "train"


def _batch_for(model):
    specs = model.train_batch_specs(_Shape)
    rng = np.random.default_rng(0)
    batch = {}
    for name, sp in specs.items():
        if jnp.issubdtype(sp.dtype, jnp.integer):
            arr = rng.integers(0, model.cfg.vocab, sp.shape)
            batch[name] = jnp.asarray(arr, sp.dtype)
        else:
            batch[name] = jnp.asarray(rng.normal(size=sp.shape) * 0.02,
                                      sp.dtype)
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    key = jax.random.PRNGKey(0)
    for arch in ARCHS:
        cfg = smoke_config(arch).replace(remat="none")
        if cfg.n_experts:
            # generous capacity: token drops are legitimate MoE behaviour but
            # would break the exact prefill/decode consistency check below
            cfg = cfg.replace(capacity_factor=8.0)
        model = build_model(cfg)
        out[arch] = (model, model.init(key))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_finite_and_grads_flow(arch, built):
    model, params = built[arch]
    batch = _batch_for(model)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: model.loss(p, b, None),
                           has_aux=True))(params, batch)
    assert jnp.isfinite(loss), arch
    assert 1.0 < float(loss) < 20.0, (arch, float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch       # gradients flow everywhere


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss(arch, built):
    from repro.optim import AdamWConfig
    from repro.train import make_train_step
    model, params = built[arch]
    batch = _batch_for(model)
    opt = AdamWConfig(peak_lr=3e-3, warmup_steps=1, decay_steps=20)
    step = make_train_step(model, opt, donate=False)
    from repro.optim.adamw import adamw_init
    state = {"params": params, "opt": adamw_init(params, opt)}
    losses = []
    for _ in range(8):
        state, out = step(state, batch)   # same batch: loss must drop
        losses.append(float(out["loss"]))
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, built):
    """Teacher-forcing consistency: decoding token t with a cache built from
    tokens[:t] gives the same hidden as prefilling tokens[:t+1]."""
    model, params = built[arch]
    cfg = model.cfg
    rng = np.random.default_rng(1)
    B, S = 2, 8
    batch = _batch_for(model)
    pb = {k: batch[k] for k in model.prefill_batch_specs(_Shape)}
    # shorten the token stream to S for the consistency check
    pb["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    max_len = S + 4 + (cfg.n_image_tokens if cfg.family == "vlm" else 0)

    state, hidden_full = jax.jit(
        lambda p, b: model.prefill(p, b, None, max_len))(params, pb)

    # now prefill S-1 then decode the final token
    pb_short = dict(pb, tokens=pb["tokens"][:, :-1])
    state2, _ = jax.jit(
        lambda p, b: model.prefill(p, b, None, max_len))(params, pb_short)
    last_tok = pb["tokens"][:, -1:]
    pos = jnp.asarray(S - 1 + getattr(model, "_decode_pos_offset", 0),
                      jnp.int32)
    if cfg.family == "vlm":
        pos = jnp.asarray(cfg.n_image_tokens + S - 1, jnp.int32)
    state2, logits_dec = jax.jit(
        lambda p, s, t, q: model.decode_step(p, s, t, q, None))(
        params, state2, last_tok, pos)

    logits_full = model.lm_head(params, hidden_full[:, -1:], None)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_are_bounded():
    """Router with capacity factor: dropped fraction stays small on random
    inputs (the balancing loss pushes towards the paper's balanced target)."""
    from repro.models import moe
    cfg = smoke_config("qwen3-moe-235b-a22b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(model)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b, None))(params, batch)
    assert jnp.isfinite(metrics["aux"])
    # aux (switch) loss near 1.0 = balanced; hugely above = collapsed router
    assert float(metrics["aux"]) < 4.0


def test_gemma3_local_global_pattern():
    cfg = smoke_config("gemma3-4b")
    wins = [cfg.window_for_layer(i) for i in range(cfg.n_layers)]
    assert wins[2] is None                      # every 3rd layer global (smoke)
    assert wins[0] == cfg.local_window
    full = smoke_config("gemma3-4b").replace(n_layers=34, global_every=6,
                                             local_window=1024)
    wins = [full.window_for_layer(i) for i in range(34)]
    assert sum(w is None for w in wins) == 5    # 34 layers -> 5 globals
    assert wins[5] is None and wins[0] == 1024  # 5:1 pattern
