"""Per-kernel allclose tests: Pallas (interpret=True on CPU) vs ref oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 64), (3, 37, 128), (1, 256), (257, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = _arr(shape, dtype)
    s = _arr(shape[-1:], jnp.float32)
    got = ops.rmsnorm(x, s)
    want = ref.rmsnorm(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D,causal,window", [
    (2, 256, 256, 4, 2, 32, True, None),     # GQA causal
    (1, 512, 512, 2, 2, 16, True, 100),      # sliding window
    (2, 256, 256, 4, 1, 32, False, None),    # bidirectional, MQA
    (1, 128, 128, 8, 8, 64, True, None),     # MHA
    (1, 384, 384, 2, 1, 32, True, 64),       # window + GQA, 3 tiles
])
def test_flash_attention_vs_ref(B, Sq, Sk, Hq, Hkv, D, causal, window):
    q = _arr((B, Sq, Hq, D))
    k = _arr((B, Sk, Hkv, D))
    v = _arr((B, Sk, Hkv, D))
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_blk=128, kv_blk=128)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q = _arr((1, 256, 4, 32), jnp.bfloat16)
    k = _arr((1, 256, 2, 32), jnp.bfloat16)
    v = _arr((1, 256, 2, 32), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, q_blk=128, kv_blk=128)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_attention_matches_model_attention():
    """Kernel == the models' jnp online-softmax path (the runtime fallback)."""
    from repro.models.attention import gqa_attention
    q = _arr((2, 256, 4, 32))
    k = _arr((2, 256, 2, 32))
    v = _arr((2, 256, 2, 32))
    a = ops.flash_attention(q, k, v, causal=True, q_blk=128, kv_blk=128)
    b = gqa_attention(q, k, v, causal=True, kv_chunk=64)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,K,V,t_blk", [
    (2, 128, 3, 16, 16, 32),
    (1, 64, 2, 8, 8, 64),     # single tile
    (1, 192, 1, 32, 16, 64),  # K != V
])
def test_rwkv6_scan(B, S, H, K, V, t_blk):
    r = _arr((B, S, H, K))
    k = _arr((B, S, H, K), scale=0.3)
    v = _arr((B, S, H, V))
    w = jnp.asarray(RNG.uniform(0.8, 0.999, (B, S, H, K)), jnp.float32)
    u = _arr((H, K))
    s0 = _arr((B, H, K, V), scale=0.1)
    y1, f1 = ops.rwkv6_scan(r, k, v, w, u, s0, t_blk=t_blk)
    y2, f2 = ref.rwkv6_scan(r, k, v, w, u, s0)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(f1, f2, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ssd (mamba-2) scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,q_blk", [
    (2, 256, 3, 16, 8, 64),
    (1, 128, 2, 32, 16, 128),  # single chunk
    (1, 512, 1, 8, 4, 32),     # many chunks
])
def test_ssd_scan(B, S, H, P, N, q_blk):
    xdt = _arr((B, S, H, P), scale=0.1)
    la = jnp.asarray(np.log(RNG.uniform(0.8, 0.999, (B, S, H))), jnp.float32)
    Bm = _arr((B, S, N), scale=0.3)
    Cm = _arr((B, S, N), scale=0.3)
    s0 = _arr((B, H, N, P), scale=0.1)
    y1, f1 = ops.ssd_scan(xdt, la, Bm, Cm, s0, q_blk=q_blk)
    y2, f2 = ref.ssd_scan(xdt, la, Bm, Cm, s0)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(f1, f2, rtol=1e-4, atol=1e-4)


def test_ssd_kernel_matches_model_chunked_path():
    """Kernel == the zamba model's jnp chunked SSD implementation."""
    from repro.models.mamba2 import _ssd_chunked
    B, S, H, P, N = 2, 256, 3, 16, 8
    xdt = _arr((B, S, H, P), scale=0.1)
    la = jnp.asarray(np.log(RNG.uniform(0.8, 0.999, (B, S, H))), jnp.float32)
    Bm = _arr((B, S, N), scale=0.3)
    Cm = _arr((B, S, N), scale=0.3)
    y1, f1 = ops.ssd_scan(xdt, la, Bm, Cm, q_blk=64)
    dt = jnp.ones((B, S, H))
    y2, f2 = _ssd_chunked(xdt, dt, jnp.exp(la), Bm, Cm, chunk=64)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(f1, f2, rtol=1e-4, atol=1e-4)


def test_rwkv_kernel_matches_model_time_mix_recurrence():
    """Kernel recurrence == rwkv6 model block's lax.scan recurrence."""
    B, S, H, K = 1, 64, 2, 16
    r = _arr((B, S, H, K)); k = _arr((B, S, H, K), scale=0.3)
    v = _arr((B, S, H, K)); u = _arr((H, K))
    w = jnp.asarray(RNG.uniform(0.9, 0.999, (B, S, H, K)), jnp.float32)

    def model_step(S_, xs):
        r_t, k_t, v_t, w_t = xs
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S_ + u[..., None] * kv)
        S_ = w_t[..., None] * S_ + kv
        return S_, y

    xs = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          w.swapaxes(0, 1))
    fin, ys = jax.lax.scan(model_step, jnp.zeros((B, H, K, K)), xs)
    y_kernel, fin_kernel = ops.rwkv6_scan(r, k, v, w, u, t_blk=32)
    np.testing.assert_allclose(y_kernel, ys.swapaxes(0, 1), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(fin_kernel, fin, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# paged attention (decode through a page table)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,D,ps,N,P", [
    (3, 4, 2, 16, 8, 12, 4),      # GQA
    (2, 4, 1, 32, 16, 6, 3),      # MQA
    (1, 8, 8, 64, 8, 4, 2),       # MHA
    (4, 2, 2, 16, 4, 20, 8),      # many small pages
])
def test_paged_attention_vs_ref(B, Hq, Hkv, D, ps, N, P):
    q = _arr((B, Hq, D))
    kp = _arr((N, ps, Hkv, D))
    vp = _arr((N, ps, Hkv, D))
    tables = jnp.asarray(RNG.integers(0, N, size=(B, P)), jnp.int32)
    # ragged validity lengths, incl. a full table and a partial last page
    lens = RNG.integers(1, P * ps + 1, size=B)
    lens[0] = P * ps
    lengths = jnp.asarray(lens, jnp.int32)
    got = ops.paged_attention(q, kp, vp, tables, lengths)
    want = ref.paged_attention(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_paged_attention_dead_slot_is_zero():
    """length-0 rows (retired decode slots) must emit exact zeros, not a
    softmax over garbage."""
    q = _arr((2, 4, 16))
    kp = _arr((6, 8, 2, 16))
    vp = _arr((6, 8, 2, 16))
    tables = jnp.zeros((2, 3), jnp.int32)
    lengths = jnp.asarray([0, 5], jnp.int32)
    got = np.asarray(ops.paged_attention(q, kp, vp, tables, lengths))
    assert np.all(got[0] == 0.0)
    assert np.any(got[1] != 0.0)


def test_paged_attention_matches_contiguous_flash():
    """A page table laid out contiguously must reproduce plain decode
    attention on the equivalent dense cache."""
    B, Hq, Hkv, D, ps = 2, 4, 2, 16, 8
    P = 4
    S = P * ps
    k = _arr((B, S, Hkv, D))
    v = _arr((B, S, Hkv, D))
    q = _arr((B, 1, Hq, D))
    lengths = jnp.asarray([S, 19], jnp.int32)
    # scatter the dense cache into per-sequence pages
    kp = k.reshape(B * P, ps, Hkv, D)
    vp = v.reshape(B * P, ps, Hkv, D)
    tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    got = ops.paged_attention(q[:, 0], kp, vp, tables, lengths)
    from repro.models.attention import gqa_attention
    want = gqa_attention(q, k, v, causal=True, q_offset=lengths - 1,
                         kv_valid_len=lengths, kv_chunk=S)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_paged_attention_bf16():
    q = _arr((2, 4, 16), jnp.bfloat16)
    kp = _arr((8, 8, 2, 16), jnp.bfloat16)
    vp = _arr((8, 8, 2, 16), jnp.bfloat16)
    tables = jnp.asarray(RNG.integers(0, 8, size=(2, 3)), jnp.int32)
    lengths = jnp.asarray([24, 7], jnp.int32)
    got = ops.paged_attention(q, kp, vp, tables, lengths)
    want = ref.paged_attention(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# paged multi-query attention (decode / spec-verify / chunked-prefill windows)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("W", [1, 2, 8])
@pytest.mark.parametrize("B,Hq,Hkv,D,ps,N,P", [
    (3, 4, 2, 16, 8, 12, 4),      # GQA
    (2, 4, 1, 32, 16, 6, 3),      # MQA
    (1, 8, 8, 64, 8, 4, 2),       # MHA
])
def test_paged_attention_mq_vs_ref(W, B, Hq, Hkv, D, ps, N, P):
    """Window kernel vs oracle across ragged per-row offsets: every slot at
    a different cached length, including partial last pages and a window
    whose last row lands exactly on the table's capacity."""
    q = _arr((B, W, Hq, D))
    kp = _arr((N, ps, Hkv, D))
    vp = _arr((N, ps, Hkv, D))
    tables = jnp.asarray(RNG.integers(0, N, size=(B, P)), jnp.int32)
    # row w of slot b sees lengths[b] + w keys; keep the deepest row in range
    lens = RNG.integers(1, P * ps - W + 2, size=B)
    lens[0] = P * ps - W + 1                 # full table for the last row
    lengths = jnp.asarray(lens, jnp.int32)
    got = ops.paged_attention_mq(q, kp, vp, tables, lengths)
    want = ref.paged_attention_mq(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_paged_attention_mq_w1_is_bitwise_decode():
    """W=1 must be the decode kernel, bit for bit — the engine's decode
    stream guarantees hang off this equivalence."""
    q = _arr((3, 4, 16))
    kp = _arr((10, 8, 2, 16))
    vp = _arr((10, 8, 2, 16))
    tables = jnp.asarray(RNG.integers(0, 10, size=(3, 4)), jnp.int32)
    lengths = jnp.asarray([0, 7, 32], jnp.int32)
    dec = np.asarray(ops.paged_attention(q, kp, vp, tables, lengths))
    mq = np.asarray(ops.paged_attention_mq(q[:, None], kp, vp, tables,
                                           lengths)[:, 0])
    np.testing.assert_array_equal(dec, mq)


def test_paged_attention_mq_dead_slot_row0_is_zero():
    """length-0 slots (dead decode slots) emit an exact-zero first row;
    deeper rows are never read by the engine."""
    q = _arr((2, 4, 4, 16))
    kp = _arr((6, 8, 2, 16))
    vp = _arr((6, 8, 2, 16))
    tables = jnp.zeros((2, 3), jnp.int32)
    lengths = jnp.asarray([0, 5], jnp.int32)
    got = np.asarray(ops.paged_attention_mq(q, kp, vp, tables, lengths))
    assert np.all(got[0, 0] == 0.0)
    assert np.any(got[1] != 0.0)


def test_paged_attention_mq_trash_page_rows_isolated():
    """Pad rows route their K/V to the pool's trash page (last page id, the
    verify-path convention for short windows / dead slots): whatever lands
    there must not perturb rows whose tables never reference it."""
    B, W, Hq, Hkv, D, ps, P = 2, 4, 4, 2, 16, 8, 3
    N = 7                                    # pages 0..5 live, 6 = trash
    q = _arr((B, W, Hq, D))
    kp = _arr((N, ps, Hkv, D))
    vp = _arr((N, ps, Hkv, D))
    tables = jnp.asarray(RNG.integers(0, N - 1, size=(B, P)), jnp.int32)
    lengths = jnp.asarray([5, ps * P - W + 1], jnp.int32)
    base = np.asarray(ops.paged_attention_mq(q, kp, vp, tables, lengths))
    # trash the trash page — live-row outputs must be bit-identical
    kp2 = kp.at[N - 1].set(1e4)
    vp2 = vp.at[N - 1].set(-1e4)
    got = np.asarray(ops.paged_attention_mq(q, kp2, vp2, tables, lengths))
    np.testing.assert_array_equal(base, got)
    np.testing.assert_allclose(
        base, np.asarray(ref.paged_attention_mq(q, kp, vp, tables, lengths)),
        rtol=2e-4, atol=2e-4)


def test_paged_attention_mq_matches_window_over_contiguous_cache():
    """A contiguous page layout must reproduce the jnp fallback's windowed
    attention on the equivalent dense cache (the model-side oracle used by
    paged_window_attention)."""
    B, W, Hq, Hkv, D, ps = 2, 4, 4, 2, 16, 8
    P = 4
    S = P * ps
    k = _arr((B, S, Hkv, D))
    v = _arr((B, S, Hkv, D))
    q = _arr((B, W, Hq, D))
    n_cached = jnp.asarray([11, S - W], jnp.int32)   # window 0's position
    kp = k.reshape(B * P, ps, Hkv, D)
    vp = v.reshape(B * P, ps, Hkv, D)
    tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    got = ops.paged_attention_mq(q, kp, vp, tables, n_cached + 1)
    from repro.models.attention import gqa_attention
    want = gqa_attention(q, k, v, causal=True, q_offset=n_cached,
                         kv_valid_len=n_cached + W, kv_chunk=S)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_paged_attention_mq_bf16():
    q = _arr((2, 3, 4, 16), jnp.bfloat16)
    kp = _arr((8, 8, 2, 16), jnp.bfloat16)
    vp = _arr((8, 8, 2, 16), jnp.bfloat16)
    tables = jnp.asarray(RNG.integers(0, 8, size=(2, 3)), jnp.int32)
    lengths = jnp.asarray([20, 7], jnp.int32)
    got = ops.paged_attention_mq(q, kp, vp, tables, lengths)
    want = ref.paged_attention_mq(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)
