"""Disaggregated prefill/decode tests: KV page handoff must be invisible.

The acceptance bar is bit-parity: a DisaggServeEngine (prefiller +
decoder, pages transferred via gather/scatter, no recompute) must produce
token streams identical to the monolithic ServeEngine on the same
workload — dense and MoE families, prefix cache on/off, kv_quant int8/off
(the int8 payload travels with its scale leaves), under forced decoder
preemption, and with the thread-farm executor overlapping the roles.

Greedy sampling ignores the PRNG key and seeded requests fold
``len(output)`` into their own seed, so a token depends only on the model
and the tokens before it — which is exactly what makes this parity
testable bit-for-bit.
"""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import DisaggServeEngine, ServeEngine


@pytest.fixture(scope="module")
def dense():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe():
    cfg = smoke_config("qwen3-moe-235b-a22b").replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(1))


def _prompts(vocab):
    # two identical prompts (prefix-cache sharing), one long (chunked
    # prefill), one short — the standard parity workload
    return [np.arange(1, 20, dtype=np.int32) % vocab,
            np.arange(1, 20, dtype=np.int32) % vocab,
            np.arange(5, 40, dtype=np.int32) % vocab,
            np.arange(2, 9, dtype=np.int32) % vocab]


def _streams(engine, prompts, max_new=8, **submit_kw):
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new, **submit_kw)
    finished = engine.run_until_drained()
    engine.close()
    assert len(finished) == len(prompts)
    return {r.rid: list(r.output) for r in finished}


KW = dict(max_slots=3, max_len=64, page_size=8, num_pages=24,
          prefill_chunk=16)


@pytest.mark.parametrize("prefix_cache", [False, True])
@pytest.mark.parametrize("kv_quant", [None, "int8"])
def test_disagg_matches_monolithic_dense(dense, prefix_cache, kv_quant):
    model, params = dense
    mono = _streams(ServeEngine(model, params, prefix_cache=prefix_cache,
                                kv_quant=kv_quant, **KW),
                    _prompts(model.cfg.vocab))
    dis = _streams(DisaggServeEngine(model, params,
                                     prefix_cache=prefix_cache,
                                     kv_quant=kv_quant, **KW),
                   _prompts(model.cfg.vocab))
    assert mono == dis


@pytest.mark.parametrize("prefix_cache", [False, True])
@pytest.mark.parametrize("kv_quant", [None, "int8"])
def test_disagg_matches_monolithic_moe(moe, prefix_cache, kv_quant):
    model, params = moe
    mono = _streams(ServeEngine(model, params, prefix_cache=prefix_cache,
                                kv_quant=kv_quant, **KW),
                    _prompts(model.cfg.vocab))
    dis = _streams(DisaggServeEngine(model, params,
                                     prefix_cache=prefix_cache,
                                     kv_quant=kv_quant, **KW),
                   _prompts(model.cfg.vocab))
    assert mono == dis


def test_disagg_under_forced_preemption(dense):
    """A decode pool too small for every injected request forces
    preemption on the decoder; recompute-style re-prefill must preserve
    the streams, so parity with the monolithic engine (given the same
    tight pool) still holds bit-for-bit."""
    model, params = dense
    tight = dict(max_slots=3, max_len=32, page_size=4, num_pages=8,
                 prefill_chunk=8)
    prompts = [np.arange(1, 8, dtype=np.int32) % model.cfg.vocab,
               np.arange(3, 12, dtype=np.int32) % model.cfg.vocab,
               np.arange(7, 13, dtype=np.int32) % model.cfg.vocab]
    mono_eng = ServeEngine(model, params, **tight)
    mono = _streams(mono_eng, prompts, max_new=12)
    dis_eng = DisaggServeEngine(model, params, prefill_pages=16, **tight)
    dis = _streams(dis_eng, prompts, max_new=12)
    assert mono == dis
    assert dis_eng.decoder.stats["preemptions"] > 0, \
        "the tight pool was meant to force decoder preemption"


def test_disagg_thread_executor_parity(dense):
    """The prefill and decode stages genuinely overlapping on farm threads
    may interleave ticks differently, but never change a token."""
    model, params = dense
    mono = _streams(ServeEngine(model, params, **KW),
                    _prompts(model.cfg.vocab))
    dis = _streams(DisaggServeEngine(model, params, executor="thread", **KW),
                   _prompts(model.cfg.vocab))
    assert mono == dis


def test_disagg_seeded_sampling_parity(dense):
    """Seeded per-request sampling folds (seed, len(output)) — independent
    of which engine's tick draws — so sampled streams transfer too."""
    model, params = dense
    prompts = _prompts(model.cfg.vocab)
    mono = {}
    eng = ServeEngine(model, params, **KW)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=8, seed=i)
    mono = {r.rid: list(r.output) for r in eng.run_until_drained()}
    eng.close()
    eng = DisaggServeEngine(model, params, **KW)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=8, seed=i)
    dis = {r.rid: list(r.output) for r in eng.run_until_drained()}
    eng.close()
    assert mono == dis


def test_disagg_instant_finish_stays_on_prefiller(dense):
    """A one-token budget finishes at the first token: the request retires
    on the prefiller and no handoff packet is ever created for it."""
    model, params = dense
    eng = DisaggServeEngine(model, params, **KW)
    eng.submit(np.arange(1, 9, dtype=np.int32) % model.cfg.vocab,
               max_new_tokens=1)
    finished = eng.run_until_drained()
    assert len(finished) == 1 and len(finished[0].output) == 1
    assert eng.prefiller.stats["kv_handoffs"] == 0
    assert eng.decoder.stats["kv_injections"] == 0
    assert finished[0] in eng.prefiller.finished
    eng.close()


def test_disagg_error_requests_retire_on_prefiller(dense):
    """An unprefillable request (empty prompt) errors out on the prefiller
    without disturbing healthy requests on either side."""
    model, params = dense
    eng = DisaggServeEngine(model, params, **KW)
    ok = eng.submit(np.arange(1, 9, dtype=np.int32) % model.cfg.vocab,
                    max_new_tokens=4)
    bad = eng.submit(np.asarray([], np.int32), max_new_tokens=4)
    finished = {r.rid: r for r in eng.run_until_drained()}
    assert finished[bad].error is not None and not finished[bad].output
    assert finished[ok].error is None and len(finished[ok].output) == 4
    eng.close()


def test_disagg_handoff_accounting_and_clean_pools(dense):
    """Every handoff is injected exactly once, and after draining both
    pools hold zero in-use pages (everything free or parked in the prefix
    cache) — the engine-level face of the conservation property."""
    model, params = dense
    eng = DisaggServeEngine(model, params, **KW)
    _streams(eng, _prompts(model.cfg.vocab))
    assert eng.prefiller.stats["kv_handoffs"] == 4
    assert eng.decoder.stats["kv_injections"] == 4
    assert not eng._pending and not eng.prefiller.handoffs
    for pool in (eng.prefiller.pool, eng.decoder.pool):
        assert pool.pages_in_use == 0
        assert pool.pages_free + pool.pages_cached == pool.num_pages


def test_disagg_backpressure_with_tiny_prefill_pool(dense):
    """In-flight packets pin prefiller pages, so a tiny prefill pool
    stalls admission until the decoder drains — but the run still
    completes with parity."""
    model, params = dense
    small = dict(max_slots=2, max_len=32, page_size=4, num_pages=8,
                 prefill_chunk=8)
    prompts = [np.arange(1, 8, dtype=np.int32) % model.cfg.vocab,
               np.arange(2, 12, dtype=np.int32) % model.cfg.vocab,
               np.arange(3, 10, dtype=np.int32) % model.cfg.vocab]
    mono = _streams(ServeEngine(model, params, **small), prompts, max_new=6)
    dis = _streams(DisaggServeEngine(model, params, prefill_pages=8,
                                     **small), prompts, max_new=6)
    assert mono == dis


def test_prefill_only_flag_validation(dense):
    model, params = dense
    with pytest.raises(ValueError, match="prefill_only requires the paged"):
        ServeEngine(model, params, paged=False, prefill_only=True)
    with pytest.raises(ValueError, match="spec_decode on a prefill_only"):
        ServeEngine(model, params, prefill_only=True, spec_decode="ngram",
                    **KW)
    eng = ServeEngine(model, params, paged=False)
    with pytest.raises(ValueError, match="requires the paged KV engine"):
        eng.inject_prefilled(None)
    eng.close()
