"""Traffic harness tests: seeded determinism, arrival-process statistics,
metric arithmetic, and trace-replay round-trips.

The statistical tests run under hypothesis (the real package in CI's props
job; the deterministic stub elsewhere) over random (rate, seed) draws —
the Poisson process must look Poisson for EVERY seed, not one golden one.
Engine-level tests pin the property the CI perf gate depends on: under
the virtual clock, the whole run — request schedule, event log, token
streams, metric report — is a deterministic function of the seed.
"""
import math

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import DisaggServeEngine, ServeEngine
from repro.serve.metrics import compute_report, nearest_rank
from repro.serve.traffic import (bursty_arrivals, make_workload,
                                 poisson_arrivals, record_trace, run_traffic,
                                 workload_from_trace)


@pytest.fixture(scope="module")
def dense():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


ENGINE_KW = dict(max_slots=3, max_len=64, page_size=8, num_pages=24,
                 prefill_chunk=16)
WL_KW = dict(n_requests=8, rate=0.5, seed=3, max_new_tokens=6,
             shared_prefix_len=8, n_sessions=2)


# ---------------------------------------------------------------------------
# workload generation
# ---------------------------------------------------------------------------

def test_same_seed_same_workload():
    a = make_workload(kind="poisson", vocab=491, **WL_KW)
    b = make_workload(kind="poisson", vocab=491, **WL_KW)
    assert len(a) == len(b) == WL_KW["n_requests"]
    for ra, rb in zip(a, b):
        assert ra.arrival == rb.arrival and ra.session == rb.session
        assert np.array_equal(ra.prompt, rb.prompt)
    c = make_workload(kind="poisson", vocab=491,
                      **{**WL_KW, "seed": WL_KW["seed"] + 1})
    assert any(not np.array_equal(ra.prompt, rc.prompt)
               or ra.arrival != rc.arrival for ra, rc in zip(a, c))


def test_shared_prefixes_are_per_session():
    wl = make_workload(kind="poisson", vocab=491, **WL_KW)
    by_session = {}
    for r in wl:
        assert r.session >= 0
        pre = tuple(r.prompt[:8])
        by_session.setdefault(r.session, pre)
        assert by_session[r.session] == pre, \
            "requests in one session must share its prefix"


@settings(max_examples=20, deadline=None)
@given(st.floats(0.2, 4.0), st.integers(0, 2 ** 31 - 1))
def test_poisson_interarrival_statistics(rate, seed):
    """Exponential inter-arrivals: mean 1/rate, coefficient of variation 1,
    memoryless tail P(X > 2/rate) = e^-2 — for every seed."""
    rng = np.random.default_rng(seed)
    arr = poisson_arrivals(4000, rate, rng)
    assert np.all(np.diff(arr) >= 0)
    gaps = np.diff(np.concatenate([[0.0], arr]))
    mean = gaps.mean()
    assert abs(mean - 1.0 / rate) < 0.1 / rate
    cv = gaps.std() / mean
    assert abs(cv - 1.0) < 0.12
    tail = (gaps > 2.0 / rate).mean()
    assert abs(tail - math.exp(-2)) < 0.04


@settings(max_examples=20, deadline=None)
@given(st.floats(0.2, 4.0), st.integers(0, 2 ** 31 - 1))
def test_bursty_arrivals_rate_and_shape(rate, seed):
    """Bursts of 4 share one arrival instant; the long-run rate matches."""
    rng = np.random.default_rng(seed)
    arr = bursty_arrivals(4000, rate, rng, burst=4)
    assert len(arr) == 4000
    for i in range(0, 4000, 4):
        assert np.all(arr[i:i + 4] == arr[i])
    assert abs(arr[-1] / 4000 - 1.0 / rate) < 0.15 / rate


def test_mixed_lengths_stay_in_bands():
    wl = make_workload(kind="poisson", n_requests=200, rate=1.0, vocab=491,
                       seed=0, shared_prefix_len=0, n_sessions=0,
                       len_mix=((1.0, 4, 8), (1.0, 30, 40)))
    lens = [len(r.prompt) for r in wl]
    assert all(4 <= n <= 8 or 30 <= n <= 40 for n in lens)
    assert any(n <= 8 for n in lens) and any(n >= 30 for n in lens)


# ---------------------------------------------------------------------------
# metric arithmetic (hand-checked)
# ---------------------------------------------------------------------------

def test_nearest_rank_percentiles():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert nearest_rank(xs, 50) == 3.0
    assert nearest_rank(xs, 95) == 5.0
    assert nearest_rank(xs, 99) == 5.0
    assert nearest_rank([7.0], 50) == 7.0
    assert nearest_rank([], 50) is None


def test_compute_report_hand_checked():
    events = [
        {"t": 0.0, "rid": 0, "kind": "submit"},
        {"t": 1.0, "rid": 1, "kind": "submit"},
        {"t": 2.0, "rid": 2, "kind": "submit"},
        {"t": 2.0, "rid": 0, "kind": "tokens", "n": 1},
        {"t": 3.0, "rid": 0, "kind": "tokens", "n": 2},
        {"t": 3.0, "rid": 0, "kind": "done", "error": False},
        {"t": 5.0, "rid": 1, "kind": "tokens", "n": 1},
        {"t": 6.0, "rid": 1, "kind": "done", "error": False},
        {"t": 7.0, "rid": 2, "kind": "done", "error": True},
    ]
    rep = compute_report(events, slo={"ttft": 3.0})
    assert rep["n_requests"] == 3 and rep["n_measured"] == 2
    assert rep["n_errors"] == 1
    # rid 0: ttft 2, tok_times [2, 3, 3] -> gaps [1, 0], e2e 3, 3 tokens
    # rid 1: ttft 4, no gaps, e2e 5, 1 token;  span = 7 - 0
    assert rep["tokens"] == 4 and rep["span"] == 7.0
    assert rep["ttft"] == {"p50": 2.0, "p95": 4.0, "p99": 4.0, "n": 2}
    assert rep["itl"] == {"p50": 0.0, "p95": 1.0, "p99": 1.0, "n": 2}
    assert rep["e2e"] == {"p50": 3.0, "p95": 5.0, "p99": 5.0, "n": 2}
    assert rep["tok_per_s"] == pytest.approx(4 / 7)
    # only rid 0 meets ttft <= 3; the errored request is never compliant
    assert rep["goodput"]["tok_per_s"] == pytest.approx(3 / 7)
    assert rep["goodput"]["req_per_s"] == pytest.approx(1 / 7)
    assert rep["goodput"]["slo_attainment"] == pytest.approx(0.5)


def test_goodput_equals_throughput_without_slo():
    events = [
        {"t": 0.0, "rid": 0, "kind": "submit"},
        {"t": 4.0, "rid": 0, "kind": "tokens", "n": 3},
        {"t": 4.0, "rid": 0, "kind": "done", "error": False},
    ]
    rep = compute_report(events)
    assert rep["goodput"]["tok_per_s"] == rep["tok_per_s"]
    assert rep["goodput"]["slo_attainment"] == 1.0


# ---------------------------------------------------------------------------
# end-to-end determinism and trace replay
# ---------------------------------------------------------------------------

def _run(model, params, workload, engine_cls=ServeEngine, **ekw):
    eng = engine_cls(model, params, **{**ENGINE_KW, **ekw})
    res = run_traffic(eng, workload, slo={"ttft": 24.0, "e2e": 96.0})
    eng.close()
    return res


def test_harness_deterministic_under_virtual_clock(dense):
    """Same seed, fresh engines: identical event log, token streams, and
    metric report — the property CI's perf gate leans on."""
    model, params = dense
    wl = make_workload(kind="poisson", vocab=model.cfg.vocab, **WL_KW)
    a = _run(model, params, wl)
    b = _run(model, params, wl)
    assert a["events"] == b["events"]
    assert a["outputs"] == b["outputs"]
    assert a["report"] == b["report"]


def test_trace_replay_round_trip(dense):
    """Record a run, rebuild the workload from the trace, replay on a
    fresh engine: bit-identical token streams AND event log."""
    model, params = dense
    wl = make_workload(kind="bursty", vocab=model.cfg.vocab, **WL_KW)
    first = _run(model, params, wl)
    trace = record_trace(wl, first["events"], first["outputs"])
    replayed_wl = workload_from_trace(trace)
    for orig, re in zip(wl, replayed_wl):
        assert np.array_equal(orig.prompt, re.prompt)
        assert orig.arrival == re.arrival
    second = _run(model, params, replayed_wl)
    assert second["events"] == trace["events"]
    assert {str(k): v for k, v in second["outputs"].items()} \
        == trace["outputs"]


def test_disagg_engine_under_traffic_matches_monolithic_streams(dense):
    """The harness drives both engine shapes; queueing changes WHEN tokens
    appear (disagg pays an injection tick) but never WHICH tokens."""
    model, params = dense
    wl = make_workload(kind="poisson", vocab=model.cfg.vocab, **WL_KW)
    mono = _run(model, params, wl)
    dis = _run(model, params, wl, engine_cls=DisaggServeEngine)
    assert mono["outputs"] == dis["outputs"]
    assert dis["report"]["n_errors"] == 0
    assert dis["report"]["tokens"] == mono["report"]["tokens"]
