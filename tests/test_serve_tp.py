"""Tensor-parallel serving over a device mesh: token-stream parity.

Sharded engines (params + paged KV heads over a 1-D ("model",) mesh) must
emit greedy token streams identical to the single-device engine — dense and
MoE families, at tp=2 and tp=4, with the Pallas paged-attention kernel in
the loop and under forced preemption.  Recurrent families run slot-parallel
(batch over the mesh) and must match too.

Subprocess SPMD via ``--xla_force_host_platform_device_count=8`` (the main
pytest process must keep 1 device), like :mod:`tests.test_distributed`.
"""
import pytest

from repro.configs import smoke_config
from repro.models.api import build_model
from tests.test_distributed import run_spmd

_STREAMS = """
    from repro.configs import smoke_config
    from repro.models.api import build_model
    from repro.serve import ServeEngine

    def streams(model, params, mesh, n_req=4, max_new=6, **kw):
        kw.setdefault("max_slots", 4); kw.setdefault("max_len", 64)
        eng = ServeEngine(model, params, mesh=mesh, **kw)
        prompts = ([5, 17, 33, 2, 9], [100, 200, 300], [7] * 11,
                   [1, 2, 3, 4])[:n_req]
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        done = eng.run_until_drained()
        eng.close()
        assert all(r.error is None for r in done)
        return {r.rid: r.output for r in done}, eng
"""


def test_tp_paged_parity_dense_and_moe():
    """tp=2 and tp=4 paged engines match the tp=1 (no-mesh) engine
    token-for-token on the dense and MoE smoke configs."""
    run_spmd(_STREAMS + """
    for arch in ("qwen2-7b", "qwen3-moe-235b-a22b"):
        cfg = smoke_config(arch).replace(remat="none", n_heads=8,
                                         n_kv_heads=4)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        want, _ = streams(model, params, None, paged=True, page_size=8,
                          prefill_chunk=16)
        for tp in (2, 4):
            mesh = jax.make_mesh((tp,), ("model",))
            got, eng = streams(model, params, mesh, paged=True, page_size=8,
                               prefill_chunk=16)
            assert eng.tp == tp
            assert got == want, (arch, tp)
    print("tp paged parity OK")
    """)


def test_tp_parity_under_preemption_and_pallas():
    """A pool at the single-request minimum forces preemption on the
    sharded engine too; the recompute policy keeps streams identical.
    Second half: the Pallas paged-attention kernel inside the shard_map
    body (interpret mode on CPU) matches as well."""
    run_spmd(_STREAMS + """
    cfg = smoke_config("qwen2-7b").replace(remat="none", n_heads=8,
                                           n_kv_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def go(mesh):
        eng = ServeEngine(model, params, max_slots=2, max_len=64, paged=True,
                          page_size=16, num_pages=4, prefill_chunk=16,
                          mesh=mesh)
        eng.submit([5, 17, 33, 2, 9, 1, 2, 3], max_new_tokens=30)
        eng.submit([100, 200, 300, 4, 5, 6, 7, 8], max_new_tokens=30)
        done = eng.run_until_drained()
        eng.close()
        return {r.rid: r.output for r in done}, eng.stats["preemptions"]

    want, pre1 = go(None)
    got, pre2 = go(jax.make_mesh((2,), ("model",)))
    assert pre1 >= 1 and pre2 >= 1, (pre1, pre2)
    assert got == want

    want, _ = streams(model, params, None, paged=True, page_size=16,
                      prefill_chunk=16, use_pallas_attention=True)
    got, _ = streams(model, params, jax.make_mesh((2,), ("model",)),
                     paged=True, page_size=16, prefill_chunk=16,
                     use_pallas_attention=True)
    assert got == want
    print("preemption + pallas tp parity OK")
    """)


def test_slot_parallel_recurrent_family():
    """rwkv6 has no KV to shard; the mesh engine shards decode SLOTS over
    the devices instead (params replicated, state batch-sharded) and the
    per-slot math is unchanged — streams match exactly."""
    run_spmd(_STREAMS + """
    cfg = smoke_config("rwkv6-3b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    want, enga = streams(model, params, None)
    assert not enga.paged
    for tp in (2, 4):
        got, eng = streams(model, params, jax.make_mesh((tp,), ("model",)))
        assert not eng.paged and eng.tp == tp
        assert got == want, tp

    # regression: a dense-FORCED DecoderLM must also run slot-parallel with
    # replicated params — applying its Megatron TP specs to the comm-less
    # dense step would silently zero half the KV heads
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    want, _ = streams(model, params, None, paged=False)
    got, eng = streams(model, params, jax.make_mesh((2,), ("model",)),
                       paged=False)
    assert not eng.paged
    assert got == want
    print("slot-parallel parity OK")
    """)


def test_tp_divisibility_validation():
    """Host-side (no mesh needed): indivisible head/expert counts raise
    with every offending dimension named."""
    model = build_model(smoke_config("qwen2-7b"))     # hq=4, hkv=2
    with pytest.raises(ValueError, match="padded_kv_heads=2"):
        model.validate_serve_tp(4)
    model.validate_serve_tp(2)                        # 2 divides everything
    model.validate_serve_tp(1)                        # tp=1 never validates
    moe = build_model(smoke_config("qwen3-moe-235b-a22b"))  # E=8
    with pytest.raises(ValueError, match="n_experts=8"):
        moe.validate_serve_tp(3)


def test_mesh_engine_argument_validation():
    """mesh= and rules= are mutually exclusive, and a mesh without a
    'model' axis is rejected (1-device meshes keep this in-process)."""
    import jax
    from repro.serve import ServeEngine
    model = build_model(smoke_config("rwkv6-3b").replace(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1,), ("model",))
    eng = ServeEngine(model, params, max_slots=3, max_len=32, mesh=mesh)
    eng.close()
    with pytest.raises(ValueError, match="not both"):
        ServeEngine(model, params, max_slots=3, max_len=32, mesh=mesh,
                    rules=object())
    with pytest.raises(ValueError, match="'model' axis"):
        ServeEngine(model, params, max_slots=2, max_len=32,
                    mesh=jax.make_mesh((1,), ("data",)))
