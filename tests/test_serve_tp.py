"""Tensor-parallel serving over a device mesh: token-stream parity.

Sharded engines (params + paged KV heads over a 1-D ("model",) mesh) must
emit greedy token streams identical to the single-device engine — dense and
MoE families, at tp=2 and tp=4, with the Pallas paged-attention kernel in
the loop and under forced preemption.  Recurrent families run slot-parallel
(batch over the mesh) and must match too.

Subprocess SPMD via ``--xla_force_host_platform_device_count=8`` (the main
pytest process must keep 1 device), like :mod:`tests.test_distributed`.
"""
import pytest

from repro.configs import smoke_config
from repro.models.api import build_model
from tests.test_distributed import run_spmd

_STREAMS = """
    from repro.configs import smoke_config
    from repro.models.api import build_model
    from repro.serve import ServeEngine

    def streams(model, params, mesh, n_req=4, max_new=6, **kw):
        kw.setdefault("max_slots", 4); kw.setdefault("max_len", 64)
        eng = ServeEngine(model, params, mesh=mesh, **kw)
        prompts = ([5, 17, 33, 2, 9], [100, 200, 300], [7] * 11,
                   [1, 2, 3, 4])[:n_req]
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        done = eng.run_until_drained()
        eng.close()
        assert all(r.error is None for r in done)
        return {r.rid: r.output for r in done}, eng
"""


def test_tp_paged_parity_dense_and_moe():
    """tp=2 and tp=4 paged engines match the tp=1 (no-mesh) engine
    token-for-token on the dense and MoE smoke configs."""
    run_spmd(_STREAMS + """
    for arch in ("qwen2-7b", "qwen3-moe-235b-a22b"):
        cfg = smoke_config(arch).replace(remat="none", n_heads=8,
                                         n_kv_heads=4)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        want, _ = streams(model, params, None, paged=True, page_size=8,
                          prefill_chunk=16)
        for tp in (2, 4):
            mesh = jax.make_mesh((tp,), ("model",))
            got, eng = streams(model, params, mesh, paged=True, page_size=8,
                               prefill_chunk=16)
            assert eng.tp == tp
            assert got == want, (arch, tp)
    print("tp paged parity OK")
    """)


def test_tp_parity_under_preemption_and_pallas():
    """A pool at the single-request minimum forces preemption on the
    sharded engine too; the recompute policy keeps streams identical.
    Second half: the Pallas paged-attention kernel inside the shard_map
    body (interpret mode on CPU) matches as well."""
    run_spmd(_STREAMS + """
    cfg = smoke_config("qwen2-7b").replace(remat="none", n_heads=8,
                                           n_kv_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def go(mesh):
        eng = ServeEngine(model, params, max_slots=2, max_len=64, paged=True,
                          page_size=16, num_pages=4, prefill_chunk=16,
                          mesh=mesh)
        eng.submit([5, 17, 33, 2, 9, 1, 2, 3], max_new_tokens=30)
        eng.submit([100, 200, 300, 4, 5, 6, 7, 8], max_new_tokens=30)
        done = eng.run_until_drained()
        eng.close()
        return {r.rid: r.output for r in done}, eng.stats["preemptions"]

    want, pre1 = go(None)
    got, pre2 = go(jax.make_mesh((2,), ("model",)))
    assert pre1 >= 1 and pre2 >= 1, (pre1, pre2)
    assert got == want

    want, _ = streams(model, params, None, paged=True, page_size=16,
                      prefill_chunk=16, use_pallas_attention=True)
    got, _ = streams(model, params, jax.make_mesh((2,), ("model",)),
                     paged=True, page_size=16, prefill_chunk=16,
                     use_pallas_attention=True)
    assert got == want
    print("preemption + pallas tp parity OK")
    """)


def test_tp_prefix_cache_parity():
    """Prefix sharing is host-side page-table policy: the sharded engine
    reads shared pages through the same gather ops, so cache-on streams at
    tp=2/4 are bit-identical to the tp=1 cache-off reference — replay
    (full-prompt hits) and copy-on-write included — and the host-side
    cache counters are identical at every tp."""
    run_spmd("""
    from repro.configs import smoke_config
    from repro.models.api import build_model
    from repro.serve import ServeEngine

    cfg = smoke_config("qwen2-7b").replace(remat="none", n_heads=8,
                                           n_kv_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    P = list(range(1, 25))                  # 1 full + 1 partial page
    # wave 2's twin P-requests share P's parked pages (replay) and then
    # diverge-proof COW on the partial last page; wave 3 diverges mid-page
    waves = ([P], [P, P], [P[:20] + [77, 78]])

    def run(mesh, prefix_cache, num_pages=None, max_len=128, max_new=12):
        eng = ServeEngine(model, params, max_slots=2, max_len=max_len,
                          paged=True, page_size=16, prefill_chunk=16,
                          num_pages=num_pages, prefix_cache=prefix_cache,
                          mesh=mesh)
        for wave in waves:
            for p in wave:
                eng.submit(p, max_new_tokens=max_new)
            eng.run_until_drained()
        outs = {r.rid: r.output for r in eng.finished}
        assert all(r.error is None for r in eng.finished)
        eng.close()
        return outs, eng.stats

    want, _ = run(None, False)
    base, s1 = run(None, True)
    assert base == want
    assert s1["prefix_hits"] >= 3 and s1["cow_copies"] >= 1, s1
    for tp in (2, 4):
        got, stats = run(jax.make_mesh((tp,), ("model",)), True)
        assert got == want, tp
        # the host-side policy is mesh-invariant, counter for counter
        for k in ("prefix_hits", "prefix_hit_tokens", "cow_copies",
                  "evictions"):
            assert stats[k] == s1[k], (tp, k, stats[k], s1[k])

    # forced preemption with sharing in play (pool at the one-request
    # minimum): parked-page re-matching survives sharding too
    waves = ([[5, 17, 33, 2, 9, 1, 2, 3], [100, 200, 300, 4, 5, 6, 7, 8]],
             [[5, 17, 33, 2, 9, 1, 2, 3]])
    want, s_off = run(None, False, num_pages=4, max_len=64, max_new=30)
    assert s_off["preemptions"] >= 1
    got, s_tp = run(jax.make_mesh((2,), ("model",)), True, num_pages=4,
                    max_len=64, max_new=30)
    assert got == want and s_tp["prefix_hits"] >= 1
    print("tp prefix-cache parity OK")
    """)


def test_tp_spec_decode_parity():
    """Speculative decode is host-side policy plus one extra batched device
    call: sharded spec-on streams (dense + MoE) must equal the tp=1
    spec-OFF reference bit-for-bit, under forced preemption too, and the
    draft counters (proposed / accepted / acceptance_rate) must be
    mesh-invariant — the same drafts are proposed and accepted at every
    tp."""
    run_spmd("""
    from repro.configs import smoke_config
    from repro.models.api import build_model
    from repro.serve import ServeEngine

    for arch in ("qwen2-7b", "qwen3-moe-235b-a22b"):
        cfg = smoke_config(arch).replace(remat="none", n_heads=8,
                                         n_kv_heads=4)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))

        def streams(mesh, **kw):
            eng = ServeEngine(model, params, max_slots=4, max_len=64,
                              prefill_chunk=16, page_size=8, paged=True,
                              mesh=mesh, **kw)
            prompts = ([5, 17, 33, 5, 17, 33, 5, 17], [7] * 11,
                       [1, 2, 3, 4, 1, 2, 3, 4, 1, 2],
                       [9, 9, 8, 8, 9, 9, 8, 8])
            for p in prompts:
                eng.submit(p, max_new_tokens=10)
            done = eng.run_until_drained()
            eng.close()
            assert all(r.error is None for r in done)
            return {r.rid: r.output for r in done}, eng.stats

        want, _ = streams(None)
        got1, s1 = streams(None, spec_decode="ngram")
        assert got1 == want, (arch, "tp=1 spec parity")
        assert s1["draft_proposed"] > 0
        got2, s2 = streams(jax.make_mesh((2,), ("model",)),
                           spec_decode="ngram")
        assert got2 == want, (arch, "tp=2 spec parity")
        for k in ("draft_proposed", "draft_accepted", "acceptance_rate"):
            assert s1[k] == s2[k], (arch, k, s1[k], s2[k])
        # fused multi-query kernel inside the shard_map body: spec verify +
        # decode + prefill all through Pallas, still the same streams
        got3, s3 = streams(jax.make_mesh((2,), ("model",)),
                           spec_decode="ngram", use_pallas_attention=True)
        assert got3 == want, (arch, "tp=2 spec+pallas parity")
        assert s3["draft_proposed"] > 0

    # forced preemption with speculation on: verify windows never evict
    # anyone plain decode would have kept, and streams still match
    cfg = smoke_config("qwen2-7b").replace(remat="none", n_heads=8,
                                           n_kv_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def tight(mesh, **kw):
        eng = ServeEngine(model, params, max_slots=2, max_len=64, paged=True,
                          page_size=16, num_pages=4, prefill_chunk=16,
                          mesh=mesh, **kw)
        eng.submit([5, 17, 33, 2, 9, 1, 2, 3], max_new_tokens=30)
        eng.submit([100, 200, 300, 4, 5, 6, 7, 8], max_new_tokens=30)
        done = eng.run_until_drained()
        eng.close()
        return {r.rid: r.output for r in done}, eng.stats["preemptions"]

    want, pre = tight(None)
    assert pre >= 1
    got, _ = tight(jax.make_mesh((2,), ("model",)), spec_decode="ngram")
    assert got == want
    print("tp spec-decode parity OK")
    """)


def test_tp_quant_parity():
    """int8 KV pages under tensor parallelism: the scale leaves shard over
    the head axis exactly like K/V, quantization happens inside the
    shard_map body on each device's own heads, and per-row scales commute
    with the head split — so quant-on tp=2/4 streams are bit-identical to
    the quant-on tp=1 streams (dense + MoE, Pallas kernel included).
    Weights-only int8 dequant also commutes with the Megatron param split
    (per-tensor scalar scale, replicated), so it must match too."""
    run_spmd(_STREAMS + """
    for arch in ("qwen2-7b", "qwen3-moe-235b-a22b"):
        cfg = smoke_config(arch).replace(remat="none", n_heads=8,
                                         n_kv_heads=4)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        want, eng1 = streams(model, params, None, paged=True, page_size=8,
                             prefill_chunk=16, kv_quant="int8")
        assert eng1.stats["kv_quant"] == "int8"
        for tp in (2, 4):
            mesh = jax.make_mesh((tp,), ("model",))
            got, eng = streams(model, params, mesh, paged=True, page_size=8,
                               prefill_chunk=16, kv_quant="int8")
            assert eng.tp == tp
            assert got == want, (arch, tp, "kv quant tp parity")
        got, _ = streams(model, params, jax.make_mesh((2,), ("model",)),
                         paged=True, page_size=8, prefill_chunk=16,
                         kv_quant="int8", use_pallas_attention=True)
        assert got == want, (arch, "kv quant + pallas tp parity")

    cfg = smoke_config("qwen2-7b").replace(remat="none", n_heads=8,
                                           n_kv_heads=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    want, _ = streams(model, params, None, paged=True, page_size=8,
                      prefill_chunk=16, kv_quant="int8", weight_quant="int8")
    got, eng = streams(model, params, jax.make_mesh((2,), ("model",)),
                       paged=True, page_size=8, prefill_chunk=16,
                       kv_quant="int8", weight_quant="int8")
    assert eng.stats["weight_quant"] == "int8"
    assert got == want, "weight quant tp parity"
    print("tp quant parity OK")
    """)


def test_slot_parallel_recurrent_family():
    """rwkv6 has no KV to shard; the mesh engine shards decode SLOTS over
    the devices instead (params replicated, state batch-sharded) and the
    per-slot math is unchanged — streams match exactly."""
    run_spmd(_STREAMS + """
    cfg = smoke_config("rwkv6-3b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    want, enga = streams(model, params, None)
    assert not enga.paged
    for tp in (2, 4):
        got, eng = streams(model, params, jax.make_mesh((tp,), ("model",)))
        assert not eng.paged and eng.tp == tp
        assert got == want, tp

    # regression: a dense-FORCED DecoderLM must also run slot-parallel with
    # replicated params — applying its Megatron TP specs to the comm-less
    # dense step would silently zero half the KV heads
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    want, _ = streams(model, params, None, paged=False)
    got, eng = streams(model, params, jax.make_mesh((2,), ("model",)),
                       paged=False)
    assert not eng.paged
    assert got == want
    print("slot-parallel parity OK")
    """)


def test_tp_divisibility_validation():
    """Host-side (no mesh needed): indivisible head/expert counts raise
    with every offending dimension named."""
    model = build_model(smoke_config("qwen2-7b"))     # hq=4, hkv=2
    with pytest.raises(ValueError, match="padded_kv_heads=2"):
        model.validate_serve_tp(4)
    model.validate_serve_tp(2)                        # 2 divides everything
    model.validate_serve_tp(1)                        # tp=1 never validates
    moe = build_model(smoke_config("qwen3-moe-235b-a22b"))  # E=8
    with pytest.raises(ValueError, match="n_experts=8"):
        moe.validate_serve_tp(3)


def test_mesh_engine_argument_validation():
    """mesh= and rules= are mutually exclusive, and a mesh without a
    'model' axis is rejected (1-device meshes keep this in-process)."""
    import jax
    from repro.serve import ServeEngine
    model = build_model(smoke_config("rwkv6-3b").replace(remat="none"))
    params = model.init(jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1,), ("model",))
    eng = ServeEngine(model, params, max_slots=3, max_len=32, mesh=mesh)
    eng.close()
    with pytest.raises(ValueError, match="not both"):
        ServeEngine(model, params, max_slots=3, max_len=32, mesh=mesh,
                    rules=object())
    with pytest.raises(ValueError, match="'model' axis"):
        ServeEngine(model, params, max_slots=2, max_len=32,
                    mesh=jax.make_mesh((1,), ("data",)))
