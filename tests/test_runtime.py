"""Executor-runtime tests: cross-tier parity on the quickstart problem plus
deterministic concurrency/work-stealing/straggler coverage for the farm."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.runtime import (MeshExecutor, SerialExecutor,
                                ThreadFarmExecutor, VmapExecutor,
                                make_executor, straggler_deadline)


# ---------------------------------------------------------------------------
# The quickstart parabola in stacked form (shared by the parity tests)
# ---------------------------------------------------------------------------

M, N, L = 16, 24, 10.0
_x = jnp.linspace(0, L, N)


def _initialize():
    vals = jnp.linspace(-1, 1, M)
    aa, bb = jnp.meshgrid(vals, vals, indexing="ij")
    return {"a": aa.ravel(), "b": bb.ravel()}


def _func(task):
    return task["a"] * _x ** 2 + task["b"] * _x + 5.0


def _finalize(out):
    return np.asarray(out)


def _all_executors():
    execs = [SerialExecutor(), VmapExecutor(),
             MeshExecutor(jax.make_mesh((jax.device_count(),), ("data",))),
             ThreadFarmExecutor(num_workers=4)]
    return execs


def test_all_executors_identical_results():
    """The acceptance-criterion parity check: four executors, one answer."""
    ref = _all_executors()[0].run(_initialize, _func, _finalize)
    assert ref.shape == (M * M, N)
    for ex in _all_executors()[1:]:
        got = ex.run(_initialize, _func, _finalize)
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=type(ex).__name__)


def test_mesh_executor_passes_valid_mask():
    """Two-argument finalize gets padded outputs + the valid-task mask."""
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    n_tasks = 3 * jax.device_count() + 1 if jax.device_count() > 1 else 3

    def initialize():
        return {"a": jnp.arange(float(n_tasks))}

    seen = {}

    def finalize(out, mask):
        seen["out"], seen["mask"] = out, mask
        return out[mask].sum()

    got = MeshExecutor(mesh).run(initialize, lambda t: 2.0 * t["a"], finalize)
    assert seen["mask"].sum() == n_tasks
    assert seen["out"].shape[0] % jax.device_count() == 0
    assert float(got) == pytest.approx(2.0 * sum(range(n_tasks)))


def test_finalize_arity_defaulted_params_stay_one_arg():
    """A defaulted second parameter (or *args) must NOT receive the mask —
    pre-runtime finalizers like np.mean(a, axis=...) keep the 1-arg call."""
    def init():
        return {"a": jnp.arange(4.0)}

    got = VmapExecutor().run(init, lambda t: t["a"] * 2, np.mean)
    assert float(got) == pytest.approx(3.0)

    seen = {}

    def fin_defaulted(out, verbose=False):
        seen["verbose"] = verbose
        return out

    SerialExecutor().run(init, lambda t: t["a"], fin_defaulted)
    assert seen["verbose"] is False

    def fin_varargs(*outs):
        return outs

    outs = SerialExecutor().run(init, lambda t: t["a"], fin_varargs)
    assert len(outs) == 1                  # mask not smuggled into *args


def test_serial_executor_paper_host_form():
    """List-of-(args, kwargs) tasks keep the paper's verbatim semantics."""
    def initialize():
        return [((i,), {"k": 10}) for i in range(5)]

    out = SerialExecutor().run(initialize, lambda i, k=1: i * k, sum)
    assert out == sum(i * 10 for i in range(5))


def test_executors_accept_generator_host_tasks():
    """initialize() may return any iterable of (args, kwargs) pairs — the
    paper's loop just iterates it."""
    def initialize():
        return (((i,), {}) for i in range(4))

    out = SerialExecutor().run(initialize, lambda i: i * 3, list)
    assert out == [0, 3, 6, 9]
    out = ThreadFarmExecutor(num_workers=2).run(
        initialize, lambda i: i * 3, list)
    assert out == [0, 3, 6, 9]


def test_make_executor_specs():
    assert isinstance(make_executor("serial"), SerialExecutor)
    assert isinstance(make_executor("vmap"), VmapExecutor)
    assert isinstance(make_executor("thread"), ThreadFarmExecutor)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    assert isinstance(make_executor("mesh", mesh=mesh), MeshExecutor)
    ex = SerialExecutor()
    assert make_executor(ex) is ex
    with pytest.raises(ValueError):
        make_executor("mesh")
    with pytest.raises(ValueError):
        make_executor("bogus")


# ---------------------------------------------------------------------------
# ThreadFarmExecutor: concurrency, stealing, rebalance, stragglers
# ---------------------------------------------------------------------------

def test_farm_overlaps_gil_releasing_tasks():
    """8 sleep-bound tasks on 8 workers must take ~1 task-time, not ~8."""
    farm = ThreadFarmExecutor(num_workers=8)
    t0 = time.perf_counter()
    results, stats = farm.map_callables(
        [lambda i=i: (time.sleep(0.05), i)[1] for i in range(8)])
    wall = time.perf_counter() - t0
    assert results == list(range(8))
    assert wall < 0.25                     # serial would be >= 0.4s
    assert stats["num_workers"] == 8


def test_farm_timings_indexed_by_task():
    """stats['timings'][i] is task i's runtime (the pre-runtime contract),
    regardless of completion order."""
    delays = [0.0, 0.06, 0.0, 0.03]
    farm = ThreadFarmExecutor(num_workers=4)
    _, stats = farm.map_callables(
        [lambda i=i: time.sleep(delays[i]) for i in range(4)])
    t = stats["timings"]
    assert len(t) == 4 and all(x is not None for x in t)
    assert t[1] > 0.05 and t[3] > 0.02 and t[0] < 0.02 and t[2] < 0.02


def test_farm_results_order_independent_of_execution_order():
    """Work stealing may run tasks in any order; results stay index-ordered."""
    rng = np.random.default_rng(0)
    delays = rng.uniform(0.0, 0.004, size=64)
    farm = ThreadFarmExecutor(num_workers=8)
    results, stats = farm.map_callables(
        [lambda i=i: (time.sleep(delays[i]), i)[1] for i in range(64)])
    assert results == list(range(64))
    assert sum(stats["worker_tasks"]) == 64


def test_farm_work_stealing_engages():
    """All slow work piled on one worker's initial queue gets stolen."""
    # 2 workers, 8 tasks -> worker 0 seeds tasks 0-3, worker 1 tasks 4-7.
    # Make worker-0's share slow so worker 1 finishes and steals.
    farm = ThreadFarmExecutor(num_workers=2, rebalance=False)
    results, stats = farm.map_callables(
        [lambda i=i: (time.sleep(0.03 if i < 4 else 0.0), i)[1]
         for i in range(8)])
    assert results == list(range(8))
    assert stats["steals"] >= 1
    # both workers did real work
    assert min(stats["worker_tasks"]) >= 1


def test_farm_straggler_redispatch_first_completion_wins():
    calls = []
    lock = threading.Lock()

    def flaky():
        with lock:
            calls.append(time.perf_counter())
            first = len(calls) == 1
        if first:
            time.sleep(0.3)               # first attempt straggles
            return "late"
        return "fast"                     # backup attempt returns instantly

    tasks = [lambda: "ok"] * 6 + [flaky]
    farm = ThreadFarmExecutor(num_workers=4, deadline_factor=2.0,
                              min_straggler_s=0.02)
    results, stats = farm.map_callables(tasks)
    assert results[:6] == ["ok"] * 6
    assert results[6] == "fast"           # backup finished first and won
    assert stats["stragglers"] == [6]
    assert len(calls) == 2                # re-issued exactly once


def test_farm_timing_rebalance_triggers():
    """With one slow worker and queued work, the farm must rebalance queues
    using the measured per-worker speed."""
    slow_worker_seen = threading.Event()

    def make(i):
        def task():
            # tasks 0..9 seed worker 0's queue (2 workers, 20 tasks);
            # make them slow so rebalancing moves its backlog to worker 1
            if i < 10:
                slow_worker_seen.set()
                time.sleep(0.01)
            return i
        return task

    farm = ThreadFarmExecutor(num_workers=2, steal=False, rebalance=True)
    results, stats = farm.map_callables([make(i) for i in range(20)])
    assert results == list(range(20))
    assert slow_worker_seen.is_set()
    assert stats["rebalances"] >= 1


def test_farm_single_worker_straggler_inline_redo():
    """With one worker no idle peer exists, so the farm must keep the old
    serial semantics: re-run a deadline-breaching task post-hoc."""
    calls = {"n": 0}

    def slow():
        calls["n"] += 1
        time.sleep(0.05 if calls["n"] == 1 else 0.0)
        return 42

    tasks = [lambda: 1] * 6 + [slow]
    farm = ThreadFarmExecutor(num_workers=1, deadline_factor=3.0)
    results, stats = farm.map_callables(tasks)
    assert results == [1] * 6 + [42]
    assert stats["stragglers"] == [6]
    assert calls["n"] == 2


def test_farm_single_worker_failed_redo_keeps_original_result():
    """A redo that raises must never clobber the slow-but-successful
    original."""
    calls = {"n": 0}

    def slow_then_broken():
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.05)
            return 42
        raise RuntimeError("redo exploded")

    tasks = [lambda: 1] * 6 + [slow_then_broken]
    farm = ThreadFarmExecutor(num_workers=1, deadline_factor=3.0)
    results, stats = farm.map_callables(tasks)
    assert results[6] == 42                # original result preserved
    assert stats["stragglers"] == [6]
    assert calls["n"] == 2


def test_farm_failing_backup_does_not_discard_running_original():
    """A fast-failing backup attempt must wait for the in-flight original;
    the original's success settles the task."""
    calls = {"n": 0}
    lock = threading.Lock()

    def slow_original_broken_backup():
        with lock:
            calls["n"] += 1
            first = calls["n"] == 1
        if first:
            time.sleep(0.3)                # slow but healthy
            return "late"
        raise RuntimeError("backup hit non-idempotent state")

    tasks = [lambda: "ok"] * 5 + [slow_original_broken_backup]
    farm = ThreadFarmExecutor(num_workers=4, deadline_factor=2.0,
                              min_straggler_s=0.02)
    results, stats = farm.map_callables(tasks)     # must not raise
    assert results[5] == "late"
    assert stats["stragglers"] == [5]
    assert calls["n"] == 2


def test_farm_nested_call_from_task_runs_serially():
    """A task calling back into its own farm instance (e.g. a task on a
    long-lived engine farm) must nest serially, not deadlock."""
    farm = ThreadFarmExecutor(num_workers=2, deadline_factor=3.0)

    def outer():
        inner, stats = farm.map_callables([lambda: 10, lambda: 20])
        assert stats["num_workers"] == 1       # serial nested fallback
        return sum(inner)

    results, _ = farm.map_callables([outer, lambda: 1])
    assert results == [30, 1]


def test_nested_host_task_farm_same_config():
    from repro.core import host_task_farm

    def outer():
        r, _ = host_task_farm([lambda: 5, lambda: 6], deadline_factor=3.0)
        return sum(r)

    results, _ = host_task_farm([outer] * 3, deadline_factor=3.0)
    assert results == [11, 11, 11]


def test_no_copy_finalize_when_unpadded():
    """Serial/Vmap never pad, so 1-arg finalize must get the outputs
    untouched (no per-leaf device copy)."""
    seen = {}

    def finalize(out):
        seen["out"] = out
        return out

    SerialExecutor().run(lambda: {"a": jnp.arange(4.0)},
                         lambda t: t["a"], finalize)
    # stacked once by the executor, then passed through without re-slicing
    assert seen["out"].shape == (4,)
    got = VmapExecutor().run(lambda: {"a": jnp.arange(4.0)},
                             lambda t: t["a"] * 2, finalize)
    assert got is seen["out"]


def test_farm_base_exception_does_not_deadlock():
    """A task calling sys.exit() must settle the task and re-raise at the
    join — not kill the worker loop and hang the farm forever."""
    import sys
    farm = ThreadFarmExecutor(num_workers=2)
    with pytest.raises(SystemExit):
        farm.map_callables([lambda: 1, lambda: sys.exit(1), lambda: 2])
    # the instance is not poisoned: _call_lock was released
    results, _ = farm.map_callables([lambda: 3])
    assert results == [3]


def test_boussinesq_rejects_non_mesh_parallel_executor():
    from repro.apps import boussinesq as bq
    p = bq.BoussinesqParams(nx=16, ny=16)
    with pytest.raises(TypeError, match="serial.*or.*mesh"):
        bq.run(p, 2, executor="vmap")


def test_farm_backup_completion_unblocks_hung_original():
    """The whole point of backup tasks: a truly stuck original attempt must
    not gate map_callables once its backup has settled the task."""
    release = threading.Event()
    calls = []
    lock = threading.Lock()

    def hung_once():
        with lock:
            calls.append(1)
            first = len(calls) == 1
        if first:
            release.wait(10.0)            # simulates deadlocked I/O
            return "late"
        return "fast"

    farm = ThreadFarmExecutor(num_workers=4, deadline_factor=2.0,
                              min_straggler_s=0.02)
    t0 = time.perf_counter()
    results, stats = farm.map_callables([lambda: "ok"] * 5 + [hung_once])
    wall = time.perf_counter() - t0
    release.set()                         # free the stuck worker thread
    assert results[5] == "fast"
    assert stats["stragglers"] == [5]
    assert wall < 5.0                     # returned long before the 10s hang


def test_vmap_executor_accepts_tuple_pytree_tasks():
    """Stacked tasks as a tuple pytree (valid before the refactor) must not
    be mistaken for the paper's (args, kwargs) host form."""
    from repro.core import vmap_solve_problem

    def initialize():
        return (jnp.arange(4.0), jnp.arange(4.0) * 10)

    got = vmap_solve_problem(initialize, lambda t: t[0] + t[1],
                             lambda o: np.asarray(o))
    np.testing.assert_allclose(got, [0.0, 11.0, 22.0, 33.0])
    got = SerialExecutor().run(initialize, lambda t: t[0] + t[1],
                               lambda o: np.asarray(o))
    np.testing.assert_allclose(got, [0.0, 11.0, 22.0, 33.0])


def test_farm_reuses_pool_across_calls():
    farm = ThreadFarmExecutor(num_workers=4)
    farm.map_callables([lambda: 1] * 8)
    pool = farm._pool
    farm.map_callables([lambda: 2] * 8)
    assert farm._pool is pool              # no per-call pool teardown


def test_farm_propagates_task_errors():
    farm = ThreadFarmExecutor(num_workers=2)
    with pytest.raises(RuntimeError, match="boom"):
        farm.map_callables([lambda: 1,
                            lambda: (_ for _ in ()).throw(RuntimeError("boom"))])


def test_farm_worker_crash_frees_idle_workers():
    """An internal worker-loop bug must surface AND not strand the other
    workers in an untimed wait holding pool slots."""
    farm = ThreadFarmExecutor(num_workers=4)
    boom = RuntimeError("internal farm bug")

    def broken_rebalance(st):
        raise boom

    farm._maybe_rebalance = broken_rebalance
    with pytest.raises(RuntimeError, match="internal farm bug"):
        farm.map_callables([lambda: 1] * 8)
    del farm._maybe_rebalance              # restore the real method
    results, _ = farm.map_callables([lambda: 2] * 8)
    assert results == [2] * 8              # pool slots were not leaked


def test_farm_fails_fast_on_task_error():
    """A failing task must stop queued tasks from starting (the serial farm
    raised immediately), not run the whole batch first."""
    executed = []
    lock = threading.Lock()

    def make(i):
        def task():
            if i == 0:
                raise ValueError("early failure")
            time.sleep(0.01)
            with lock:
                executed.append(i)
            return i
        return task

    farm = ThreadFarmExecutor(num_workers=2)
    with pytest.raises(ValueError, match="early failure"):
        farm.map_callables([make(i) for i in range(40)])
    time.sleep(0.1)                        # let in-flight tasks finish
    assert len(executed) < 10              # queues were drained, not run


def test_host_task_farm_concurrent_same_config_independent():
    """Two threads on the same config must not serialize whole runs."""
    from repro.core import host_task_farm
    done_b = []

    def run_a():
        host_task_farm([lambda: time.sleep(0.1)] * 4, num_workers=2,
                       deadline_factor=None)

    def run_b():
        host_task_farm([lambda: 0] * 4, num_workers=2, deadline_factor=None)
        done_b.append(time.perf_counter())

    t0 = time.perf_counter()
    a = threading.Thread(target=run_a)
    a.start()
    time.sleep(0.02)                       # let A take the cached farm
    b = threading.Thread(target=run_b)
    b.start()
    b.join()
    assert done_b[0] - t0 < 0.15           # B did not wait out A's ~0.2s run
    a.join()


def test_farm_empty_and_single():
    farm = ThreadFarmExecutor(num_workers=4)
    results, stats = farm.map_callables([])
    assert results == [] and stats["num_workers"] == 0
    results, _ = farm.map_callables([lambda: 7])
    assert results == [7]


def test_farm_stacked_pytree_mode_matches_serial():
    farm = ThreadFarmExecutor(num_workers=4)
    got = farm.run(_initialize, _func, _finalize)
    ref = SerialExecutor().run(_initialize, _func, _finalize)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Applications select executors instead of hand-wiring tiers
# ---------------------------------------------------------------------------

def test_mcmc_executor_selection_matches():
    from repro.apps import mcmc
    y, _ = mcmc.make_synthetic_votes(jax.random.PRNGKey(2), 12, 24)
    ref = mcmc.solve(mcmc.IdealPointProblem(y, n_chains=2, n_iter=30,
                                            burn=10, seed=3), "serial")
    for spec in ("vmap", "thread"):
        got = mcmc.solve(mcmc.IdealPointProblem(y, n_chains=2, n_iter=30,
                                                burn=10, seed=3), spec)
        np.testing.assert_allclose(np.asarray(got["x_mean"]),
                                   np.asarray(ref["x_mean"]),
                                   rtol=1e-4, atol=1e-4, err_msg=spec)


def test_dmc_replica_farm():
    from repro.apps import dmc
    out = dmc.run_replicas(n_replicas=2, executor="thread", num_workers=2,
                           n_walkers=80, timesteps=120, tau=0.02, seed=0)
    assert abs(float(out["e0_estimate"]) - 1.5) < 0.4
    assert len(out["replicas"]) == 2
    # thread farm must agree with the serial executor on the same seeds
    ref = dmc.run_replicas(n_replicas=2, executor="serial",
                           n_walkers=80, timesteps=120, tau=0.02, seed=0)
    np.testing.assert_allclose(float(out["e0_estimate"]),
                               float(ref["e0_estimate"]), rtol=1e-5)


def test_boussinesq_executor_dispatch():
    from repro.apps import boussinesq as bq
    p = bq.BoussinesqParams(nx=24, ny=24, dt=0.02)
    _, _, hist = bq.run(p, 5, executor="serial")
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    _, _, hist_m = bq.run(p, 5, executor="mesh", mesh=mesh)
    # mass of the standing wave is ~0, so compare absolutely (the Schwarz
    # iterates differ from global Jacobi only at stencil tolerance)
    np.testing.assert_allclose(np.asarray(hist_m["mass"]),
                               np.asarray(hist["mass"]), atol=1e-4)


def test_fault_redispatch_stragglers_entry_point():
    from repro.train.fault import redispatch_stragglers
    results, stats = redispatch_stragglers([lambda i=i: i for i in range(5)],
                                           deadline_factor=5.0)
    assert results == list(range(5))
    assert stats["stragglers"] == []


def test_straggler_deadline_rule():
    assert straggler_deadline([1.0, 1.0, 1.0], 3.0) == 3.0
    assert straggler_deadline([1e-6] * 5, 3.0, floor=0.01) == 0.01
    # median of even-length list: upper middle (same rule as host_task_farm)
    assert straggler_deadline([1.0, 2.0], 2.0) == 4.0
    assert straggler_deadline([], 3.0, floor=0.5) == 0.5  # no history yet


def test_make_executor_rejects_options_with_instance():
    with pytest.raises(ValueError, match="configure the instance"):
        make_executor(ThreadFarmExecutor(), num_workers=8)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    with pytest.raises(ValueError, match="configure the instance"):
        make_executor(SerialExecutor(), mesh=mesh)
