"""The docs gate as a tier-1 test: broken intra-repo markdown links and
missing docstrings/``__all__`` on the serving stack's public surface fail
the suite (and CI's ``docs`` job) — see ``tools/check_docs.py``."""
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_no_broken_markdown_links():
    assert check_docs.check_links(REPO) == []


def test_public_surface_is_documented():
    assert check_docs.check_docstrings(REPO) == []


def test_architecture_doc_exists_and_covers_the_stack():
    """ARCHITECTURE.md must keep naming the load-bearing pieces — a cheap
    tripwire against the doc rotting while the stack grows."""
    doc = (REPO / "docs" / "ARCHITECTURE.md").read_text(encoding="utf-8")
    for needle in ("Request lifecycle", "PagePool", "CrossKVPool",
                   "PrefixCache", "Scheduler", "prefill_chunk",
                   "encoder_input", "reemption", "Executor",
                   "speculative", "int8", "disagg"):
        assert needle in doc, f"ARCHITECTURE.md no longer mentions {needle!r}"


def test_checker_catches_a_broken_link(tmp_path):
    (tmp_path / "a.md").write_text("see [b](missing.md) and [ok](#x)\n")
    problems = check_docs.check_links(tmp_path)
    assert len(problems) == 1 and "missing.md" in problems[0]


def test_checker_catches_missing_docstring(tmp_path):
    mod = tmp_path / "src" / "repro" / "serve"
    mod.mkdir(parents=True)
    (mod / "bad.py").write_text('"""Doc."""\n__all__ = ["f"]\n'
                                "def f():\n    pass\n")
    problems = check_docs.check_docstrings(tmp_path)
    assert any("'f' has no docstring" in p for p in problems)


def test_checker_cli_exit_status():
    proc = subprocess.run([sys.executable, str(REPO / "tools" /
                                               "check_docs.py")],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
