"""Expert capacity, mesh validation and load-aware placement (host-side).

Single-device tier-1 coverage for the expert-parallel serving stack:
``capacity()`` edge cases, ``validate_serve_mesh`` (+ the ``validate_serve_tp``
alias), the ``plan_placement`` rebalancer (skew gains, hot-expert replication,
zero-traffic eviction, determinism), ``apply_placement`` as a pure weight
permutation, the replicated-combine == single-copy bitwise property, and
engine-level drop telemetry + placement stream parity.  Multi-device parity
lives in :mod:`tests.test_serve_ep`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.comm import SerialComm
from repro.models import moe as M
from repro.models.api import build_model
from repro.serve import ServeEngine
from repro.serve.placement import (PlacementPlan, apply_placement,
                                   identity_plan, imbalance, plan_placement)


def test_capacity_edge_cases():
    """cf scales the balanced budget; cf < 1 under-provisions on purpose;
    the floor is 4 (aligned); top_k > n_experts can never route."""
    # balanced split * cf, rounded up to a multiple of 4
    assert M.capacity(64, 4, 8, 1.0) == 32
    assert M.capacity(64, 4, 8, 1.25) == 40
    # cf < 1 deliberately under-provisions (drops are counted, not hidden)
    assert M.capacity(64, 4, 8, 0.5) == 16
    # tiny token counts clamp to the aligned floor, never 0
    assert M.capacity(1, 4, 8, 1.25) == 4
    assert M.capacity(0, 2, 8, 1.0) == 4
    with pytest.raises(ValueError, match="top_k=9 > n_experts=8"):
        M.capacity(16, 9, 8, 1.0)


def test_validate_serve_mesh_and_alias():
    """Every indivisible dimension is named; dense families refuse an
    expert axis outright; the old validate_serve_tp name still works."""
    dense = build_model(smoke_config("qwen2-7b"))      # hq=4, hkv=2
    moe = build_model(smoke_config("qwen3-moe-235b-a22b"))  # E=8

    dense.validate_serve_mesh(tp=2)                    # divides everything
    moe.validate_serve_mesh(tp=2, ep=4)                # 8 experts over 8 ways
    moe.validate_serve_mesh(tp=1, ep=8)
    with pytest.raises(ValueError, match="padded_kv_heads=2"):
        dense.validate_serve_mesh(tp=4)
    with pytest.raises(ValueError, match="n_experts=8"):
        moe.validate_serve_mesh(tp=1, ep=3)
    with pytest.raises(ValueError, match="n_experts=8"):
        moe.validate_serve_mesh(tp=2, ep=8)            # ep*tp = 16 > 8
    with pytest.raises(ValueError, match="dense family"):
        dense.validate_serve_mesh(tp=1, ep=2)
    # the legacy entry point is an alias for ep=1
    dense.validate_serve_tp(2)
    with pytest.raises(ValueError, match="padded_kv_heads=2"):
        dense.validate_serve_tp(4)


def test_plan_placement_skew_gain_and_determinism():
    """Adjacent hot experts (worst case for the identity layout) rebalance
    to >= 1.5x lower max/mean; plans are bit-deterministic."""
    counts = [1000, 900, 10, 10, 10, 10, 10, 10]
    before = imbalance(identity_plan(8, 2).rank_loads(counts))
    plan = plan_placement(counts, ep=2)
    after = imbalance(plan.rank_loads(counts))
    assert before / after >= 1.5, (before, after)
    # token conservation: a plan only moves load, it never loses any
    assert plan.rank_loads(counts).sum() == sum(counts)
    # determinism: same window -> bit-identical plan
    again = plan_placement(counts, ep=2)
    for f in ("phys_expert", "slot_a", "slot_b", "split_q"):
        assert np.array_equal(getattr(plan, f), getattr(again, f)), f


def test_plan_placement_replication_and_eviction():
    """A dominant expert is replicated (split_q set, second slot) by
    evicting a zero-traffic expert; evicted experts read slot -1."""
    counts = [5000, 0, 10, 10, 0, 10, 10, 10]
    plan = plan_placement(counts, ep=2)
    h = 0
    assert plan.slot_a[h] != plan.slot_b[h] and plan.split_q[h] > 0
    evicted = [e for e in range(8) if plan.slot_a[e] < 0]
    assert evicted and all(counts[e] == 0 for e in evicted)
    gain = (imbalance(identity_plan(8, 2).rank_loads(counts))
            / imbalance(plan.rank_loads(counts)))
    assert gain >= 1.5, gain
    # replicate=False keeps one slot per expert (pure permutation)
    pure = plan_placement(counts, ep=2, replicate=False)
    assert (pure.slot_a == pure.slot_b).all() and (pure.split_q == 0).all()
    assert sorted(pure.phys_expert.tolist()) == list(range(8))
    with pytest.raises(ValueError, match="not divisible"):
        plan_placement([1, 2, 3], ep=2)


def test_plan_placement_heterogeneous_ranks():
    """Measured per-rank seconds/token feed find_optimal_workload: the 2x
    slower rank gets the lighter half of the experts."""
    counts = [300, 300, 300, 300, 20, 20, 20, 20]
    even = plan_placement(counts, ep=2).rank_loads(counts)
    assert abs(int(even[0]) - int(even[1])) <= 40, even  # uniform: balanced
    plan = plan_placement(counts, ep=2, rank_time_per_token=[1.0, 2.0])
    loads = plan.rank_loads(counts)
    assert loads[0] > loads[1], loads                    # fast rank loaded up


def test_identity_plan_matches_identity_placement():
    """The engine's no-op plan and the module-level identity dispatch map
    are the same (3, E) integers — the bitwise-parity anchor."""
    assert np.array_equal(identity_plan(8, 2).dispatch_arrays(),
                          M.identity_placement(8))


def test_apply_placement_permutes_weight_stacks():
    """apply_placement is a pure permutation of the expert axis of the
    stacked MoE leaves (router untouched), including int8 weight leaves."""
    rng = np.random.default_rng(0)
    gate = rng.standard_normal((2, 4, 3, 5)).astype(np.float32)  # (L,E,d,f)
    down = rng.standard_normal((2, 4, 5, 3)).astype(np.float32)
    q8 = {"q8": rng.integers(-127, 127, (2, 4, 3, 5), dtype=np.int8),
          "s8": np.float32(0.02)}
    params = {"blocks": {"attn": "keep", "moe": {
        "router": "keep", "gate": gate, "up": q8, "down": down}}}
    perm = np.array([2, 0, 3, 1])
    plan = PlacementPlan(4, 2, perm, np.argsort(perm), np.argsort(perm),
                         np.zeros(4, np.int64))
    out = apply_placement(params, plan)
    assert np.array_equal(out["blocks"]["moe"]["gate"], gate[:, perm])
    assert np.array_equal(out["blocks"]["moe"]["down"], down[:, perm])
    assert np.array_equal(out["blocks"]["moe"]["up"]["q8"], q8["q8"][:, perm])
    assert out["blocks"]["moe"]["up"]["s8"] == q8["s8"]  # per-tensor scale
    assert out["blocks"]["moe"]["router"] == "keep"      # routing is logical
    assert out["blocks"]["attn"] == "keep"
    # original tree untouched; unassigned slots / dense trees refuse
    assert np.array_equal(params["blocks"]["moe"]["gate"], gate)
    bad = PlacementPlan(4, 2, np.array([2, 0, 3, -1]), perm, perm,
                        np.zeros(4, np.int64))
    with pytest.raises(ValueError, match="unassigned"):
        apply_placement(params, bad)
    with pytest.raises(ValueError, match="no expert-stacked"):
        apply_placement({"blocks": {"attn": "x"}}, plan)


def test_replicated_combine_matches_single_copy():
    """Property: splitting a hot expert's capacity rows across two physical
    slots (both holding its weights) combines to the BITWISE same output,
    aux loss and telemetry as the single-copy dispatch — each capacity row
    is computed exactly once either way.  Expert E-1 is pinned out of the
    router's top_k so its eviction provably drops nothing."""
    cfg = smoke_config("qwen3-moe-235b-a22b")
    E, d, eff = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    for seed in range(3):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        # column 0 of x is the constant 1 so wr[0, E-1] = -100 pins logit
        # E-1 at -100 for every token: expert E-1 never routes
        x = jax.random.normal(ks[0], (37, d), jnp.float32).at[:, 0].set(1.0)
        wr = (jax.random.normal(ks[1], (d, E), jnp.float32) * 0.2
              ).at[:, E - 1].set(0.0).at[0, E - 1].set(-100.0)
        wg = jax.random.normal(ks[2], (E, d, eff), jnp.float32) * 0.1
        wu = jax.random.normal(ks[3], (E, d, eff), jnp.float32) * 0.1
        wd = jax.random.normal(ks[4], (E, eff, d), jnp.float32) * 0.1
        y0, aux0, s0 = M._dispatch_compute_combine(
            x, wr, wg, wu, wd, cfg, SerialComm())
        counts = np.asarray(s0["tokens"])
        assert counts[E - 1] == 0 and counts.sum() == 37 * cfg.top_k
        # the identity map reproduces the unplaced integer slots exactly
        yi, auxi, si = M._dispatch_compute_combine(
            x, wr, wg, wu, wd, cfg, SerialComm(),
            placement=jnp.asarray(M.identity_placement(E)))
        assert (np.asarray(yi) == np.asarray(y0)).all()
        assert float(auxi) == float(aux0)
        # replicate the hottest expert h into evicted E-1's slot at three
        # different q8 split points; weights permuted to match
        h = int(counts.argmax())
        for q in (64, 128, 200):
            pl = M.identity_placement(E)
            pl[1, h] = E - 1
            pl[2, h] = q
            pl[0, E - 1] = pl[1, E - 1] = -1
            idx = np.arange(E)
            idx[E - 1] = h                    # slot E-1 holds h's weights
            yr, auxr, sr = M._dispatch_compute_combine(
                x, wr, wg[idx], wu[idx], wd[idx], cfg, SerialComm(),
                placement=jnp.asarray(pl))
            assert (np.asarray(yr) == np.asarray(y0)).all(), (seed, q)
            assert float(auxr) == float(aux0)
            assert np.array_equal(np.asarray(sr["tokens"]), counts)
            assert np.array_equal(np.asarray(sr["dropped"]),
                                  np.asarray(s0["dropped"]))


def _streams(model, params, **kw):
    eng = ServeEngine(model, params, max_slots=4, max_len=96, paged=True,
                      page_size=16, prefill_chunk=32, **kw)
    for p in ([5, 17, 33, 2, 9], [7] * 9, [1, 2, 3] * 4,
              [100, 200, 300, 4, 5, 6, 7]):
        eng.submit(p, max_new_tokens=6)
    done = eng.run_until_drained()
    eng.close()
    assert all(r.error is None for r in done)
    return {r.rid: r.output for r in done}, eng


def test_engine_drop_telemetry_serial_path():
    """Capacity-factor drops are counted on the plain single-device path:
    cf=0.5 under-provisions the dispatch and the engine's stats surface
    routed/dropped totals plus per-expert counts."""
    cfg = smoke_config("qwen3-moe-235b-a22b").replace(remat="none",
                                                      capacity_factor=0.5)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _, eng = _streams(model, params)
    s = eng.stats
    assert s["moe_dropped_tokens"] > 0
    assert s["moe_tokens_routed"] == sum(s["expert_tokens"]) > 0
    assert len(s["expert_tokens"]) == cfg.n_experts
    assert s["expert_imbalance"] >= 1.0
    # dense engines carry the same keys, at zero
    dense = build_model(smoke_config("qwen2-7b").replace(remat="none"))
    _, deng = _streams(dense, dense.init(jax.random.PRNGKey(0)))
    assert deng.stats["moe_tokens_routed"] == 0
    assert deng.stats["expert_tokens"] == []


def test_engine_placement_stream_parity_single_device():
    """Re-placing experts every 2 ticks (weight permutation + dispatch map)
    leaves the greedy token streams bitwise unchanged, and dense engines
    refuse update_placement with a clear error."""
    cfg = smoke_config("qwen3-moe-235b-a22b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    want, ref = _streams(model, params)
    got, eng = _streams(model, params, placement_interval=2)
    assert got == want
    assert eng.stats["placement_updates"] >= 1
    assert eng.placement is not None
    assert sorted(eng.placement.phys_expert.tolist()) == list(range(8))
    # telemetry is placement-invariant (routing stays logical)
    assert eng.stats["moe_tokens_routed"] == ref.stats["moe_tokens_routed"]
    assert eng.stats["expert_tokens"] == ref.stats["expert_tokens"]

    dense = build_model(smoke_config("qwen2-7b").replace(remat="none"))
    deng = ServeEngine(dense, dense.init(jax.random.PRNGKey(0)), max_slots=2,
                       max_len=32, paged=True, page_size=16)
    with pytest.raises(ValueError, match="expert placement"):
        deng.update_placement()
    deng.close()
