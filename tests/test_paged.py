"""Paged serving subsystem: page pool, scheduler policy, and token-for-token
parity of the paged engine against the dense-cache engine across families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import PagePool, PagedLeafSpec, ServeEngine
from repro.serve import pages as PG
from repro.serve.scheduler import Scheduler


# ---------------------------------------------------------------------------
# PagePool host accounting
# ---------------------------------------------------------------------------

def _tiny_pool(num_pages=4, page_size=8):
    specs = {"k": PagedLeafSpec((2,), (3, 4), jnp.float32)}
    return PagePool(specs, num_pages=num_pages, page_size=page_size)


def test_pool_storage_shapes_include_trash_page():
    pool = _tiny_pool(num_pages=4, page_size=8)
    assert pool.storage["k"].shape == (2, 5, 8, 3, 4)   # 4 pages + trash
    assert pool.trash_page == 4


def test_pool_alloc_free_and_high_water():
    pool = _tiny_pool(num_pages=4)
    a = pool.alloc(3)
    assert a == [0, 1, 2] and pool.pages_in_use == 3
    assert pool.alloc(2) is None            # all-or-nothing: 1 < 2 stays put
    assert pool.pages_in_use == 3
    b = pool.alloc(1)
    assert b == [3] and pool.high_water == 4
    pool.free(a)
    assert pool.pages_in_use == 1 and pool.high_water == 4
    c = pool.alloc(3)                       # FIFO recycling is deterministic
    assert c == [0, 1, 2]


def test_scatter_gather_roundtrip():
    rng = np.random.default_rng(0)
    storage = jnp.zeros((5, 4, 2, 3))                   # (N=5, ps=4, suffix)
    chunk = jnp.asarray(rng.normal(size=(8, 2, 3)), jnp.float32)
    storage = PG.scatter_chunk(storage, jnp.asarray([3, 1]), chunk,
                               page_size=4)
    tok = jnp.asarray(rng.normal(size=(1, 2, 3)), jnp.float32)
    storage = PG.scatter_token(storage, jnp.asarray([1]), jnp.asarray([2]),
                               tok)
    got = PG.gather_pages(storage, jnp.asarray([[3, 1]]))
    want = np.asarray(chunk).copy()
    want[4 + 2] = np.asarray(tok[0])        # token landed in page 1, slot 2
    np.testing.assert_allclose(np.asarray(got[0]), want)


# ---------------------------------------------------------------------------
# Scheduler policy (host-only, no device work)
# ---------------------------------------------------------------------------

class _Req:
    def __init__(self, rid, n):
        self.rid, self.prompt, self.output = rid, np.arange(n, dtype=np.int32), []


def test_scheduler_admission_reserves_pages_all_or_nothing():
    pool = _tiny_pool(num_pages=4, page_size=8)
    s = Scheduler(max_slots=2, max_len=32, pool=pool, prefill_chunk=8)
    s.submit(_Req(0, 20))                   # ceil(21/8) = 3 pages
    s.submit(_Req(1, 20))
    admits, rejects = s.admit()
    assert [slot for slot, _ in admits] == [0] and not rejects
    assert pool.pages_in_use == 3
    assert len(s.queue) == 1                # head blocks until pages drain
    s.release(0)
    assert pool.pages_in_use == 0
    admits, _ = s.admit()
    assert [slot for slot, _ in admits] == [0]


def test_scheduler_chunks_are_page_aligned_and_interleaved():
    pool = _tiny_pool(num_pages=8, page_size=8)
    s = Scheduler(max_slots=2, max_len=64, pool=pool, prefill_chunk=16,
                  chunks_per_tick=2)
    s.submit(_Req(0, 30))                   # padded 32 -> chunks 16+16
    s.submit(_Req(1, 10))                   # padded 16 -> one chunk
    s.admit()
    jobs = s.next_chunks()
    assert [(j.slot, j.start, len(j.tokens)) for j in jobs] == [
        (0, 0, 16), (1, 0, 16)]             # round-robin across slots
    assert not jobs[0].is_last and jobs[1].is_last
    assert jobs[1].n_valid == 10            # right-padded to the page grid
    for j in jobs:
        s.chunk_done(j)
    jobs = s.next_chunks()
    assert [(j.slot, j.start, j.is_last) for j in jobs] == [(0, 16, True)]
    s.chunk_done(jobs[0])
    assert s.live_slots() == [0, 1]
    assert int(s.lengths[0]) == 30 and int(s.lengths[1]) == 10


def test_scheduler_preempts_youngest_on_exhaustion():
    pool = _tiny_pool(num_pages=4, page_size=8)
    s = Scheduler(max_slots=2, max_len=32, pool=pool, prefill_chunk=8)
    s.submit(_Req(0, 14))                   # 2 pages
    s.submit(_Req(1, 14))                   # 2 pages
    s.admit()
    for _ in range(2):
        for j in s.next_chunks():
            s.chunk_done(j)
    assert s.live_slots() == [0, 1] and pool.pages_in_use == 4
    s.lengths[0] = 16                       # slot 0 crosses a page boundary
    preempted, cow, _ = s.ensure_decode_pages()
    assert [slot for slot, _ in preempted] == [1]   # youngest admitted
    assert cow == []                        # exclusive pages: no copies
    assert s.status[1] == "free" and len(s.queue) == 1
    assert s.queue[0].rid == 1              # requeued at the head
    assert int(s.n_pages[0]) == 3           # slot 0 got its page


def test_scheduler_single_resident_exhaustion_raises():
    pool = _tiny_pool(num_pages=4, page_size=8)
    s = Scheduler(max_slots=2, max_len=32, pool=pool)
    s.submit(_Req(0, 14))
    s.admit()
    for j in s.next_chunks():
        s.chunk_done(j)
    pool.alloc(2)                           # drain the pool externally
    s.lengths[0] = 16
    with pytest.raises(RuntimeError, match="page pool exhausted"):
        s.ensure_decode_pages()


def test_scheduler_pool_too_small_for_max_len():
    pool = _tiny_pool(num_pages=2, page_size=8)
    with pytest.raises(ValueError, match="cannot hold one"):
        Scheduler(max_slots=2, max_len=32, pool=pool)   # needs 4 pages


# ---------------------------------------------------------------------------
# Engine parity: paged == dense == aligned reference, across families
# ---------------------------------------------------------------------------

PROMPTS = [[5, 17, 33, 2, 9], [100, 200, 300], [7] * 11]


def _run(model, params, paged, **kw):
    eng = ServeEngine(model, params, max_slots=3, max_len=128, paged=paged,
                      **kw)
    for p in PROMPTS:
        eng.submit(p, max_new_tokens=6)
    done = eng.run_until_drained()
    eng.close()
    return {r.rid: r.output for r in done}, eng


@pytest.fixture(scope="module", params=["qwen2-7b", "qwen3-moe-235b-a22b"])
def family(request):
    cfg = smoke_config(request.param).replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def test_paged_engine_token_parity(family):
    """Dense-cache engine and paged engine emit identical greedy streams
    (dense + MoE families; chunked prefill exercised via a small chunk)."""
    model, params = family
    dense, _ = _run(model, params, False)
    paged, eng = _run(model, params, True, page_size=16, prefill_chunk=16)
    assert dense == paged
    # the headline win: pages in use stayed far below the dense reservation
    dense_pages = 3 * 128 // 16
    assert eng.pool.high_water < dense_pages // 2


def test_paged_engine_parity_under_preemption():
    """A pool sized at the single-request minimum forces preemption; the
    recompute policy keeps greedy output streams bit-identical."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def go(paged, **kw):
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          paged=paged, **kw)
        eng.submit([5, 17, 33, 2, 9, 1, 2, 3], max_new_tokens=30)
        eng.submit([100, 200, 300, 4, 5, 6, 7, 8], max_new_tokens=30)
        done = eng.run_until_drained()
        eng.close()
        return {r.rid: r.output for r in done}, eng

    want, _ = go(False)
    got, eng = go(True, page_size=16, num_pages=4, prefill_chunk=16)
    assert got == want
    assert eng.stats["preemptions"] >= 1


def test_paged_engine_pallas_kernel_parity(family, monkeypatch):
    """The fused multi-query kernel behind prefill + decode
    (use_pallas_attention=True) emits streams identical to the jnp
    gather-fallback engine, dense + MoE, prefix cache on and off — and the
    kernel path never touches ``gather_pages``: the whole point is that the
    page gather happens on-chip via the prefetched table, so HBM
    materialization of the cache would be a silent perf regression."""
    model, params = family
    for prefix_cache in (False, True):
        want, _ = _run(model, params, True, page_size=16, prefill_chunk=16,
                       prefix_cache=prefix_cache)
        real = PG.gather_pages
        calls = []

        def counting(storage, tables, *, n_prefix=0):
            calls.append(tables.shape)
            return real(storage, tables, n_prefix=n_prefix)

        monkeypatch.setattr(PG, "gather_pages", counting)
        got, _ = _run(model, params, True, page_size=16, prefill_chunk=16,
                      prefix_cache=prefix_cache, use_pallas_attention=True)
        monkeypatch.undo()
        assert got == want, prefix_cache
        assert calls == [], calls           # no HBM gather on the hot path


def test_paged_engine_pallas_parity_under_preemption():
    """Forced preemption + recompute with the kernel on: streams stay
    bit-identical to the kernel-off run and the pool is conserved."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def go(**kw):
        eng = ServeEngine(model, params, max_slots=2, max_len=64, paged=True,
                          page_size=16, num_pages=4, prefill_chunk=16, **kw)
        eng.submit([5, 17, 33, 2, 9, 1, 2, 3], max_new_tokens=30)
        eng.submit([100, 200, 300, 4, 5, 6, 7, 8], max_new_tokens=30)
        done = eng.run_until_drained()
        eng.close()
        return {r.rid: r.output for r in done}, eng

    want, eng_off = go()
    got, eng_on = go(use_pallas_attention=True)
    assert eng_off.stats["preemptions"] >= 1
    assert eng_on.stats["preemptions"] >= 1
    assert got == want
    pool = eng_on.pool
    assert pool.pages_free + pool.pages_cached == pool.num_pages


def test_pallas_attention_flag_validated_at_construction():
    """use_pallas_attention is checked once in __init__: a paged-capable
    family forced to paged=False and a recurrent family (no paged KV cache,
    ever) both fail fast with an error naming the family — not mid-tick
    inside a jitted call."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged=False"):
        ServeEngine(model, params, paged=False, use_pallas_attention=True)

    rcfg = smoke_config("rwkv6-3b").replace(remat="none")
    rmodel = build_model(rcfg)
    rparams = rmodel.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="recurrent/window"):
        ServeEngine(rmodel, rparams, use_pallas_attention=True)


def test_recurrent_family_keeps_dense_path():
    """rwkv6 has O(1) decode state — the engine auto-selects the dense slot
    path and still matches itself run-to-run; paged=True is refused."""
    cfg = smoke_config("rwkv6-3b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert not model.supports_paged_decode()
    with pytest.raises(ValueError, match="no paged KV cache"):
        ServeEngine(model, params, paged=True)
    a, enga = _run(model, params, None)
    assert not enga.paged
    b, _ = _run(model, params, None)
    assert a == b and len(a) == 3


def test_chunked_prefill_keeps_decode_flowing():
    """While a long prompt prefills chunk-by-chunk, an already-live request
    keeps emitting tokens every tick (the anti-stall property)."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_slots=2, max_len=128, paged=True,
                      page_size=16, prefill_chunk=16, chunks_per_tick=1)
    eng.submit([9, 8, 7], max_new_tokens=24)
    eng.run_until_drained(max_ticks=2)          # short request is live
    short = eng.sched.slot_req[0]
    eng.submit(list(range(1, 100)), max_new_tokens=4)   # 99 tokens: 7 chunks
    n0 = len(short.output)
    for _ in range(6):                          # six ticks of chunked prefill
        eng.tick()
    n1 = len(short.output)
    assert n1 - n0 == 6                         # one token per tick, no stall
    long_req = eng.sched.slot_req[1]
    assert long_req is not None and not long_req.output   # still prefilling
    done = eng.run_until_drained()
    eng.close()
    by_len = {len(r.prompt): r for r in done}
    assert len(by_len[3].output) == 24 and len(by_len[99].output) == 4
    assert eng.stats["chunk_prefills"] >= 7


# ---------------------------------------------------------------------------
# Prefill-failure page accounting (regression: a request that errors
# mid-chunked-prefill must hand every reserved page back to the pool)
# ---------------------------------------------------------------------------

def _pool_conserved(eng):
    """free + cached-unreferenced + held partitions the pool, and the
    slots' table references account for every refcount."""
    pool = eng.pool
    return (pool.pages_free + pool.pages_cached + pool.pages_in_use
            == pool.num_pages
            and eng.sched.held_pages()
            == sum(pool.ref(p) for p in range(pool.num_pages)))


def test_prefill_sampler_failure_returns_pages():
    """The last-chunk lm-head/sampler path is error-isolated too: a sampler
    that raises on the first token retires the request with ``req.error``,
    frees its pages, and never stalls the other requests."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_slots=2, max_len=128, paged=True,
                      page_size=16, prefill_chunk=16)

    def bad_sampler(key, logits):
        raise RuntimeError("sampler exploded")

    eng.submit(list(range(1, 40)), max_new_tokens=4, sampler=bad_sampler)
    eng.submit([1, 2, 3], max_new_tokens=3)
    done = eng.run_until_drained()
    eng.close()
    assert _pool_conserved(eng) and eng.pool.pages_in_use == 0
    bad = [r for r in done if r.error is not None]
    good = [r for r in done if r.error is None]
    assert len(bad) == 1 and "sampler exploded" in str(bad[0].error)
    assert len(good) == 1 and len(good[0].output) == 3


def test_prefill_device_failure_mid_chunk_returns_pages():
    """An error in the Nth prefill chunk's device call releases the slot's
    whole reservation (pool invariant holds every tick)."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_slots=2, max_len=128, paged=True,
                      page_size=16, prefill_chunk=16, chunks_per_tick=1)
    orig, calls = eng._prefill_chunk, {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected chunk failure")
        return orig(*a, **kw)

    eng._prefill_chunk = flaky
    eng.submit(list(range(1, 50)), max_new_tokens=4)    # 49 tokens: 4 chunks
    while eng.tick():
        assert _pool_conserved(eng)
    eng.close()
    assert eng.pool.pages_in_use == 0
    (req,) = eng.finished
    assert req.error is not None and not req.output


def test_prefill_failure_with_donated_storage_recovers():
    """Non-CPU backends donate the pool storage into the jitted calls, so a
    call that raises may already have CONSUMED the buffers.  The engine
    must detect that, evict residents (recompute flavor) and rebuild zeroed
    storage — the surviving request's greedy stream still matches an
    unfailed run token for token."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def run(inject):
        eng = ServeEngine(model, params, max_slots=2, max_len=128,
                          paged=True, page_size=16, prefill_chunk=16,
                          chunks_per_tick=1)
        if inject:
            orig, calls = eng._prefill_chunk, {"n": 0}

            def flaky(*a, **kw):
                calls["n"] += 1
                if calls["n"] == 3:
                    for leaf in jax.tree_util.tree_leaves(eng.pool.storage):
                        leaf.delete()       # simulate consumed donation
                    raise RuntimeError("injected donated failure")
                return orig(*a, **kw)

            eng._prefill_chunk = flaky
        eng.submit([9, 8, 7, 6], max_new_tokens=6)       # resident victim
        eng.submit(list(range(1, 40)), max_new_tokens=4)  # fails mid-prefill
        done = eng.run_until_drained()
        eng.close()
        assert _pool_conserved(eng)
        assert eng.pool.pages_in_use == 0
        assert not eng.pool.storage_deleted()
        return {len(r.prompt): (r.output, r.error is not None) for r in done}

    want = run(False)
    got = run(True)
    assert got[39][1] and not got[39][0]         # failed request, no output
    assert not want[39][1]
    assert got[4] == want[4]                     # victim's stream unchanged


def test_decode_sampler_failure_is_isolated():
    """A per-request sampler that works for the first token but raises on a
    later decode tick retires only that request (req.error set, pages
    freed); the other live slots keep decoding."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_slots=2, max_len=128, paged=True,
                      page_size=16, prefill_chunk=16)
    calls = {"n": 0}

    def flaky_sampler(key, logits):
        calls["n"] += 1
        if calls["n"] >= 3:
            raise RuntimeError("sampler died mid-decode")
        return jnp.argmax(logits).astype(jnp.int32)

    eng.submit([5, 17, 33], max_new_tokens=10, sampler=flaky_sampler)
    eng.submit([1, 2, 3], max_new_tokens=10)
    done = eng.run_until_drained()
    eng.close()
    assert eng.pool.pages_in_use == 0
    bad = [r for r in done if r.error is not None]
    good = [r for r in done if r.error is None]
    assert len(bad) == 1 and "mid-decode" in str(bad[0].error)
    assert 1 <= len(bad[0].output) < 10          # died after emitting some
    assert len(good) == 1 and len(good[0].output) == 10


def test_decode_failure_with_donated_storage_recovers():
    """A decode-tick failure still raises (engine-level), but if the
    raising call consumed the donated storage the engine recovers first:
    residents are evicted recompute-style, so simply ticking on completes
    every stream bit-identically to an unfailed run."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def run(inject):
        eng = ServeEngine(model, params, max_slots=2, max_len=128,
                          paged=True, page_size=16, prefill_chunk=16)
        if inject:
            orig, calls = eng._decode_paged, {"n": 0}

            def flaky(*a, **kw):
                calls["n"] += 1
                if calls["n"] == 2:
                    for leaf in jax.tree_util.tree_leaves(eng.pool.storage):
                        leaf.delete()
                    raise RuntimeError("injected decode failure")
                return orig(*a, **kw)

            eng._decode_paged = flaky
        eng.submit([9, 8, 7, 6], max_new_tokens=6)
        eng.submit([5, 4, 3], max_new_tokens=6)
        if inject:
            with pytest.raises(RuntimeError, match="injected"):
                eng.run_until_drained()
            assert not eng.pool.storage_deleted()    # recovered already
        done = eng.run_until_drained()
        eng.close()
        assert _pool_conserved(eng)
        return {len(r.prompt): r.output for r in done}, eng

    want, _ = run(False)
    got, eng = run(True)
    assert got == want
    assert eng.stats["preemptions"] >= 1


def test_paged_state_specs_match_pool_storage():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    pool = PagePool(model.paged_leaf_specs(), num_pages=6, page_size=16)
    specs = model.paged_state_specs(6, 16)
    shapes = jax.tree_util.tree_map(lambda a: a.shape, pool.storage)
    spec_shapes = jax.tree_util.tree_map(
        lambda s: s.shape, specs, is_leaf=lambda x: hasattr(x, "spec"))
    assert shapes == spec_shapes
