"""The paper's three applications — scientific correctness on one device.
(Multi-device equivalence lives in test_distributed.py subprocesses.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import boussinesq as bq
from repro.apps import dmc, mcmc


# ---------------------------------------------------------------------------
# §4.1 MCMC ideal points
# ---------------------------------------------------------------------------

def test_mcmc_recovers_ideal_points():
    y, truth = mcmc.make_synthetic_votes(jax.random.PRNGKey(1),
                                         n_leg=50, n_votes=120)
    prob = mcmc.IdealPointProblem(y, n_chains=4, n_iter=120, burn=60)
    res = mcmc.solve_vmap(prob)
    corr = abs(np.corrcoef(np.asarray(res["x_mean"]),
                           np.asarray(truth["x"]))[0, 1])
    assert corr > 0.85, corr


def test_mcmc_serial_equals_vmap_structure():
    y, _ = mcmc.make_synthetic_votes(jax.random.PRNGKey(2), 20, 40)
    p1 = mcmc.IdealPointProblem(y, n_chains=2, n_iter=40, burn=20, seed=3)
    p2 = mcmc.IdealPointProblem(y, n_chains=2, n_iter=40, burn=20, seed=3)
    r1 = mcmc.solve_serial(p1)
    r2 = mcmc.solve_vmap(p2)
    # same chains, same seeds -> identical draws
    np.testing.assert_allclose(np.asarray(r1["x_mean"]),
                               np.asarray(r2["x_mean"]), rtol=1e-4, atol=1e-4)


def test_trunc_normal_signs():
    key = jax.random.PRNGKey(0)
    mu = jnp.zeros((1000,))
    pos = mcmc._trunc_normal(key, mu, jnp.ones(1000, bool))
    neg = mcmc._trunc_normal(key, mu, jnp.zeros(1000, bool))
    assert (np.asarray(pos) > 0).all() and (np.asarray(neg) < 0).all()


# ---------------------------------------------------------------------------
# §4.2 Diffusion Monte Carlo
# ---------------------------------------------------------------------------

def test_dmc_ground_state_energy():
    out = dmc.run_serial(n_walkers=300, timesteps=500, tau=0.02, seed=0)
    assert abs(float(out["e0_estimate"]) - 1.5) < 0.15


def test_dmc_population_control():
    out = dmc.run_serial(n_walkers=200, timesteps=300, tau=0.02, seed=1)
    counts = np.asarray(out["counts"])
    # E_T feedback keeps the population near target, never extinct/exploded
    assert counts.min() > 50 and counts.max() < 800
    assert abs(counts[-50:].mean() - 200) < 80


def test_walker_step_compaction_invariants():
    key = jax.random.PRNGKey(0)
    pos = jax.random.normal(key, (64, 3))
    count = jnp.asarray(40, jnp.int32)
    new_pos, new_count, obs = dmc.walker_step(key, pos, count,
                                              jnp.asarray(1.5), tau=0.01)
    n = int(new_count)
    assert 0 <= n <= 64
    # dead slots zeroed; live slots finite
    np.testing.assert_allclose(np.asarray(new_pos[n:]), 0.0)
    assert np.isfinite(np.asarray(new_pos[:n])).all()


# ---------------------------------------------------------------------------
# §4.3 Boussinesq (serial; Schwarz equivalence is distributed test)
# ---------------------------------------------------------------------------

def test_boussinesq_mass_conserved():
    p = bq.BoussinesqParams(nx=48, ny=48, dt=0.02)
    _, _, hist = bq.run_serial(p, steps=30)
    mass = np.asarray(hist["mass"])
    assert abs(mass[-1] - mass[0]) < 1e-3 * abs(mass[0]) + 1e-3


def test_boussinesq_wave_oscillates():
    """Standing-wave probe must oscillate (not decay to zero or blow up).

    k_mode=1: the probe at x = Lx/4 sits at cos(pi/4), off any node."""
    p = bq.BoussinesqParams(nx=48, ny=48, dt=0.05, eps=0.2)
    _, _, hist = bq.run_serial(p, steps=200, k_mode=1)
    probe = np.asarray(hist["probe"])
    assert np.isfinite(probe).all()
    assert probe.max() > 0.01 and probe.min() < -0.01      # oscillation
    assert abs(probe).max() < 0.2                           # stability


def test_boussinesq_dispersion_slows_waves():
    """Boussinesq regime: larger eps (dispersion) -> slower oscillation.

    Count probe zero-crossings as a frequency proxy."""
    def crossings(eps):
        # k_mode=4: k^2 ~ 6.9, so eps=1 slows the wave ~45% vs eps~0
        p = bq.BoussinesqParams(nx=48, ny=48, dt=0.05, eps=eps)
        _, _, hist = bq.run_serial(p, steps=400, k_mode=4)
        probe = np.asarray(hist["probe"])
        return int((np.diff(np.sign(probe)) != 0).sum())

    assert crossings(1.0) < 0.8 * crossings(0.01)


def test_jacobi_solves_helmholtz():
    """The 'legacy serial kernel' actually solves (I - c∇²)x = b
    (BC refreshed between sweep batches, as the Schwarz loop does)."""
    p = bq.BoussinesqParams(nx=32, ny=32)
    rng = np.random.default_rng(0)
    rhs = jnp.asarray(rng.normal(size=(32, 32)) * 0.1)
    x = jnp.zeros((34, 32))
    for _ in range(150):
        x = bq.apply_physical_bc(x, None)
        x = bq.jacobi_sweeps(x, rhs, p.c, p.dx, 6)
    x = bq.apply_physical_bc(x, None)
    resid = rhs - (x[1:-1] - p.c * bq.laplacian(x, p.dx))
    assert float(jnp.abs(resid).max()) < 1e-4
