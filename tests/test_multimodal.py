"""Encoder-attached (multimodal) serving tests: VLM image prefixes and
enc-dec audio through the paged engine.

What is pinned here:

* **llava paged parity** — a VLM request with precomputed image-patch
  embeddings decodes the same greedy stream through the paged engine
  (image prefix as pseudo-token KV pages) as a hand-driven dense
  ``prefill`` + ``decode_step`` reference.
* **image prefix caching** — the pseudo-token prefix is a pure content
  hash of the embeddings, so repeated-image requests hit the radix index
  (shared image pages) while distinct images never alias.
* **whisper paged parity** — an audio request decodes the same greedy
  stream as the dense enc-dec reference when the clip fits one encode
  chunk (streaming chunked encode is exact there: full bidirectional
  attention over the chunk).
* **cross-KV pool conservation** — property-tested over random
  admit / encode / chunk / preempt / release interleavings, including
  forced preemption: free + in-use cross pages always partition the pool
  and FREE slots hold no cross pages.
* **int8 composition** — both modalities run deterministically with
  ``kv_quant="int8"`` (cross K/V quantized on scatter like self-KV).
* **construction-time validation** — ``validate_serve_encoder`` rejects
  impossible encoder geometry with the fix spelled out.
"""
import json

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine, make_workload, run_traffic
from repro.serve.engine import encoder_prefix_tokens
from repro.serve.pages import CrossKVPool, PagedLeafSpec
from repro.serve.scheduler import FREE, EncodeJob, Scheduler
from repro.serve.traffic import record_trace, workload_from_trace

import jax.numpy as jnp


@pytest.fixture(scope="module")
def vlm():
    cfg = smoke_config("llava-next-mistral-7b").replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def whisper():
    cfg = smoke_config("whisper-tiny").replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _greedy_ref_vlm(model, params, img, prompt, n_new):
    """Dense reference: prefill with image_embeds, then decode_step loop."""
    cfg = model.cfg
    S, I = len(prompt), cfg.n_image_tokens
    max_len = I + S + n_new + 2
    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32),
             "image_embeds": jnp.asarray(img[None], jnp.dtype(cfg.dtype))}
    state, hidden = model.prefill(params, batch, None, max_len)
    logits = model.lm_head(params, hidden[:, -1:], None)
    out = [int(np.argmax(np.asarray(logits)[0, -1, :cfg.vocab]))]
    for t in range(n_new - 1):
        pos = jnp.asarray(I + S + t, jnp.int32)
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        state, logits = model.decode_step(params, state, tok, pos, None)
        out.append(int(np.argmax(np.asarray(logits)[0, -1, :cfg.vocab])))
    return out


def _greedy_ref_whisper(model, params, frames, prompt, n_new):
    """Dense enc-dec reference: full encode + decoder prefill, then
    per-token decode."""
    cfg = model.cfg
    S = len(prompt)
    max_len = S + n_new + 2
    batch = {"frames": jnp.asarray(frames[None], jnp.dtype(cfg.dtype)),
             "tokens": jnp.asarray(prompt[None], jnp.int32)}
    state, hidden = model.prefill(params, batch, None, max_len)
    logits = model.lm_head(params, hidden[:, -1:], None)
    out = [int(np.argmax(np.asarray(logits)[0, -1, :cfg.vocab]))]
    for t in range(n_new - 1):
        pos = jnp.asarray(S + t, jnp.int32)
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        state, logits = model.decode_step(params, state, tok, pos, None)
        out.append(int(np.argmax(np.asarray(logits)[0, -1, :cfg.vocab])))
    return out


# ---------------------------------------------------------------------------
# VLM: image prefix through the paged engine
# ---------------------------------------------------------------------------

def test_vlm_paged_matches_dense_reference(vlm):
    model, params = vlm
    cfg = model.cfg
    rng = np.random.default_rng(0)
    img = rng.standard_normal(
        (cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    prompt = rng.integers(0, cfg.vocab, 11).astype(np.int32)
    n_new = 6
    ref = _greedy_ref_vlm(model, params, img, prompt, n_new)
    eng = ServeEngine(model, params, max_slots=2, max_len=64, page_size=8,
                      prefill_chunk=8)
    eng.submit(prompt, max_new_tokens=n_new, encoder_input=img)
    done = eng.run_until_drained()
    eng.close()
    assert len(done) == 1 and done[0].error is None
    assert done[0].output == ref


def test_vlm_mixed_image_and_text_requests(vlm):
    """Text-only and image requests coexist in one batch; text streams
    equal a text-only engine's (zero special cases downstream)."""
    model, params = vlm
    cfg = model.cfg
    rng = np.random.default_rng(1)
    img = rng.standard_normal(
        (cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    txt_prompt = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    img_prompt = rng.integers(0, cfg.vocab, 7).astype(np.int32)

    solo = ServeEngine(model, params, max_slots=2, max_len=64, page_size=8,
                       prefill_chunk=8)
    solo.submit(txt_prompt, max_new_tokens=5)
    ref = {r.rid: r.output for r in solo.run_until_drained()}
    solo.close()

    eng = ServeEngine(model, params, max_slots=2, max_len=64, page_size=8,
                      prefill_chunk=8)
    r_txt = eng.submit(txt_prompt, max_new_tokens=5)
    r_img = eng.submit(img_prompt, max_new_tokens=5, encoder_input=img)
    done = {r.rid: r for r in eng.run_until_drained()}
    eng.close()
    assert done[r_txt].error is None and done[r_img].error is None
    assert done[r_txt].output == ref[0]
    assert len(done[r_img].output) == 5


def test_repeated_image_hits_prefix_cache(vlm):
    """Same image -> same pseudo-token prefix -> shared pages (prefix
    hits); a different image never aliases."""
    model, params = vlm
    cfg = model.cfg
    rng = np.random.default_rng(2)
    img_a = rng.standard_normal(
        (cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    img_b = rng.standard_normal(
        (cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    prompt = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    eng = ServeEngine(model, params, max_slots=2, max_len=64, page_size=8,
                      prefill_chunk=8)
    eng.submit(prompt, max_new_tokens=4, encoder_input=img_a)
    eng.run_until_drained()
    hits0 = eng.stats["prefix_hits"]
    # same image + same prompt: at least the image page (8 positions) and
    # the first prompt page re-use
    eng.submit(prompt, max_new_tokens=4, encoder_input=img_a)
    eng.run_until_drained()
    assert eng.stats["prefix_hits"] == hits0 + 1
    assert eng.stats["prefix_hit_tokens"] >= cfg.n_image_tokens
    hit_toks = eng.stats["prefix_hit_tokens"]
    # different image, same prompt: pseudo-tokens differ from position 0,
    # so nothing matches (the image prefix blocks accidental text sharing)
    eng.submit(prompt, max_new_tokens=4, encoder_input=img_b)
    done = eng.run_until_drained()
    eng.close()
    assert eng.stats["prefix_hit_tokens"] == hit_toks
    assert all(r.error is None for r in done)
    # streams for identical (image, prompt) pairs are identical
    outs = [r.output for r in done]
    assert outs[0] == outs[1]


def test_encoder_prefix_tokens_contract():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((8, 16)).astype(np.float32)
    b = rng.standard_normal((8, 16)).astype(np.float32)
    ta, ta2, tb = (encoder_prefix_tokens(x) for x in (a, a.copy(), b))
    assert ta.dtype == np.int32 and len(ta) == 8
    assert np.all(ta < 0), "pseudo-tokens must never collide with vocab ids"
    assert np.array_equal(ta, ta2), "content-addressed: same image, same ids"
    assert not np.array_equal(ta, tb)


# ---------------------------------------------------------------------------
# whisper: enc-dec audio through the paged engine
# ---------------------------------------------------------------------------

def test_whisper_paged_matches_dense_reference(whisper):
    model, params = whisper
    cfg = model.cfg
    rng = np.random.default_rng(0)
    frames = rng.standard_normal(
        (cfg.n_audio_frames, cfg.d_model)).astype(np.float32)
    prompt = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    n_new = 6
    ref = _greedy_ref_whisper(model, params, frames, prompt, n_new)
    # encode_chunk >= n_audio_frames: one chunk == exact full encode
    eng = ServeEngine(model, params, max_slots=2, max_len=64, page_size=8,
                      prefill_chunk=16)
    eng.submit(prompt, max_new_tokens=n_new, encoder_input=frames)
    done = eng.run_until_drained()
    eng.close()
    assert len(done) == 1 and done[0].error is None
    assert done[0].output == ref
    assert eng.stats["encode_chunks"] >= 1


def test_whisper_batched_requests_and_release(whisper):
    """Several clips decode concurrently; after drain every cross page is
    back in the pool and identical (clip, prompt) pairs match streams."""
    model, params = whisper
    cfg = model.cfg
    rng = np.random.default_rng(1)
    clips = [rng.standard_normal(
        (cfg.n_audio_frames, cfg.d_model)).astype(np.float32)
        for _ in range(2)]
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (6, 9, 6)]
    eng = ServeEngine(model, params, max_slots=3, max_len=64, page_size=8,
                      prefill_chunk=16)
    eng.submit(prompts[0], max_new_tokens=5, encoder_input=clips[0])
    eng.submit(prompts[1], max_new_tokens=5, encoder_input=clips[1])
    eng.submit(prompts[0], max_new_tokens=5, encoder_input=clips[0])
    done = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert all(r.error is None for r in done)
    assert done[0].output == done[2].output
    assert eng.cross_pool.pages_in_use == 0
    assert eng.cross_pool.pages_free == eng.cross_pool.num_pages
    eng.close()


def test_whisper_short_clip_and_validation(whisper):
    model, params = whisper
    cfg = model.cfg
    rng = np.random.default_rng(2)
    eng = ServeEngine(model, params, max_slots=2, max_len=64, page_size=8,
                      prefill_chunk=16)
    short = rng.standard_normal((5, cfg.d_model)).astype(np.float32)
    prompt = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    eng.submit(prompt, max_new_tokens=4, encoder_input=short)
    done = eng.run_until_drained()
    assert done[0].error is None and len(done[0].output) == 4
    with pytest.raises(ValueError, match="requires encoder_input"):
        eng.submit(prompt, max_new_tokens=4)           # enc-dec needs a clip
    with pytest.raises(ValueError, match="audio frames"):
        eng.submit(prompt, max_new_tokens=4, encoder_input=np.zeros(
            (cfg.n_audio_frames + 1, cfg.d_model), np.float32))
    assert eng.prefix_cache is False, \
        "enc-dec must disable token-keyed prefix sharing"
    eng.close()


def test_multimodal_int8_kv_composes(whisper, vlm):
    """Both modalities serve deterministically with int8 KV pages (cross
    K/V included — scale leaves ride the same scatter)."""
    for (model, params), mk_enc in (
            (whisper, lambda cfg, rng: rng.standard_normal(
                (cfg.n_audio_frames, cfg.d_model)).astype(np.float32)),
            (vlm, lambda cfg, rng: rng.standard_normal(
                (cfg.n_image_tokens, cfg.d_model)).astype(np.float32))):
        cfg = model.cfg
        streams = []
        for _ in range(2):
            rng = np.random.default_rng(7)
            eng = ServeEngine(model, params, max_slots=2, max_len=64,
                              page_size=8, prefill_chunk=16,
                              kv_quant="int8")
            eng.submit(rng.integers(0, cfg.vocab, 8).astype(np.int32),
                       max_new_tokens=5, encoder_input=mk_enc(cfg, rng))
            done = eng.run_until_drained()
            eng.close()
            assert done[0].error is None
            streams.append(done[0].output)
        assert streams[0] == streams[1], cfg.name


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------

def test_vlm_page_size_validation(vlm):
    """The llava SMOKE bugfix: n_image_tokens=8 with a 16-wide page and the
    prefix cache on can never share image pages — rejected at construction
    with the fix in the message."""
    model, params = vlm
    with pytest.raises(ValueError, match="--page-size 8"):
        ServeEngine(model, params, max_slots=2, max_len=64, page_size=16)
    # either fix works: page size that divides I, or prefix cache off
    ServeEngine(model, params, max_slots=2, max_len=64, page_size=8).close()
    ServeEngine(model, params, max_slots=2, max_len=64, page_size=16,
                prefix_cache=False).close()


def test_vlm_max_len_validation(vlm):
    model, params = vlm
    I = model.cfg.n_image_tokens
    with pytest.raises(ValueError, match="--max-len"):
        ServeEngine(model, params, max_slots=2, max_len=I + 1, page_size=8)


def test_whisper_engine_flags_validation(whisper):
    model, params = whisper
    with pytest.raises(ValueError, match="paged engine only"):
        ServeEngine(model, params, max_slots=2, max_len=64, paged=False)
    with pytest.raises(ValueError, match="prefill_only"):
        ServeEngine(model, params, max_slots=2, max_len=64, page_size=8,
                    prefill_only=True)


def test_text_family_rejects_encoder_input():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_slots=2, max_len=64)
    with pytest.raises(ValueError, match="no encoder_input"):
        eng.submit(np.arange(4), max_new_tokens=2,
                   encoder_input=np.zeros((4, cfg.d_model), np.float32))
    eng.close()


# ---------------------------------------------------------------------------
# cross-KV pool: conservation under random interleavings (property)
# ---------------------------------------------------------------------------

class _Req:
    def __init__(self, rid, n_tok, n_frames):
        self.rid = rid
        self.prompt = np.arange(n_tok, dtype=np.int32)
        self.encoder_input = np.zeros((n_frames, 2), np.float32)
        self.output: list = []


def _cross_sched():
    leaf = PagedLeafSpec((1,), (1, 1), jnp.float32)
    from repro.serve.pages import PagePool
    pool = PagePool({"k": leaf, "v": leaf}, num_pages=8, page_size=4)
    cross = CrossKVPool({"cross_k": leaf, "cross_v": leaf},
                        num_pages=6, page_size=4)
    sched = Scheduler(max_slots=3, max_len=32, pool=pool, prefill_chunk=4,
                      chunks_per_tick=2, cross_pool=cross, max_frames=8)
    return pool, cross, sched


def _check_cross(cross, sched):
    assert cross.pages_cached == 0, "cross pages never park (no prefix keys)"
    assert cross.pages_free + cross.pages_in_use == cross.num_pages
    held = sum(int(sched.cross_n[s]) for s in range(sched.max_slots)
               if sched.status[s] != FREE)
    assert held == cross.pages_in_use == sched.held_cross_pages()
    for s in range(sched.max_slots):
        if sched.status[s] == FREE:
            assert sched.cross_n[s] == 0, "FREE slots hold no cross pages"


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 8),
                          st.integers(1, 8)), min_size=4, max_size=40),
       st.integers(0, 2 ** 31 - 1))
def test_cross_pool_conservation_property(actions, seed):
    """Random submit / plan+commit / preempt / release interleavings —
    including pool-dry forced preemption — never leak or double-free a
    cross page."""
    rng = np.random.default_rng(seed)
    pool, cross, sched = _cross_sched()
    rid = 0
    for op, n_tok, n_frames in actions:
        if op == 0:                                     # submit + admit
            sched.submit(_Req(rid, n_tok, n_frames))
            rid += 1
            sched.admit()
        elif op == 1:                                   # plan + commit work
            for job in sched.next_chunks():
                if isinstance(job, EncodeJob):
                    sched.encode_done(job)
                else:
                    sched.chunk_done(job)
        elif op == 2:                                   # preempt youngest
            live = [s for s in range(sched.max_slots)
                    if sched.slot_req[s] is not None]
            if live:
                sched.preempt(int(rng.choice(live)))
        else:                                           # retire one slot
            live = [s for s in range(sched.max_slots)
                    if sched.slot_req[s] is not None]
            if live:
                sched.release(int(rng.choice(live)))
        _check_cross(cross, sched)
    # drain: releasing everything returns the cross pool to fully free
    for s in range(sched.max_slots):
        if sched.slot_req[s] is not None:
            sched.release(s)
    _check_cross(cross, sched)
    assert cross.pages_in_use == 0


def test_cross_pool_rejects_prefix_cache():
    leaf = PagedLeafSpec((1,), (1, 1), jnp.float32)
    with pytest.raises(ValueError, match="content-addressed"):
        CrossKVPool({"cross_k": leaf}, num_pages=4, page_size=4,
                    prefix_cache=True)


def test_forced_preemption_conserves_cross_pages(whisper):
    """Engine-level: a self-KV pool too small for every clip forces
    preemption mid-decode; cross pages must follow their requests out and
    back without leaking."""
    model, params = whisper
    cfg = model.cfg
    rng = np.random.default_rng(5)
    eng = ServeEngine(model, params, max_slots=3, max_len=32, page_size=8,
                      prefill_chunk=16, num_pages=5)
    for i in range(3):
        eng.submit(rng.integers(0, cfg.vocab, 10).astype(np.int32),
                   max_new_tokens=12,
                   encoder_input=rng.standard_normal(
                       (cfg.n_audio_frames, cfg.d_model)
                   ).astype(np.float32))
    for _ in range(200):
        busy = eng.tick()
        assert (eng.cross_pool.pages_free + eng.cross_pool.pages_in_use
                == eng.cross_pool.num_pages)
        assert eng.cross_pool.pages_in_use == eng.sched.held_cross_pages()
        if not busy:
            break
    done = eng.finished
    eng.close()
    assert eng.stats["preemptions"] > 0, "pool was not actually forced dry"
    assert len(done) == 3 and all(r.error is None for r in done)
    assert eng.cross_pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# traffic: mixed-modality workloads
# ---------------------------------------------------------------------------

def test_workload_multimodal_determinism_and_gating():
    kw = dict(kind="poisson", n_requests=10, rate=0.5, vocab=97, seed=11,
              shared_prefix_len=4, n_sessions=2)
    text = make_workload(**kw)
    a = make_workload(**kw, encoder="image", encoder_shape=(4, 8),
                      encoder_frac=0.5)
    b = make_workload(**kw, encoder="image", encoder_shape=(4, 8),
                      encoder_frac=0.5)
    # same seed, same multimodal schedule (payloads bit-equal)
    for ra, rb in zip(a, b):
        assert (ra.encoder_input is None) == (rb.encoder_input is None)
        if ra.encoder_input is not None:
            assert np.array_equal(ra.encoder_input, rb.encoder_input)
    # the arrival process and length mix are drawn before the encoder pool,
    # so they are independent of the encoder band; and a text-only workload
    # with the same seed reproduces itself exactly (encoder=None adds no
    # rng draws)
    for rt, ra in zip(text, a):
        assert rt.arrival == ra.arrival
        assert len(rt.prompt) == len(ra.prompt)
        assert rt.encoder_input is None
    for rt, rt2 in zip(text, make_workload(**kw)):
        assert rt.arrival == rt2.arrival and rt.session == rt2.session
        assert np.array_equal(rt.prompt, rt2.prompt)
    assert any(r.encoder_input is not None for r in a)
    # session-bound requests reuse their session's payload
    by_sess = {}
    for r in a:
        if r.encoder_input is None or r.session < 0:
            continue
        key = r.session
        if key in by_sess:
            assert np.array_equal(by_sess[key], r.encoder_input)
        by_sess[key] = r.encoder_input


def test_trace_roundtrip_with_encoder_payloads():
    wl = make_workload(kind="poisson", n_requests=6, rate=1.0, vocab=97,
                       seed=3, encoder="audio", encoder_shape=(6, 8),
                       encoder_frac=1.0, n_encoder_inputs=2)
    trace = record_trace(wl, [], {})
    back = workload_from_trace(json.loads(json.dumps(trace)))
    assert len(back) == len(wl)
    for ra, rb in zip(wl, back):
        assert ra.arrival == rb.arrival
        assert np.array_equal(ra.prompt, rb.prompt)
        assert rb.encoder_input is not None
        assert rb.encoder_input.dtype == np.float32
        assert np.array_equal(ra.encoder_input, rb.encoder_input), \
            "f32 payloads must survive JSON bit-exactly"


def test_traffic_repeated_image_sessions_hit_cache(vlm):
    """A seeded image workload replays deterministically and its repeated-
    image sessions produce prefix-cache hits."""
    model, params = vlm
    cfg = model.cfg
    wl = make_workload(kind="poisson", n_requests=8, rate=1.0,
                       vocab=cfg.vocab, seed=5, max_new_tokens=4,
                       shared_prefix_len=8, n_sessions=2,
                       len_mix=((1.0, 4, 10),),
                       encoder="image",
                       encoder_shape=(cfg.n_image_tokens, cfg.d_model),
                       encoder_frac=1.0, n_encoder_inputs=2)
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params, max_slots=3, max_len=64,
                          page_size=8, prefill_chunk=8)
        res = run_traffic(eng, wl)
        stats = dict(eng.stats)
        eng.close()
        outs.append((res["outputs"], res["events"]))
        assert stats["prefix_hit_tokens"] >= cfg.n_image_tokens, \
            "repeated-image sessions must share image pages"
    assert outs[0] == outs[1], "virtual-clock runs are deterministic"


def test_traffic_mixed_audio_band(whisper):
    model, params = whisper
    cfg = model.cfg
    wl = make_workload(kind="bursty", n_requests=6, rate=1.0,
                       vocab=cfg.vocab, seed=9, max_new_tokens=4,
                       shared_prefix_len=0, n_sessions=0,
                       len_mix=((1.0, 4, 10),),
                       encoder="audio",
                       encoder_shape=(cfg.n_audio_frames, cfg.d_model),
                       encoder_frac=1.0, n_encoder_inputs=2)
    eng = ServeEngine(model, params, max_slots=3, max_len=64, page_size=8,
                      prefill_chunk=16)
    res = run_traffic(eng, wl)
    eng.close()
    assert len(res["outputs"]) == 6
    assert all(len(toks) == 4 for toks in res["outputs"].values())
    assert res["report"]["n_measured"] == 6
