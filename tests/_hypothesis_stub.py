"""Minimal deterministic stand-in for ``hypothesis`` (see pyproject's
``[props]`` extra for the real thing).

Registered as ``sys.modules['hypothesis']`` by ``conftest.py`` only when the
real package is absent, so the property tests still run — each ``@given`` test
executes a fixed number of deterministically-sampled examples (always
including the all-minimal corner) instead of dying at import.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, sample, minimal):
        self.sample = sample          # rng -> value
        self.minimal = minimal        # () -> shrink-target value


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     lambda: min_value)


def floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     lambda: min_value)


def lists(elements, *, min_size=0, max_size=10):
    def sample(rng):
        size = rng.randint(min_size, max_size)
        return [elements.sample(rng) for _ in range(size)]

    return _Strategy(sample,
                     lambda: [elements.minimal() for _ in range(min_size)])


def booleans():
    return _Strategy(lambda rng: bool(rng.getrandbits(1)), lambda: False)


def tuples(*elements):
    return _Strategy(lambda rng: tuple(e.sample(rng) for e in elements),
                     lambda: tuple(e.minimal() for e in elements))


strategies = types.SimpleNamespace(integers=integers, floats=floats,
                                   lists=lists, booleans=booleans,
                                   tuples=tuples)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(f):
        f._stub_max_examples = max_examples
        return f

    return deco


def given(*strats):
    def deco(f):
        max_examples = getattr(f, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)

        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            # deterministic per-test seed, stable across runs
            rng = random.Random(zlib.crc32(f.__qualname__.encode()))
            f(*args, *(s.minimal() for s in strats), **kwargs)
            for _ in range(max_examples - 1):
                f(*args, *(s.sample(rng) for s in strats), **kwargs)

        # hide the strategy parameters from pytest's fixture resolution
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
