"""Shared-prefix KV reuse: refcounted pages, radix prefix cache, and
copy-on-write serving.

Host layer: strict free/decref accounting, radix match/insert/evict/forget,
admission that reserves only the uncached remainder, replay of fully cached
prompts, and the COW / unregister-in-place write-safety rules.

Device layer: with the prefix cache enabled, token streams are bit-identical
to cache-off — shared and disjoint prompt sets, dense and MoE configs,
under forced preemption, with seeded sampling — because sharing is pure
host-side policy over the same scatter/gather ops.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import PagePool, PagedLeafSpec, PrefixCache, ServeEngine
from repro.serve import pages as PG
from repro.serve.sampling import sample_top_p
from repro.serve.scheduler import Scheduler


def _pool(num_pages=8, page_size=4, prefix_cache=True):
    specs = {"k": PagedLeafSpec((1,), (1, 1), jnp.float32)}
    return PagePool(specs, num_pages=num_pages, page_size=page_size,
                    prefix_cache=prefix_cache)


# ---------------------------------------------------------------------------
# PagePool: strict free/decref (regression: double free must raise)
# ---------------------------------------------------------------------------

def test_double_free_raises():
    pool = _pool(prefix_cache=False)
    (a,) = pool.alloc(1)
    pool.free([a])
    with pytest.raises(ValueError, match="double free"):
        pool.free([a])
    assert pool.pages_free == pool.num_pages        # free list uncorrupted
    assert len(set(pool._free)) == len(pool._free)


def test_decref_below_zero_raises():
    pool = _pool(prefix_cache=False)
    (a,) = pool.alloc(1)
    pool.decref([a])
    with pytest.raises(ValueError, match="below zero"):
        pool.decref([a])
    with pytest.raises(ValueError, match="invalid page"):
        pool.decref([pool.num_pages + 3])


def test_free_of_shared_page_raises():
    pool = _pool()
    (a,) = pool.alloc(1)
    toks = np.arange(4, dtype=np.int32)
    pool.prefix.insert(toks, 0, a)
    pool.incref([a])                                # second holder via match
    with pytest.raises(ValueError, match="refcount 2"):
        pool.free([a])
    pool.decref([a])
    pool.free([a])                                  # exclusive again: fine
    assert a not in pool.prefix                     # free drops registration


def test_incref_of_unheld_uncached_page_raises():
    pool = _pool()
    with pytest.raises(ValueError, match="neither held nor cached"):
        pool.incref([0])


# ---------------------------------------------------------------------------
# PrefixCache: radix match / insert / park / LRU evict / forget
# ---------------------------------------------------------------------------

def test_match_full_chain_and_partial_tail():
    cache = PrefixCache(4)
    seq = np.arange(12, dtype=np.int32)
    assert cache.insert(seq, 0, 10) and cache.insert(seq, 1, 11)
    assert cache.insert(seq, 2, 12)
    # full-page walk
    assert cache.match(seq[:8]) == ([10, 11], 8)
    # partial tail: the cached chunk covers the whole remainder
    assert cache.match(seq[:10]) == ([10, 11, 12], 10)
    assert cache.match(seq[:11]) == ([10, 11, 12], 11)
    # divergence mid-page falls back to the full-page boundary
    div = np.concatenate([seq[:9], [99, 98, 97]]).astype(np.int32)
    assert cache.match(div) == ([10, 11], 8)
    # no match at all
    assert cache.match(np.asarray([7, 7, 7, 7], np.int32)) == ([], 0)


def test_insert_first_wins_and_requires_parent_chain():
    cache = PrefixCache(4)
    seq = np.arange(8, dtype=np.int32)
    assert cache.insert(seq, 0, 10)
    assert not cache.insert(seq, 0, 20)             # same chunk: keep page 10
    assert cache.match(seq[:4]) == ([10], 4)
    other = np.asarray([9, 9, 9, 9, 4, 5, 6, 7], np.int32)
    assert not cache.insert(other, 1, 21)           # parent chunk missing
    assert 21 not in cache


def test_forget_drops_descendants():
    cache = PrefixCache(4)
    seq = np.arange(12, dtype=np.int32)
    for d, p in enumerate((10, 11, 12)):
        cache.insert(seq, d, p)
    assert sorted(cache.forget(11)) == [11, 12]     # subtree goes with it
    assert 11 not in cache and 12 not in cache
    assert cache.match(seq) == ([10], 4)            # chain truncated cleanly


def test_park_on_decref_and_lru_eviction_on_alloc():
    pool = _pool(num_pages=4, page_size=4)
    pages = pool.alloc(3)
    seq = np.arange(12, dtype=np.int32)
    for d, p in enumerate(pages):
        pool.prefix.insert(seq, d, p)
    pool.decref(pages)                              # all park, none freed
    assert pool.pages_cached == 3 and pool.pages_free == 1
    assert pool.pages_in_use == 0
    # allocation beyond the free list evicts LRU leaves (deepest-first here:
    # leaf-first keeps surviving chains matchable)
    got = pool.alloc(2)
    assert got is not None and pool.evictions == 1
    assert pages[2] not in pool.prefix              # the leaf went first
    assert pool.prefix.match(seq)[1] == 8           # shorter chain survives
    # a parked page a new request matched is protected from eviction
    keep = pool.prefix.match(seq[:4])[0]
    pool.incref(keep)
    assert pool.alloc(2) is None                    # only 1 evictable left
    assert keep[0] in pool.prefix and pool.ref(keep[0]) == 1


def test_reset_storage_flushes_cache():
    pool = _pool(num_pages=4, page_size=4)
    pages = pool.alloc(2)
    seq = np.arange(8, dtype=np.int32)
    for d, p in enumerate(pages):
        pool.prefix.insert(seq, d, p)
    pool.decref(pages)
    assert pool.pages_cached == 2
    pool.reset_storage()                            # KV contents are gone
    assert pool.pages_cached == 0 and pool.pages_free == pool.num_pages
    assert pool.prefix.match(seq) == ([], 0)


# ---------------------------------------------------------------------------
# Scheduler: prefix-matched admission, replay, COW write safety
# ---------------------------------------------------------------------------

class _Req:
    def __init__(self, rid, toks):
        self.rid = rid
        self.prompt = np.asarray(toks, np.int32)
        self.output: list = []


def _retire_with_output(s, slot, output, lengths):
    """Drive a slot to LIVE with ``output`` generated and release it, as the
    engine would at retirement — full clean pages park in the cache."""
    s.slot_req[slot].output = list(output)
    s.lengths[slot] = lengths
    s.release(slot)


def _prefill_all(s):
    jobs = s.next_chunks()
    while jobs:
        for j in jobs:
            s.chunk_done(j)
        jobs = s.next_chunks()


def _admit_one(s):
    admits, rejects = s.admit()
    assert len(admits) == 1 and not rejects
    return admits[0][0]


def test_admission_matches_prefix_and_reserves_only_tail():
    pool = _pool(num_pages=8, page_size=4)
    s = Scheduler(max_slots=2, max_len=16, pool=pool, prefill_chunk=4)
    s.submit(_Req(0, range(6)))                     # 6 toks -> 2 pages
    a = _admit_one(s)
    _prefill_all(s)
    a_pages = s.table[a, :2].tolist()
    _retire_with_output(s, a, [100, 101, 102], lengths=8)   # both pages full
    assert pool.pages_cached == 2

    # B shares one full page then diverges: tail allocated, chunking starts
    # at the match boundary
    s.submit(_Req(1, [0, 1, 2, 3, 99, 98]))
    b = _admit_one(s)
    assert s.table[b, 0] == a_pages[0] and pool.ref(a_pages[0]) == 1
    assert s.table[b, 1] != a_pages[1]              # diverged: own tail page
    assert int(s.prefill_done[b]) == 4 and not s.replay[b]
    assert s.prefix_hits == 1 and s.prefix_hit_tokens == 4
    (job,) = s.next_chunks()
    assert job.start == 4 and job.pages.tolist() == [int(s.table[b, 1])]
    s.chunk_done(job)
    s.release(b)

    # C's whole prompt is cached (prefix of A's sequence): zero tail pages,
    # one replay chunk writing to the trash page
    s.submit(_Req(2, [0, 1, 2, 3, 4, 5, 100]))      # 7 toks, ends mid-page-1
    c = _admit_one(s)
    assert s.table[c, :2].tolist() == a_pages       # both shared
    assert s.replay[c] and s.prefix_hit_tokens == 4 + 7
    assert int(s.prefill_done[c]) == 4              # replay the last page
    (job,) = s.next_chunks()
    assert job.start == 4 and job.is_last and job.n_valid == 3
    assert job.pages.tolist() == [pool.trash_page]  # shared pages: read-only
    s.chunk_done(job)
    assert s.status[c] == "live" and int(s.lengths[c]) == 7


def test_cow_on_shared_write_and_unregister_in_place():
    pool = _pool(num_pages=8, page_size=4)
    s = Scheduler(max_slots=3, max_len=16, pool=pool, prefill_chunk=4)
    s.submit(_Req(0, range(6)))
    a = _admit_one(s)
    _prefill_all(s)
    a_pages = s.table[a, :2].tolist()
    _retire_with_output(s, a, [100, 101, 102], lengths=8)

    # B and C both end inside A's parked page 1 -> they share it (rc=2)
    for rid in (1, 2):
        s.submit(_Req(rid, [0, 1, 2, 3, 4, 5, 100]))
    admits, _ = s.admit()
    (b, _), (c, _) = admits
    assert s.table[b, 1] == s.table[c, 1] == a_pages[1]
    assert pool.ref(a_pages[1]) == 2
    _prefill_all(s)                                 # replay chunks only

    preempted, cow, _ = s.ensure_decode_pages()
    assert not preempted
    # B (older) hit the shared page first: copy-on-write into a fresh page;
    # C then held the original alone -> unregistered, written in place
    assert len(cow) == 1 and cow[0][0] == b and cow[0][1] == a_pages[1]
    assert s.table[b, 1] == cow[0][2] != a_pages[1]
    assert s.cow_copies == 1
    assert s.table[c, 1] == a_pages[1]
    assert a_pages[1] not in pool.prefix            # in-place write is safe
    for slot in (b, c):
        p = int(s.table[slot, int(s.lengths[slot]) // 4])
        assert pool.ref(p) == 1 and p not in pool.prefix


def test_admission_blocks_without_stealing_cached_match():
    """All-or-nothing on the uncached remainder: when the tail cannot be
    allocated the matched pages go back to parked, not leaked."""
    pool = _pool(num_pages=4, page_size=4)
    s = Scheduler(max_slots=2, max_len=16, pool=pool, prefill_chunk=4)
    s.submit(_Req(0, range(6)))
    a = _admit_one(s)
    _prefill_all(s)
    a_pages = s.table[a, :2].tolist()
    _retire_with_output(s, a, [100, 101, 102], lengths=8)   # 2 pages parked
    other = pool.alloc(2)                           # drain the free list
    # B matches one parked page but needs 2 more; only 1 is evictable —
    # B's own match is incref'd BEFORE the tail alloc, so the eviction the
    # alloc triggers can only take the other parked page, never the match
    s.submit(_Req(1, [0, 1, 2, 3, 9, 9, 9, 9, 9]))  # 9 toks -> 3 pages
    admits, _ = s.admit()
    assert admits == [] and len(s.queue) == 1
    assert a_pages[0] in pool.prefix                # match re-parked, intact
    assert pool.ref(a_pages[0]) == 0
    assert pool.pages_in_use == 2 and pool.pages_cached == 1
    assert pool.evictions == 1                      # the non-matched page
    pool.free(other)                                # capacity returns
    assert [sl for sl, _ in s.admit()[0]] == [0]
    assert s.prefix_hit_tokens == 4


# ---------------------------------------------------------------------------
# Device ops: n_prefix > 0, partial last pages, trash rows, page copies
# ---------------------------------------------------------------------------

def test_scatter_gather_roundtrip_with_prefix_axes():
    """The layered layout (L, N, page, H, D): scatter_chunk/gather_pages
    address the page axis behind n_prefix leading dims."""
    rng = np.random.default_rng(0)
    storage = jnp.zeros((2, 5, 4, 3, 2))            # L=2, N=5, ps=4, (3,2)
    chunk = jnp.asarray(rng.normal(size=(2, 8, 3, 2)), jnp.float32)
    storage = PG.scatter_chunk(storage, jnp.asarray([4, 2]), chunk,
                               page_size=4, n_prefix=1)
    tok = jnp.asarray(rng.normal(size=(2, 1, 3, 2)), jnp.float32)
    storage = PG.scatter_token(storage, jnp.asarray([2]), jnp.asarray([3]),
                               tok, n_prefix=1)
    got = PG.gather_pages(storage, jnp.asarray([[4, 2]]), n_prefix=1)
    want = np.asarray(chunk).copy()
    want[:, 4 + 3] = np.asarray(tok[:, 0])
    np.testing.assert_allclose(np.asarray(got[:, 0]), want)


def test_gather_pages_partial_last_page_and_trash_rows():
    """A slot's table rows beyond its pages point at the trash page; the
    gathered view yields the trash content there (callers mask by length)
    and the partial page's tail garbage stays confined past the valid
    length."""
    storage = jnp.zeros((3, 4, 2))                  # N=2 pages + trash, ps=4
    full = jnp.arange(8, dtype=jnp.float32).reshape(4, 2) + 1
    storage = PG.scatter_chunk(storage, jnp.asarray([0]), full, page_size=4)
    # partial write: 2 of 4 positions of page 1
    storage = PG.scatter_token(storage, jnp.asarray([1, 1]),
                               jnp.asarray([0, 1]),
                               jnp.full((2, 2), 9.0))
    got = np.asarray(PG.gather_pages(storage, jnp.asarray([[0, 1, 2]])))[0]
    np.testing.assert_allclose(got[:4], np.asarray(full))
    np.testing.assert_allclose(got[4:6], 9.0)
    np.testing.assert_allclose(got[6:8], 0.0)       # unwritten page tail
    np.testing.assert_allclose(got[8:], 0.0)        # trash row reads zeros


def test_dead_slot_writes_land_in_trash_and_stay_there():
    storage = jnp.zeros((3, 4, 2))
    live = jnp.arange(8, dtype=jnp.float32).reshape(4, 2) + 1
    storage = PG.scatter_chunk(storage, jnp.asarray([1]), live, page_size=4)
    # dead-slot token write targets the trash page (index 2)
    storage = PG.scatter_token(storage, jnp.asarray([2]), jnp.asarray([0]),
                               jnp.full((1, 2), 7.0))
    got = np.asarray(PG.gather_pages(storage, jnp.asarray([[1]])))[0]
    np.testing.assert_allclose(got, np.asarray(live))       # live page clean


def test_copy_pages_moves_whole_pages_per_leaf():
    rng = np.random.default_rng(1)
    specs = {"k": PagedLeafSpec((2,), (3,), jnp.float32),
             "v": PagedLeafSpec((), (2, 2), jnp.float32)}
    storage = {
        "k": jnp.asarray(rng.normal(size=(2, 5, 4, 3)), jnp.float32),
        "v": jnp.asarray(rng.normal(size=(5, 4, 2, 2)), jnp.float32)}
    # one source fans out to two destinations (two slots COW'd off the
    # same shared page in one tick)
    out = PG.copy_pages(storage, specs,
                        jnp.asarray([0, 0], jnp.int32),
                        jnp.asarray([2, 3], jnp.int32))
    for leaf, n in (("k", 1), ("v", 0)):
        src = np.asarray(storage[leaf])
        got = np.asarray(out[leaf])
        idx = (slice(None),) * n
        for dst in (2, 3):
            np.testing.assert_array_equal(got[idx + (dst,)],
                                          src[idx + (0,)])
        for untouched in (0, 1, 4):
            np.testing.assert_array_equal(got[idx + (untouched,)],
                                          src[idx + (untouched,)])


# ---------------------------------------------------------------------------
# Engine parity: cache-on streams == cache-off streams, bit for bit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["qwen2-7b", "qwen3-moe-235b-a22b"])
def family(request):
    cfg = smoke_config(request.param).replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _run_waves(model, params, waves, *, prefix_cache, seeds=None,
               max_new=12, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 128)
    eng = ServeEngine(model, params, paged=True, page_size=16,
                      prefill_chunk=16, prefix_cache=prefix_cache, **kw)
    sampler = None
    if seeds is not None:
        sampler = lambda k, l: sample_top_p(k, l, p=0.9,
                                            true_vocab=model.cfg.vocab)
    i = 0
    for wave in waves:
        for p in wave:
            eng.submit(p, max_new_tokens=max_new,
                       seed=None if seeds is None else seeds[i],
                       sampler=sampler)
            i += 1
        eng.run_until_drained()
    outs = {r.rid: r.output for r in eng.finished}
    assert all(r.error is None for r in eng.finished)
    eng.close()
    return outs, dict(eng.stats)


def test_cache_parity_shared_and_disjoint(family):
    """Greedy streams with the prefix cache on are bit-identical to
    cache-off: a shared 24-token prefix across waves (full-prompt replay
    hits included), plus disjoint prompts that never match."""
    model, params = family
    P = list(range(1, 25))
    waves = [[P], [P, P[:20] + [77, 78]], [list(range(50, 71))], [P]]
    on, s_on = _run_waves(model, params, waves, prefix_cache=True)
    off, s_off = _run_waves(model, params, waves, prefix_cache=False)
    assert on == off
    assert s_on["prefix_hits"] >= 3 and s_on["prefix_hit_tokens"] >= 40
    assert s_off["prefix_hits"] == 0
    # sharing lowers the footprint at identical streams
    assert s_on["pages_high_water"] <= s_off["pages_high_water"]


def test_cache_parity_with_cow_under_sampling(family):
    """Two seeded top-p requests with the SAME prompt share its pages —
    including the partially-filled last one — then diverge at decode:
    copy-on-write fires and streams still match cache-off exactly."""
    model, params = family
    P = list(range(1, 25))                          # 1 full + 1 partial page
    waves = [[P], [P, P]]
    on, s_on = _run_waves(model, params, waves, prefix_cache=True,
                          seeds=[3, 4, 5])
    off, _ = _run_waves(model, params, waves, prefix_cache=False,
                        seeds=[3, 4, 5])
    assert on == off
    assert s_on["cow_copies"] >= 1
    assert s_on["prefix_hit_tokens"] >= 2 * len(P)  # both follow-ups replay


def test_cache_parity_under_forced_preemption():
    """A pool at the single-request minimum forces preemption with sharing
    in play; recompute + re-matching parked pages keeps streams exact."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    waves = [[[5, 17, 33, 2, 9, 1, 2, 3], [100, 200, 300, 4, 5, 6, 7, 8]],
             [[5, 17, 33, 2, 9, 1, 2, 3]]]
    kw = dict(max_len=64, num_pages=4, max_new=30)
    on, s_on = _run_waves(model, params, waves, prefix_cache=True, **kw)
    off, s_off = _run_waves(model, params, waves, prefix_cache=False, **kw)
    assert on == off
    assert s_off["preemptions"] >= 1
    assert s_on["prefix_hits"] >= 1                 # wave 2 re-used wave 1


def test_seeded_streams_reproduce_across_admission_order():
    """A request's sampled stream is a function of (seed, prompt) only:
    submitting in a different order — hence different slots, tick keys and
    admission times — reproduces every stream exactly."""
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pa, pb = list(range(1, 20)), [9, 8, 7, 6, 5]
    fwd, _ = _run_waves(model, params, [[pa, pb]], prefix_cache=True,
                        seeds=[11, 22])
    rev, _ = _run_waves(model, params, [[pb, pa]], prefix_cache=True,
                        seeds=[22, 11])
    assert fwd[0] == rev[1] and fwd[1] == rev[0]
    # unseeded requests keep the legacy engine-key stream (still present)
    base, _ = _run_waves(model, params, [[pa]], prefix_cache=True,
                         seeds=None)
    assert len(base[0]) == 12


def test_stats_counters_surface_end_to_end():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    P = list(range(1, 25))
    _, stats = _run_waves(model, params, [[P], [P, P]], prefix_cache=True,
                          num_pages=8, max_len=64)
    for key in ("prefix_hits", "prefix_hit_tokens", "cow_copies",
                "evictions", "pages_high_water"):
        assert key in stats and stats[key] >= 0
    assert stats["prefix_hits"] >= 2
    assert stats["pages_high_water"] <= 8
