"""Property-based scheduler tests (hypothesis, or the deterministic stub in
``tests/_hypothesis_stub.py`` when the real package is absent).

Random admit / chunk / decode / preempt / retire / evict / verify-window
interleavings must
uphold the serving-policy invariants the engine relies on — with and
without the prefix cache:

* **page conservation under refcounts** — free, cached-unreferenced and
  held pages partition the pool exactly, and the slots' page-table
  references account for every refcount (a page shared by k slots appears
  in k tables and has refcount k) after every scheduler call, including
  across preemption and LRU eviction;
* **write safety (COW)** — after ``ensure_decode_pages`` every live slot's
  decode-write page has refcount 1 and is not registered in the prefix
  index: a page with refcount > 1 is never mutated (it is copied first),
  a registered page is unregistered before an in-place write;
* **FIFO admission** — a request is never first-admitted before an
  earlier-submitted request (the queue head blocks, it is never skipped);
* **free slots hold nothing** — a FREE slot owns zero pages.

Prompts are ``np.arange(n)``, so two requests with equal lengths share
content — random interleavings exercise prefix matching, partial-page
sharing, parking and COW organically.
"""
from collections import Counter

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.serve.pages import PagePool, PagedLeafSpec
from repro.serve.scheduler import FREE, LIVE, Scheduler

NUM_PAGES, PAGE_SIZE, SLOTS, MAX_LEN = 8, 4, 3, 32


class _Req:
    def __init__(self, rid, n):
        self.rid = rid
        self.prompt = np.arange(n, dtype=np.int32)
        self.output: list = []


def _make(prefix_cache=False):
    # a quantized-layout leaf tree: int8 value pages plus a per-row f32
    # scale leaf, exactly what Int8KVQuant produces — every conservation
    # property below must hold with the scale leaf riding along
    from repro.serve.quant import Int8KVQuant, quantize_leaf_specs
    specs = quantize_leaf_specs(
        {"k": PagedLeafSpec((1,), (1, 1), jnp.float32)}, Int8KVQuant())
    pool = PagePool(specs, num_pages=NUM_PAGES, page_size=PAGE_SIZE,
                    prefix_cache=prefix_cache)
    sched = Scheduler(max_slots=SLOTS, max_len=MAX_LEN, pool=pool,
                      prefill_chunk=PAGE_SIZE, chunks_per_tick=2)
    return pool, sched


def _check_invariants(pool, s):
    refs = [pool.ref(p) for p in range(pool.num_pages)]
    # every refcount is accounted for by a page-table reference
    cnt = Counter(int(p) for slot in range(s.max_slots)
                  for p in s.table[slot, :int(s.n_pages[slot])])
    for p in range(pool.num_pages):
        assert cnt.get(p, 0) == refs[p], \
            f"page {p}: {cnt.get(p, 0)} table refs vs refcount {refs[p]}"
    assert s.held_pages() == sum(refs)
    # free / cached-unreferenced / held partition the pool exactly
    free = {int(p) for p in pool._free}
    cached = {p for p in range(pool.num_pages)
              if pool.prefix is not None and p in pool.prefix
              and refs[p] == 0}
    held = {p for p in range(pool.num_pages) if refs[p] > 0}
    assert len(free) == pool.pages_free, "free list holds duplicates"
    assert not (free & cached) and not (free & held) and not (cached & held)
    assert free | cached | held == set(range(pool.num_pages)), \
        "pages lost: partition incomplete"
    assert pool.pages_cached == len(cached)
    assert (pool.pages_free + pool.pages_cached + pool.pages_in_use
            == pool.num_pages)
    for slot in range(s.max_slots):
        if s.status[slot] == FREE:
            assert int(s.n_pages[slot]) == 0, "FREE slot owns pages"


def _check_write_safety(pool, s):
    """The COW postcondition: every live slot may write its next token."""
    for slot in s.live_slots():
        idx = int(s.lengths[slot]) // s.page_size
        p = int(s.table[slot, idx])
        assert pool.ref(p) == 1, \
            f"slot {slot} would mutate page {p} with refcount {pool.ref(p)}"
        assert pool.prefix is None or p not in pool.prefix, \
            f"slot {slot} would mutate registered page {p}"


def _drive(actions, plens, prefix_cache=False):
    """Interpret (action, payload) int streams against a fresh scheduler,
    checking the invariants after every step.  Returns the first-admission
    rid sequence for the FIFO property."""
    pool, s = _make(prefix_cache)
    rid = iter(range(1_000_000))
    for n in plens:
        s.submit(_Req(next(rid), n))
    first_admits, seen = [], set()
    n_late = n_spec = 0
    for a in actions:
        if a == 0:                      # admit from the queue
            admits, _ = s.admit()
            for _slot, req in admits:
                if req.rid not in seen:
                    seen.add(req.rid)
                    first_admits.append(req.rid)
        elif a == 1:                    # run one tick's prefill chunks
            for job in s.next_chunks():
                s.chunk_done(job)
        elif a == 2:                    # decode tick: grow + take pages
            for slot in s.live_slots():
                if int(s.lengths[slot]) < s.max_len - 1:
                    s.lengths[slot] += 1
            try:
                s.ensure_decode_pages()
            except RuntimeError:
                pass                    # single-resident pool exhaustion
            else:
                _check_write_safety(pool, s)
        elif a == 3:                    # retire the oldest live request
            live = s.live_slots()
            if live:
                s.release(min(live, key=lambda sl: s.admitted_at[sl]))
        elif a == 4:                    # forced preemption of the youngest
            resident = [sl for sl in range(s.max_slots)
                        if s.status[sl] != FREE]
            if len(resident) > 1:
                s.preempt(max(resident, key=lambda sl: s.admitted_at[sl]))
                _check_invariants(pool, s)   # conservation across preemption
        elif a == 5:                    # late submission
            n_late += 1                 # vary lengths across late arrivals
            s.submit(_Req(next(rid), 1 + (n_late * 7) % (MAX_LEN // 2)))
        else:                           # a == 6: speculative verify window
            n_spec += 1
            want = {sl: 1 + (n_spec + sl) % 3 for sl in s.live_slots()
                    if int(s.lengths[sl]) + 4 < s.max_len - 1}
            try:
                _, _, granted = s.ensure_decode_pages(extra=want)
            except RuntimeError:
                pass                    # single-resident pool exhaustion
            else:
                _check_write_safety(pool, s)
                _check_invariants(pool, s)      # extras are accounted too
                for sl in want:         # only windowed slots emit here
                    if s.status[sl] != LIVE:
                        continue        # a victim of this very pass
                    # accept a varying prefix of the window (emitting
                    # accepted + 1 tokens), then roll the reservation back
                    extra = granted.get(sl, 0)
                    accepted = (n_spec + sl) % (extra + 1)
                    s.lengths[sl] += accepted + 1
                    s.rollback_verify_pages(sl)
                    # nothing beyond next-write page survives the rollback
                    assert int(s.n_pages[sl]) <= \
                        int(s.lengths[sl]) // s.page_size + 1
        _check_invariants(pool, s)
    return first_admits, pool, s


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8))
def test_scheduler_never_leaks_pages(actions, plens):
    _drive(actions, plens)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8))
def test_scheduler_never_leaks_pages_with_prefix_cache(actions, plens):
    """Same conservation laws with sharing in play: duplicate-length
    prompts (= identical content) match each other's pages, park on
    release, get LRU-evicted on demand, and copy-on-write on decode."""
    _drive(actions, plens, prefix_cache=True)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8))
def test_scheduler_fifo_first_admission(actions, plens):
    """First admissions happen in submission order: re-admissions of
    preempted requests may jump the queue (by design — they re-enter at the
    head), but a NEW request never overtakes an older waiting one."""
    first_admits, _, _ = _drive(actions, plens)
    assert first_admits == sorted(first_admits)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8))
def test_scheduler_fifo_first_admission_with_prefix_cache(actions, plens):
    first_admits, _, _ = _drive(actions, plens, prefix_cache=True)
    assert first_admits == sorted(first_admits)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=10, max_size=60),
       st.lists(st.integers(1, 20), min_size=2, max_size=8),
       st.booleans())
def test_scheduler_drain_returns_every_page(actions, plens, prefix_cache):
    """Releasing everything that remains resident after a random run, then
    flushing the cache, restores the full pool — nothing is retained by
    dead bookkeeping."""
    _, pool, s = _drive(actions, plens, prefix_cache)
    for slot in range(s.max_slots):
        if s.status[slot] != FREE:
            s.release(slot)
    assert s.held_pages() == 0
    assert pool.pages_free + pool.pages_cached == pool.num_pages
    pool.flush_cache()
    assert pool.pages_free == pool.num_pages and pool.pages_cached == 0
