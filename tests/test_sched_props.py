"""Property-based scheduler tests (hypothesis, or the deterministic stub in
``tests/_hypothesis_stub.py`` when the real package is absent).

Random admit / chunk / decode / preempt / retire interleavings must uphold
the serving-policy invariants the engine relies on:

* **page conservation** — ``pool.pages_free + held == num_pages`` after
  every scheduler call, with held/free page ids forming an exact partition
  of the pool (no page double-held, none lost), including across
  preemption;
* **FIFO admission** — a request is never first-admitted before an
  earlier-submitted request (the queue head blocks, it is never skipped);
* **free slots hold nothing** — a FREE slot owns zero pages.
"""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.serve.pages import PagePool, PagedLeafSpec
from repro.serve.scheduler import FREE, LIVE, Scheduler

NUM_PAGES, PAGE_SIZE, SLOTS, MAX_LEN = 8, 4, 3, 32


class _Req:
    def __init__(self, rid, n):
        self.rid = rid
        self.prompt = np.arange(n, dtype=np.int32)
        self.output: list = []


def _make():
    pool = PagePool({"k": PagedLeafSpec((1,), (1, 1), jnp.float32)},
                    num_pages=NUM_PAGES, page_size=PAGE_SIZE)
    sched = Scheduler(max_slots=SLOTS, max_len=MAX_LEN, pool=pool,
                      prefill_chunk=PAGE_SIZE, chunks_per_tick=2)
    return pool, sched


def _check_invariants(pool, s):
    held = s.held_pages()
    assert pool.pages_free + held == pool.num_pages, \
        f"leak: free={pool.pages_free} held={held} total={pool.num_pages}"
    held_ids = [int(p) for slot in range(s.max_slots)
                for p in s.table[slot, :int(s.n_pages[slot])]]
    assert sorted(held_ids + [int(p) for p in pool._free]) == \
        list(range(pool.num_pages)), "page ids no longer partition the pool"
    for slot in range(s.max_slots):
        if s.status[slot] == FREE:
            assert int(s.n_pages[slot]) == 0, "FREE slot owns pages"


def _drive(actions, plens):
    """Interpret (action, payload) int streams against a fresh scheduler,
    checking the invariants after every step.  Returns the first-admission
    rid sequence for the FIFO property."""
    pool, s = _make()
    rid = iter(range(1_000_000))
    for n in plens:
        s.submit(_Req(next(rid), n))
    first_admits, seen = [], set()
    n_late = 0
    for a in actions:
        if a == 0:                      # admit from the queue
            admits, _ = s.admit()
            for _slot, req in admits:
                if req.rid not in seen:
                    seen.add(req.rid)
                    first_admits.append(req.rid)
        elif a == 1:                    # run one tick's prefill chunks
            for job in s.next_chunks():
                s.chunk_done(job)
        elif a == 2:                    # decode tick: grow + take pages
            for slot in s.live_slots():
                if int(s.lengths[slot]) < s.max_len - 1:
                    s.lengths[slot] += 1
            try:
                s.ensure_decode_pages()
            except RuntimeError:
                pass                    # single-resident pool exhaustion
        elif a == 3:                    # retire the oldest live request
            live = s.live_slots()
            if live:
                s.release(min(live, key=lambda sl: s.admitted_at[sl]))
        elif a == 4:                    # forced preemption of the youngest
            resident = [sl for sl in range(s.max_slots)
                        if s.status[sl] != FREE]
            if len(resident) > 1:
                s.preempt(max(resident, key=lambda sl: s.admitted_at[sl]))
                _check_invariants(pool, s)   # conservation across preemption
        else:                           # a == 5: late submission
            n_late += 1                 # vary lengths across late arrivals
            s.submit(_Req(next(rid), 1 + (n_late * 7) % (MAX_LEN // 2)))
        _check_invariants(pool, s)
    return first_admits, pool, s


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8))
def test_scheduler_never_leaks_pages(actions, plens):
    _drive(actions, plens)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8))
def test_scheduler_fifo_first_admission(actions, plens):
    """First admissions happen in submission order: re-admissions of
    preempted requests may jump the queue (by design — they re-enter at the
    head), but a NEW request never overtakes an older waiting one."""
    first_admits, _, _ = _drive(actions, plens)
    assert first_admits == sorted(first_admits)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=10, max_size=60),
       st.lists(st.integers(1, 20), min_size=2, max_size=8))
def test_scheduler_drain_returns_every_page(actions, plens):
    """Releasing everything that remains resident after a random run
    restores the full pool — nothing is retained by dead bookkeeping."""
    _, pool, s = _drive(actions, plens)
    for slot in range(s.max_slots):
        if s.status[slot] != FREE:
            s.release(slot)
    assert pool.pages_free == pool.num_pages
    assert s.held_pages() == 0
