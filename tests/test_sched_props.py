"""Property-based scheduler tests (hypothesis, or the deterministic stub in
``tests/_hypothesis_stub.py`` when the real package is absent).

Random admit / chunk / decode / preempt / retire / evict / verify-window
interleavings must
uphold the serving-policy invariants the engine relies on — with and
without the prefix cache:

* **page conservation under refcounts** — free, cached-unreferenced and
  held pages partition the pool exactly, and the slots' page-table
  references account for every refcount (a page shared by k slots appears
  in k tables and has refcount k) after every scheduler call, including
  across preemption and LRU eviction;
* **write safety (COW)** — after ``ensure_decode_pages`` every live slot's
  decode-write page has refcount 1 and is not registered in the prefix
  index: a page with refcount > 1 is never mutated (it is copied first),
  a registered page is unregistered before an in-place write;
* **FIFO admission** — a request is never first-admitted before an
  earlier-submitted request (the queue head blocks, it is never skipped);
* **free slots hold nothing** — a FREE slot owns zero pages.

Prompts are ``np.arange(n)``, so two requests with equal lengths share
content — random interleavings exercise prefix matching, partial-page
sharing, parking and COW organically.
"""
from collections import Counter

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.serve.pages import KVHandoff, PagePool, PagedLeafSpec
from repro.serve.scheduler import FREE, LIVE, Scheduler

NUM_PAGES, PAGE_SIZE, SLOTS, MAX_LEN = 8, 4, 3, 32


class _Req:
    def __init__(self, rid, n):
        self.rid = rid
        self.prompt = np.arange(n, dtype=np.int32)
        self.output: list = []


def _make(prefix_cache=False):
    # a quantized-layout leaf tree: int8 value pages plus a per-row f32
    # scale leaf, exactly what Int8KVQuant produces — every conservation
    # property below must hold with the scale leaf riding along
    from repro.serve.quant import Int8KVQuant, quantize_leaf_specs
    specs = quantize_leaf_specs(
        {"k": PagedLeafSpec((1,), (1, 1), jnp.float32)}, Int8KVQuant())
    pool = PagePool(specs, num_pages=NUM_PAGES, page_size=PAGE_SIZE,
                    prefix_cache=prefix_cache)
    sched = Scheduler(max_slots=SLOTS, max_len=MAX_LEN, pool=pool,
                      prefill_chunk=PAGE_SIZE, chunks_per_tick=2)
    return pool, sched


def _check_invariants(pool, s, extra=None):
    """``extra`` (a Counter of page -> refs) accounts references held
    OUTSIDE the slot tables — in-flight KV handoff packets; such pages are
    part of the *held* partition (their refcount pins them) even though no
    slot's table points at them."""
    refs = [pool.ref(p) for p in range(pool.num_pages)]
    # every refcount is accounted for by a page-table or handoff reference
    cnt = Counter(int(p) for slot in range(s.max_slots)
                  for p in s.table[slot, :int(s.n_pages[slot])])
    extra = extra or Counter()
    cnt.update(extra)
    for p in range(pool.num_pages):
        assert cnt.get(p, 0) == refs[p], \
            f"page {p}: {cnt.get(p, 0)} table+handoff refs vs refcount {refs[p]}"
    assert s.held_pages() + sum(extra.values()) == sum(refs)
    # free / cached-unreferenced / held partition the pool exactly
    free = {int(p) for p in pool._free}
    cached = {p for p in range(pool.num_pages)
              if pool.prefix is not None and p in pool.prefix
              and refs[p] == 0}
    held = {p for p in range(pool.num_pages) if refs[p] > 0}
    assert len(free) == pool.pages_free, "free list holds duplicates"
    assert not (free & cached) and not (free & held) and not (cached & held)
    assert free | cached | held == set(range(pool.num_pages)), \
        "pages lost: partition incomplete"
    assert pool.pages_cached == len(cached)
    assert (pool.pages_free + pool.pages_cached + pool.pages_in_use
            == pool.num_pages)
    for slot in range(s.max_slots):
        if s.status[slot] == FREE:
            assert int(s.n_pages[slot]) == 0, "FREE slot owns pages"


def _check_write_safety(pool, s):
    """The COW postcondition: every live slot may write its next token."""
    for slot in s.live_slots():
        idx = int(s.lengths[slot]) // s.page_size
        p = int(s.table[slot, idx])
        assert pool.ref(p) == 1, \
            f"slot {slot} would mutate page {p} with refcount {pool.ref(p)}"
        assert pool.prefix is None or p not in pool.prefix, \
            f"slot {slot} would mutate registered page {p}"


def _drive(actions, plens, prefix_cache=False):
    """Interpret (action, payload) int streams against a fresh scheduler,
    checking the invariants after every step.  Returns the first-admission
    rid sequence for the FIFO property."""
    pool, s = _make(prefix_cache)
    rid = iter(range(1_000_000))
    for n in plens:
        s.submit(_Req(next(rid), n))
    first_admits, seen = [], set()
    n_late = n_spec = 0
    for a in actions:
        if a == 0:                      # admit from the queue
            admits, _ = s.admit()
            for _slot, req in admits:
                if req.rid not in seen:
                    seen.add(req.rid)
                    first_admits.append(req.rid)
        elif a == 1:                    # run one tick's prefill chunks
            for job in s.next_chunks():
                s.chunk_done(job)
        elif a == 2:                    # decode tick: grow + take pages
            for slot in s.live_slots():
                if int(s.lengths[slot]) < s.max_len - 1:
                    s.lengths[slot] += 1
            try:
                s.ensure_decode_pages()
            except RuntimeError:
                pass                    # single-resident pool exhaustion
            else:
                _check_write_safety(pool, s)
        elif a == 3:                    # retire the oldest live request
            live = s.live_slots()
            if live:
                s.release(min(live, key=lambda sl: s.admitted_at[sl]))
        elif a == 4:                    # forced preemption of the youngest
            resident = [sl for sl in range(s.max_slots)
                        if s.status[sl] != FREE]
            if len(resident) > 1:
                s.preempt(max(resident, key=lambda sl: s.admitted_at[sl]))
                _check_invariants(pool, s)   # conservation across preemption
        elif a == 5:                    # late submission
            n_late += 1                 # vary lengths across late arrivals
            s.submit(_Req(next(rid), 1 + (n_late * 7) % (MAX_LEN // 2)))
        else:                           # a == 6: speculative verify window
            n_spec += 1
            want = {sl: 1 + (n_spec + sl) % 3 for sl in s.live_slots()
                    if int(s.lengths[sl]) + 4 < s.max_len - 1}
            try:
                _, _, granted = s.ensure_decode_pages(extra=want)
            except RuntimeError:
                pass                    # single-resident pool exhaustion
            else:
                _check_write_safety(pool, s)
                _check_invariants(pool, s)      # extras are accounted too
                for sl in want:         # only windowed slots emit here
                    if s.status[sl] != LIVE:
                        continue        # a victim of this very pass
                    # accept a varying prefix of the window (emitting
                    # accepted + 1 tokens), then roll the reservation back
                    extra = granted.get(sl, 0)
                    accepted = (n_spec + sl) % (extra + 1)
                    s.lengths[sl] += accepted + 1
                    s.rollback_verify_pages(sl)
                    # nothing beyond next-write page survives the rollback
                    assert int(s.n_pages[sl]) <= \
                        int(s.lengths[sl]) // s.page_size + 1
        _check_invariants(pool, s)
    return first_admits, pool, s


def _drive_disagg(actions, plens, prefix_cache=False):
    """Two-pool drive modelling disaggregated prefill/decode: the prefiller
    scheduler hands completed prefills off as :class:`KVHandoff` packets
    (one in-flight reference per source page), the decoder scheduler binds
    them via ``bind_prefilled`` into freshly allocated pages.  Checks page
    conservation on BOTH pools after every step — with packet references
    counted into the prefiller's held partition — and deliberately
    double-releases every delivered packet to pin release idempotence (the
    no-double-free-under-racing-preemption property)."""
    pool_p, sp = _make(prefix_cache)            # prefiller side
    pool_d, sd = _make(prefix_cache)            # decoder side
    pending: list[KVHandoff] = []
    rid = iter(range(1_000_000))
    for n in plens:
        sp.submit(_Req(next(rid), n))
    n_late = 0

    def check():
        inflight = Counter(p for pkt in pending if not pkt.released
                           for p in pkt.pages)
        _check_invariants(pool_p, sp, extra=inflight)
        _check_invariants(pool_d, sd)

    for a in actions:
        if a == 0:                      # admit on the prefiller
            sp.admit()
        elif a == 1:                    # prefill chunks; completions hand off
            for job in sp.next_chunks():
                sp.chunk_done(job)
                if job.is_last:
                    slot = job.slot
                    total = int(sp.lengths[slot])
                    n_kv = -(-total // sp.page_size)
                    pages = [int(p) for p in sp.table[slot, :n_kv]]
                    pool_p.incref(pages)        # the in-flight references
                    sp.release(slot)
                    pending.append(KVHandoff(req=job.req, length=total,
                                             kv=None, pages=pages,
                                             pool=pool_p))
        elif a == 2:                    # deliver the oldest packet (FIFO)
            if pending:
                pkt = pending[0]
                slot = next((x for x in range(sd.max_slots)
                             if sd.status[x] == FREE), None)
                if slot is not None:
                    ps = sd.page_size
                    pages = pool_d.alloc((pkt.length + ps) // ps)
                    if pages is not None:       # else: retry a later step
                        sd.bind_prefilled(slot, pkt.req, pages, pkt.length)
                        pkt.release()
                        pkt.release()   # deliberate: must be a no-op
                        pending.pop(0)
        elif a == 3:                    # decode tick on the decoder
            for slot in sd.live_slots():
                if int(sd.lengths[slot]) < sd.max_len - 1:
                    sd.lengths[slot] += 1
            try:
                sd.ensure_decode_pages()
            except RuntimeError:
                pass                    # single-resident pool exhaustion
            else:
                _check_write_safety(pool_d, sd)
        elif a == 4:                    # retire the oldest live on the decoder
            live = sd.live_slots()
            if live:
                sd.release(min(live, key=lambda sl: sd.admitted_at[sl]))
        elif a == 5:                    # preempt on the PREFILLER: a victim
            resident = [sl for sl in range(sp.max_slots)  # may share pages
                        if sp.status[sl] != FREE]         # with in-flight
            if resident:                                  # packets
                sp.preempt(max(resident, key=lambda sl: sp.admitted_at[sl]))
        elif a == 6:                    # preempt + re-admit on the decoder
            resident = [sl for sl in range(sd.max_slots)
                        if sd.status[sl] != FREE]
            if resident:
                sd.preempt(max(resident, key=lambda sl: sd.admitted_at[sl]))
            sd.admit()                  # re-admission may match handoff-
        else:                           # registered pages (a == 7: late sub)
            n_late += 1
            sp.submit(_Req(next(rid), 1 + (n_late * 7) % (MAX_LEN // 2)))
        check()
    return pending, (pool_p, sp), (pool_d, sd)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8))
def test_scheduler_never_leaks_pages(actions, plens):
    _drive(actions, plens)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8))
def test_scheduler_never_leaks_pages_with_prefix_cache(actions, plens):
    """Same conservation laws with sharing in play: duplicate-length
    prompts (= identical content) match each other's pages, park on
    release, get LRU-evicted on demand, and copy-on-write on decode."""
    _drive(actions, plens, prefix_cache=True)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8))
def test_scheduler_fifo_first_admission(actions, plens):
    """First admissions happen in submission order: re-admissions of
    preempted requests may jump the queue (by design — they re-enter at the
    head), but a NEW request never overtakes an older waiting one."""
    first_admits, _, _ = _drive(actions, plens)
    assert first_admits == sorted(first_admits)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8))
def test_scheduler_fifo_first_admission_with_prefix_cache(actions, plens):
    first_admits, _, _ = _drive(actions, plens, prefix_cache=True)
    assert first_admits == sorted(first_admits)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=10, max_size=60),
       st.lists(st.integers(1, 20), min_size=2, max_size=8),
       st.booleans())
def test_scheduler_drain_returns_every_page(actions, plens, prefix_cache):
    """Releasing everything that remains resident after a random run, then
    flushing the cache, restores the full pool — nothing is retained by
    dead bookkeeping."""
    _, pool, s = _drive(actions, plens, prefix_cache)
    for slot in range(s.max_slots):
        if s.status[slot] != FREE:
            s.release(slot)
    assert s.held_pages() == 0
    assert pool.pages_free + pool.pages_cached == pool.num_pages
    pool.flush_cache()
    assert pool.pages_free == pool.num_pages and pool.pages_cached == 0


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=60),
       st.lists(st.integers(1, 20), min_size=1, max_size=8),
       st.booleans())
def test_handoff_page_conservation(actions, plens, prefix_cache):
    """Random prefill / handoff / deliver / decode / preempt interleavings
    conserve pages on both pools, with in-flight packet references counted
    as held on the prefiller — and double-releasing a delivered packet is
    always a no-op (checked inside the drive)."""
    _drive_disagg(actions, plens, prefix_cache)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=10, max_size=60),
       st.lists(st.integers(1, 20), min_size=2, max_size=8),
       st.booleans())
def test_handoff_drain_returns_every_page(actions, plens, prefix_cache):
    """After releasing every in-flight packet (twice — idempotence) and
    every resident slot on both sides, both pools are whole again."""
    pending, (pool_p, sp), (pool_d, sd) = _drive_disagg(
        actions, plens, prefix_cache)
    for pkt in pending:
        pkt.release()
        pkt.release()                   # idempotent by contract
    for pool, s in ((pool_p, sp), (pool_d, sd)):
        for slot in range(s.max_slots):
            if s.status[slot] != FREE:
                s.release(slot)
        assert s.held_pages() == 0
        assert pool.pages_free + pool.pages_cached == pool.num_pages
        pool.flush_cache()
        assert pool.pages_free == pool.num_pages and pool.pages_cached == 0
