"""Multi-device SPMD tests.

Each test runs in a SUBPROCESS with ``--xla_force_host_platform_device_count``
because the main pytest process must keep 1 device (smoke-test requirement).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_spmd(body: str, n_devices: int = 8, timeout: int = 420) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_parallel_solve_problem_task_farm():
    run_spmd("""
        from repro.core import parallel_solve_problem
        mesh = jax.make_mesh((8,), ("data",))
        m = 12  # 144 tasks over 8 shards (not divisible: pad+mask path)
        def initialize():
            a = jnp.linspace(-1, 1, m); b = jnp.linspace(-1, 1, m)
            aa, bb = jnp.meshgrid(a, b, indexing="ij")
            return {"a": aa.ravel(), "b": bb.ravel()}
        x = jnp.linspace(0, 10.0, 16)
        def func(t):
            return t["a"] * x**2 + t["b"] * x + 5
        got = parallel_solve_problem(initialize, func, lambda o: o, mesh)
        tasks = initialize()
        want = jax.vmap(func)(tasks)
        np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5, atol=1e-5)
        print("task farm OK")
    """)


def test_redistribute_work_across_shards():
    run_spmd("""
        from repro.core.comm import Comm
        from repro.core.load_balance import redistribute_work
        mesh = jax.make_mesh((8,), ("data",))
        cap = 16
        def per_shard(x):
            comm = Comm("data")
            rank = comm.rank()
            count = jnp.where(rank == 0, 9, jnp.where(rank == 1, 5, 0))
            data = jnp.where((jnp.arange(cap) < count)[:, None],
                             x + 100.0 * rank, 0.0)
            new_data, new_count = redistribute_work(data, count, comm)
            return new_data, new_count.reshape(1)
        x = jnp.tile(jnp.arange(cap, dtype=jnp.float32)[:, None], (8, 1))
        from repro.core.comm import shard_map
        f = jax.jit(shard_map(per_shard, mesh=mesh,
                    in_specs=P("data", None),
                    out_specs=(P("data", None), P("data")), check_vma=False))
        data, counts = f(x)
        counts = np.asarray(counts)
        assert counts.sum() == 14, counts           # conservation
        assert counts.max() - counts.min() <= 1     # balance
        # global rank-major order preserved: first shard's items come first
        flat = np.asarray(data).reshape(8, cap, 1)
        live = [flat[r, :counts[r], 0] for r in range(8)]
        merged = np.concatenate(live)
        want = np.concatenate([np.arange(9), 100.0 + np.arange(5)])
        np.testing.assert_allclose(merged, want)
        print("redistribute OK")
    """)


def test_dmc_parallel_with_load_balancing():
    run_spmd("""
        from repro.apps import dmc
        mesh = jax.make_mesh((8,), ("data",))
        out = dmc.run_parallel(mesh, n_walkers=512, timesteps=400, tau=0.02)
        e0 = float(out["e0_estimate"])
        assert abs(e0 - 1.5) < 0.2, e0
        assert int(out["rebalances"]) > 0           # LB actually fired
        lc = np.asarray(out["local_counts"])[-1]
        assert lc.max() - lc.min() <= max(3, 0.2 * lc.mean()), lc
        print("parallel DMC OK", e0)
    """)


def test_boussinesq_schwarz_matches_serial():
    run_spmd("""
        from repro.apps import boussinesq as bq
        p = bq.BoussinesqParams(nx=48, ny=48, dt=0.02, eps=0.3, alpha=0.05)
        eta_s, phi_s, hist_s = bq.run_serial(p, steps=40)
        mesh = jax.make_mesh((8,), ("data",))
        eta_p, phi_p, hist_p = bq.run_parallel(mesh, p, steps=40)
        err = np.abs(np.asarray(eta_s) - np.asarray(eta_p)).max()
        assert err < 1e-5, err
        print("schwarz OK", err)
    """)


def test_sharded_train_step_matches_single_device():
    run_spmd("""
        from repro.configs import smoke_config
        from repro.models.api import build_model
        from repro.optim import AdamWConfig
        from repro.optim.adamw import adamw_init
        from repro.train import make_train_step
        from repro.train.state import state_shardings
        from repro.mesh.axes import rules_for_mesh
        from repro.data import SyntheticTask

        cfg = smoke_config("qwen3-1.7b").replace(remat="none", tp=2)
        model = build_model(cfg)
        task = SyntheticTask(cfg, batch=8, seq_len=32)
        batch = task.batch_at(0)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamWConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10)

        # single device
        s1 = {"params": params, "opt": adamw_init(params, opt)}
        step1 = make_train_step(model, opt, donate=False)
        o1, m1 = step1(s1, batch)

        # 4x2 mesh
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = rules_for_mesh(mesh)
        sh = state_shardings(model, mesh, rules)
        s2 = jax.device_put({"params": params, "opt": adamw_init(params, opt)}, sh)
        step2 = make_train_step(model, opt, mesh, rules, donate=False)
        o2, m2 = step2(s2, batch)

        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
        w1 = np.asarray(jax.tree_util.tree_leaves(o1["params"])[0])
        w2 = np.asarray(jax.device_get(jax.tree_util.tree_leaves(o2["params"])[0]))
        np.testing.assert_allclose(w1, w2, rtol=2e-4, atol=2e-5)
        print("sharded step OK", float(m1["loss"]), float(m2["loss"]))
    """)


def test_compressed_pod_dp_matches_uncompressed():
    run_spmd("""
        from repro.configs import smoke_config
        from repro.models.api import build_model
        from repro.optim import AdamWConfig
        from repro.train.pod_dp import make_pod_dp_step
        from repro.mesh.axes import rules_for_mesh
        from repro.data import SyntheticTask

        cfg = smoke_config("qwen3-1.7b").replace(remat="none", tp=2)
        model = build_model(cfg)
        task = SyntheticTask(cfg, batch=8, seq_len=32)
        opt = AdamWConfig(peak_lr=3e-3, warmup_steps=0, decay_steps=20)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = rules_for_mesh(mesh)

        def run(compress):
            step = make_pod_dp_step(model, opt, mesh, rules, compress=compress)
            state = step.init_state(jax.random.PRNGKey(0))
            losses = []
            for i in range(8):
                state, out = step(state, task.batch_at(i))
                losses.append(out["loss"])
            return losses, out, state

        lc, outc, sc = run(True)
        lu, outu, su = run(False)
        assert lc[-1] < lc[0], lc                       # training works
        # int8+EF tracks uncompressed DP closely
        assert abs(lc[-1] - lu[-1]) < 0.05, (lc[-1], lu[-1])
        # wire savings: ~4x less than fp32
        assert outc["wire_bytes"] < 0.3 * outc["fp32_bytes"]
        # pods stay in lockstep (same params on both pods)
        import numpy as np
        w0 = np.asarray(jax.device_get(
            jax.tree_util.tree_leaves(sc["pods"][0]["params"])[0]))
        w1 = np.asarray(jax.device_get(
            jax.tree_util.tree_leaves(sc["pods"][1]["params"])[0]))
        np.testing.assert_allclose(w0, w1, rtol=1e-6, atol=1e-7)
        print("pod-DP OK", lc[0], lc[-1], lu[-1])
    """)


def test_elastic_reshard_resume_across_mesh_sizes():
    run_spmd("""
        import tempfile
        from repro.configs import smoke_config
        from repro.models.api import build_model
        from repro.optim import AdamWConfig
        from repro.optim.adamw import adamw_init
        from repro.train import (make_train_step, save_checkpoint,
                                 restore_checkpoint)
        from repro.train.state import state_shardings
        from repro.mesh.axes import rules_for_mesh
        from repro.data import SyntheticTask

        cfg = smoke_config("qwen3-1.7b").replace(remat="none", tp=2)
        model = build_model(cfg)
        task = SyntheticTask(cfg, batch=8, seq_len=32)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamWConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10)

        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        rules1 = rules_for_mesh(mesh1)
        sh1 = state_shardings(model, mesh1, rules1)
        state = jax.device_put({"params": params,
                                "opt": adamw_init(params, opt)}, sh1)
        step1 = make_train_step(model, opt, mesh1, rules1, donate=False)
        state, _ = step1(state, task.batch_at(0))

        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, state)
            # "cluster shrank": resume on 2x2
            mesh2 = jax.make_mesh((2, 2), ("data", "model"))
            rules2 = rules_for_mesh(mesh2)
            sh2 = state_shardings(model, mesh2, rules2)
            state2, step_no = restore_checkpoint(d, state, shardings=sh2)
            assert step_no == 1
            stepf = make_train_step(model, opt, mesh2, rules2, donate=False)
            state2, out = stepf(state2, task.batch_at(1))
            assert np.isfinite(float(out["loss"]))
        print("elastic reshard OK")
    """)


def test_moe_ep_all_to_all_matches_serial():
    run_spmd("""
        from repro.configs import smoke_config
        from repro.models.api import build_model
        from repro.mesh.axes import rules_for_mesh
        from repro.data import SyntheticTask

        cfg = smoke_config("qwen3-moe-235b-a22b").replace(
            remat="none", tp=2, capacity_factor=8.0)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        task = SyntheticTask(cfg, batch=8, seq_len=32)
        batch = task.batch_at(0)
        l1, m1 = jax.jit(lambda p, b: model.loss(p, b, None))(params, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = rules_for_mesh(mesh)
        from repro.models.module import sharding_tree
        psh = sharding_tree(model.param_defs(), mesh, rules)
        params2 = jax.device_put(params, psh)
        l2, m2 = jax.jit(lambda p, b: model.loss(p, b, rules))(params2, batch)
        assert abs(float(l1) - float(l2)) < 2e-3, (float(l1), float(l2))
        print("moe EP OK", float(l1), float(l2))
    """)
