"""Speculative decode tests: drafters, acceptance rules, and engine-level
greedy parity (spec-on streams must be bit-identical to spec-off).

The engine-level tests mirror the serving parity suite: dense + MoE smoke
models, prefix cache on and off, under forced preemption, with seeded
requests — speculation may only change *latency* (ticks), never a token.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine
from repro.serve.pages import PagedLeafSpec, scatter_window
from repro.serve.sampling import spec_rejection_sample, spec_verify_greedy
from repro.serve.spec import (NgramDrafter, TruncatedSelfDrafter,
                              make_drafter)


@pytest.fixture(scope="module")
def dense():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe():
    cfg = smoke_config("qwen3-moe-235b-a22b").replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(1))


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------

def test_ngram_drafter_proposes_recent_continuation():
    d = NgramDrafter(max_n=3)
    # tail [7, 8] last occurred at positions 1..2, followed by 9, 4
    toks = np.asarray([1, 7, 8, 9, 4, 7, 8], np.int32)
    assert d.propose(toks, 4).tolist() == [9, 4, 7, 8][:4]
    # longest n-gram wins: tail [8, 9] matches over tail [9]
    toks = np.asarray([8, 9, 1, 9, 2, 8, 9], np.int32)
    assert d.propose(toks, 2).tolist() == [1, 9]


def test_ngram_drafter_takes_most_recent_match():
    d = NgramDrafter(max_n=2)
    toks = np.asarray([5, 1, 5, 2, 5], np.int32)      # "5" seen twice before
    assert d.propose(toks, 1).tolist() == [2]          # latest continuation


def test_ngram_drafter_fills_window_inside_loops():
    """A generation loop of period p: the very last match could only
    propose p tokens, so the drafter backs up to the most recent match
    with a FULL k-token continuation."""
    d = NgramDrafter()
    assert d.propose(np.asarray([7, 7, 7, 7], np.int32), 3).tolist() == [7] * 3
    toks = np.asarray([1, 2, 1, 2, 1, 2, 1, 2], np.int32)
    assert d.propose(toks, 4).tolist() == [1, 2, 1, 2]
    # no full-window match anywhere: the longest partial continuation wins
    toks = np.asarray([5, 6, 7, 8, 1, 5, 6, 7], np.int32)
    assert d.propose(toks, 6).tolist() == [8, 1, 5, 6, 7]   # partial, 5 of 6


def test_ngram_drafter_no_match_is_empty():
    d = NgramDrafter()
    assert d.propose(np.asarray([1, 2, 3, 4], np.int32), 4).size == 0
    assert d.propose(np.asarray([1], np.int32), 4).size == 0
    assert d.propose(np.asarray([7, 7, 7], np.int32), 0).size == 0


def test_ngram_drafter_respects_k():
    d = NgramDrafter(max_n=1)
    toks = np.asarray([3, 1, 2, 4, 5, 6, 3], np.int32)
    assert d.propose(toks, 2).tolist() == [1, 2]


def test_truncated_drafter_greedy_and_deterministic(dense):
    model, params = dense
    d = TruncatedSelfDrafter(model, params, layers=1)
    assert d.layers == 1
    toks = np.asarray([5, 17, 33, 2], np.int32)
    a = d.propose(toks, 3)
    b = d.propose(toks, 3)
    assert a.tolist() == b.tolist() and len(a) == 3
    assert all(0 <= t < model.cfg.vocab for t in a)


def test_truncated_drafter_clamps_layers(dense):
    model, params = dense
    d = TruncatedSelfDrafter(model, params, layers=99)
    assert d.layers == model.cfg.n_layers


def test_truncated_drafter_rejects_recurrent_family():
    model = build_model(smoke_config("rwkv6-3b"))
    with pytest.raises(ValueError, match="ngram"):
        TruncatedSelfDrafter(model, {}, layers=1)


def test_make_drafter_parses_names(dense):
    model, params = dense
    assert isinstance(make_drafter("ngram"), NgramDrafter)
    assert make_drafter("ngram-5").max_n == 5
    assert make_drafter("self-1", model, params).layers == 1
    with pytest.raises(ValueError, match="model="):
        make_drafter("self-1")
    with pytest.raises(ValueError, match="unknown drafter"):
        make_drafter("medusa")


# ---------------------------------------------------------------------------
# acceptance rules
# ---------------------------------------------------------------------------

def test_spec_verify_greedy_accepts_matching_prefix():
    rows = np.asarray([4, 5, 6, 7])             # target argmax per position
    assert spec_verify_greedy(rows, [4, 5, 6]) == (3, [4, 5, 6, 7])  # +bonus
    assert spec_verify_greedy(rows, [4, 9, 6]) == (1, [4, 5])  # correction
    assert spec_verify_greedy(rows, [9]) == (0, [4])
    assert spec_verify_greedy(rows, []) == (0, [4])            # plain decode


def test_spec_rejection_zero_temperature_is_greedy():
    logits = np.zeros((3, 8), np.float32)
    logits[0, 2] = 9.0
    logits[1, 5] = 9.0
    logits[2, 1] = 9.0
    keys = [jax.random.PRNGKey(i) for i in range(3)]
    accepted, emitted = spec_rejection_sample(keys, logits, [2, 5],
                                              temperature=0.0)
    assert (accepted, emitted) == (2, [2, 5, 1])


def test_spec_rejection_preserves_target_distribution():
    """The emitted first token's marginal equals softmax(logits) whatever
    the drafter proposed — the standard speculative-sampling theorem, here
    checked empirically for an adversarially bad and a good draft."""
    logits = np.log(np.asarray([[0.6, 0.3, 0.1]], np.float32))
    for draft_tok in (2, 0):                   # low-prob and high-prob draft
        draws = []
        for i in range(400):
            keys = [jax.random.PRNGKey(1000 * draft_tok + i),
                    jax.random.PRNGKey(987654 + i)]
            _, emitted = spec_rejection_sample(keys, np.tile(logits, (2, 1)),
                                               [draft_tok])
            draws.append(emitted[0])
        freq = np.bincount(np.asarray(draws), minlength=3) / len(draws)
        assert abs(freq[0] - 0.6) < 0.08, (draft_tok, freq)
        assert abs(freq[1] - 0.3) < 0.08, (draft_tok, freq)


def test_spec_rejection_respects_padded_vocab():
    logits = np.zeros((2, 8), np.float32)
    logits[:, 7] = 30.0                         # huge mass in the padded tail
    for i in range(20):
        keys = [jax.random.PRNGKey(i), jax.random.PRNGKey(10_000 + i)]
        _, emitted = spec_rejection_sample(keys, logits, [7], true_vocab=6)
        assert all(t < 6 for t in emitted)


# ---------------------------------------------------------------------------
# device op
# ---------------------------------------------------------------------------

def test_scatter_window_writes_per_slot_windows():
    storage = jnp.zeros((4, 2, 3))              # (N=4 pages, ps=2, D=3)
    pages = jnp.asarray([[0, 0], [2, 3]], jnp.int32)
    offs = jnp.asarray([[0, 1], [1, 0]], jnp.int32)
    vals = jnp.arange(12, dtype=jnp.float32).reshape(2, 2, 3)
    out = scatter_window(storage, pages, offs, vals)
    np.testing.assert_array_equal(out[0, 0], vals[0, 0])
    np.testing.assert_array_equal(out[0, 1], vals[0, 1])
    np.testing.assert_array_equal(out[2, 1], vals[1, 0])
    np.testing.assert_array_equal(out[3, 0], vals[1, 1])
    assert float(jnp.abs(out[1]).sum()) == 0.0


# ---------------------------------------------------------------------------
# engine parity: spec-on == spec-off, token for token
# ---------------------------------------------------------------------------

_PROMPTS = ([5, 17, 33, 5, 17, 33, 5, 17], [7] * 11,
            [1, 2, 3, 4, 1, 2, 3, 4, 1, 2], [9, 9, 8, 8, 9, 9, 8, 8])


def _streams(model, params, *, n_req=4, max_new=12, seeds=(), **kw):
    eng = ServeEngine(model, params, max_slots=3, max_len=128,
                      prefill_chunk=16, **kw)
    for i, p in enumerate(_PROMPTS[:n_req]):
        eng.submit(p, max_new_tokens=max_new,
                   seed=i if i in seeds else None)
    done = eng.run_until_drained()
    eng.close()
    assert all(r.error is None for r in done)
    return {r.rid: r.output for r in done}, eng


@pytest.mark.parametrize("family", ["dense", "moe"])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_spec_greedy_parity(dense, moe, family, prefix_cache):
    """ngram spec-on greedy streams == spec-off, dense + MoE, prefix cache
    on and off; the acceptance counters are consistent."""
    model, params = dense if family == "dense" else moe
    want, _ = _streams(model, params, prefix_cache=prefix_cache)
    got, eng = _streams(model, params, prefix_cache=prefix_cache,
                        spec_decode="ngram")
    assert got == want
    s = eng.stats
    assert s["draft_proposed"] >= s["draft_accepted"] >= 0
    assert s["draft_proposed"] > 0          # repetitive prompts do draft
    assert s["acceptance_rate"] == s["draft_accepted"] / s["draft_proposed"]


def test_spec_self_drafter_parity(dense):
    """The truncated-layer self-drafter preserves greedy streams too (its
    proposals come from a 1-layer prefix of the target)."""
    model, params = dense
    want, _ = _streams(model, params)
    drafter = TruncatedSelfDrafter(model, params, layers=1)
    got, eng = _streams(model, params, spec_decode=drafter)
    assert got == want
    assert eng.stats["draft_proposed"] > 0


def test_spec_parity_under_forced_preemption(dense):
    """A pool at the single-request minimum forces preemption; recompute
    re-admission plus verify rollback keep streams identical and the pool
    conserved."""
    model, params = dense

    def tight(**kw):
        eng = ServeEngine(model, params, max_slots=2, max_len=64, paged=True,
                          page_size=16, num_pages=4, prefill_chunk=16, **kw)
        eng.submit([5, 17, 33, 2, 9, 1, 2, 3], max_new_tokens=30)
        eng.submit([100, 200, 300, 4, 5, 6, 7, 8], max_new_tokens=30)
        done = eng.run_until_drained()
        assert all(r.error is None for r in done)
        streams = {r.rid: r.output for r in done}
        eng.close()
        return streams, eng

    want, eng_off = tight()
    assert eng_off.stats["preemptions"] >= 1
    got, eng_on = tight(spec_decode="ngram")
    assert got == want
    # verify rollback leaked nothing: the full pool is accounted for
    pool = eng_on.pool
    assert pool.pages_free + pool.pages_cached == pool.num_pages
    assert eng_on.sched.held_pages() == 0


def test_spec_seeded_requests_keep_streams(dense):
    """Seeded requests (default greedy sampler) reproduce bit-identically
    with speculation on."""
    model, params = dense
    want, _ = _streams(model, params, seeds=(0, 2))
    got, _ = _streams(model, params, seeds=(0, 2), spec_decode="ngram")
    assert got == want


def test_spec_custom_request_sampler_is_isolated(dense):
    """A request carrying its own (black-box) sampler is never drafted for
    — it decodes per-token inside the verify batch — while other slots
    keep speculating; a key-independent sampler's stream is unchanged."""
    model, params = dense
    const = lambda key, logits: jnp.asarray(7, jnp.int32)

    def run(spec):
        eng = ServeEngine(model, params, max_slots=2, max_len=128,
                          prefill_chunk=16, spec_decode=spec)
        eng.submit([5, 17, 33, 5, 17, 33], max_new_tokens=8, sampler=const)
        eng.submit([1, 2, 3, 4, 1, 2, 3, 4, 1, 2], max_new_tokens=8)
        done = eng.run_until_drained()
        eng.close()
        assert all(r.error is None for r in done)
        return {r.rid: r.output for r in done}

    want = run(None)
    got = run("ngram")
    assert want[0] == [7] * 8 and got == want


def test_spec_rejection_sampled_streams_reproduce(dense):
    """spec_temperature > 0: rejection sampling draws valid tokens and
    seeded streams reproduce run to run (per-stream-index keys)."""
    model, params = dense

    def run():
        eng = ServeEngine(model, params, max_slots=2, max_len=128,
                          prefill_chunk=16, spec_decode="ngram",
                          spec_temperature=1.0)
        eng.submit([5, 17, 33, 5, 17, 33, 5, 17], max_new_tokens=10, seed=3)
        eng.submit([7] * 9, max_new_tokens=10, seed=4)
        done = eng.run_until_drained()
        eng.close()
        assert all(r.error is None for r in done)
        return {r.rid: r.output for r in done}

    a, b = run(), run()
    assert a == b
    assert all(0 <= t < model.cfg.vocab for out in a.values() for t in out)
    assert all(len(out) == 10 for out in a.values())


class _NoDrafts:
    def propose(self, tokens, k):
        return np.zeros(0, np.int32)


def test_spec_temperature_samples_on_draftless_ticks(dense):
    """spec_temperature > 0 must temperature-sample EVERY tick — a tick
    whose drafter proposes nothing may not silently fall back to the
    greedy sampler, or the stream would mix two distributions."""
    model, params = dense

    def run(spec_decode, temp):
        eng = ServeEngine(model, params, max_slots=2, max_len=128,
                          prefill_chunk=16, spec_decode=spec_decode,
                          spec_temperature=temp)
        eng.submit([5, 17, 33, 2, 9], max_new_tokens=12, seed=11)
        done = eng.run_until_drained()
        eng.close()
        assert done[0].error is None
        return done[0].output

    sampled = run(_NoDrafts(), 1.0)
    assert sampled == run(_NoDrafts(), 1.0)        # seeded: reproduces
    greedy_stream = run(None, 0.0)
    assert sampled != greedy_stream                # actually sampling


@pytest.mark.parametrize("family", ["dense", "moe"])
@pytest.mark.parametrize("prefix_cache", [False, True])
def test_spec_pallas_greedy_parity(dense, moe, family, prefix_cache):
    """spec_decode + use_pallas_attention (once refused, now served by the
    fused multi-query kernel for both verify windows and decode) keeps
    greedy streams bit-identical to the spec-off/Pallas-off engine — dense
    + MoE, prefix cache on and off — and speculation actually ran."""
    model, params = dense if family == "dense" else moe
    want, _ = _streams(model, params, prefix_cache=prefix_cache)
    got, eng = _streams(model, params, prefix_cache=prefix_cache,
                        spec_decode="ngram", use_pallas_attention=True)
    assert got == want
    assert eng.stats["draft_proposed"] > 0


def test_spec_pallas_parity_under_forced_preemption(dense):
    """Kernel-backed verify under preemption: rollback + recompute keep
    streams identical to the plain engine and the pool stays conserved."""
    model, params = dense

    def tight(**kw):
        eng = ServeEngine(model, params, max_slots=2, max_len=64, paged=True,
                          page_size=16, num_pages=4, prefill_chunk=16, **kw)
        eng.submit([5, 17, 33, 2, 9, 1, 2, 3], max_new_tokens=30)
        eng.submit([100, 200, 300, 4, 5, 6, 7, 8], max_new_tokens=30)
        done = eng.run_until_drained()
        assert all(r.error is None for r in done)
        streams = {r.rid: r.output for r in done}
        eng.close()
        return streams, eng

    want, eng_off = tight()
    assert eng_off.stats["preemptions"] >= 1
    got, eng_on = tight(spec_decode="ngram", use_pallas_attention=True)
    assert got == want
    assert eng_on.stats["preemptions"] >= 1
    pool = eng_on.pool
    assert pool.pages_free + pool.pages_cached == pool.num_pages
    assert eng_on.sched.held_pages() == 0


def test_spec_windows_never_preempt_for_extras(dense):
    """A pool sized so that plain decode just fits must behave identically
    with speculation on: verify windows are best-effort and may not evict
    the request plain decode would have kept resident."""
    model, params = dense

    def run(spec):
        eng = ServeEngine(model, params, max_slots=2, max_len=32, paged=True,
                          page_size=4, num_pages=16, prefill_chunk=8,
                          prefix_cache=False, spec_decode=spec)
        eng.submit([5, 17, 33, 5, 17, 33, 5], max_new_tokens=20)
        eng.submit([7, 7, 7, 7, 7, 7, 7], max_new_tokens=20)
        done = eng.run_until_drained()
        eng.close()
        assert all(r.error is None for r in done)
        return {r.rid: r.output for r in done}, eng.stats["preemptions"]

    want, pre_off = run(None)
    got, pre_on = run("ngram")
    assert got == want
    assert pre_on == pre_off == 0       # speculation evicted nobody


def test_spec_requires_default_sampler(dense):
    model, params = dense
    with pytest.raises(ValueError, match="default greedy"):
        ServeEngine(model, params, max_slots=2, max_len=64,
                    spec_decode="ngram",
                    sampler=lambda k, lg: jnp.zeros((2,), jnp.int32))
    # rejection sampling at a temperature is the sanctioned sampled mode
    eng = ServeEngine(model, params, max_slots=2, max_len=64,
                      spec_decode="ngram", spec_temperature=0.7)
    assert eng.drafter is not None
    eng.close()


def test_spec_falls_back_on_dense_state_families():
    """Recurrent families have no paged verify: the engine silently keeps
    per-token decode (the drafter is never consulted)."""
    cfg = smoke_config("rwkv6-3b").replace(remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_slots=2, max_len=64,
                      spec_decode="ngram")
    assert eng.drafter is None
    eng.submit([5, 5, 5, 5, 5], max_new_tokens=4)
    done = eng.run_until_drained()
    eng.close()
    assert len(done) == 1 and done[0].error is None
    assert eng.stats["draft_proposed"] == 0


def test_spec_decode_emits_multiple_tokens_per_tick(dense):
    """The whole point: with an agreeable drafter (the target's own greedy
    continuation), one verify tick emits several tokens — fewer ticks than
    tokens."""
    model, params = dense
    drafter = TruncatedSelfDrafter(model, params, layers=model.cfg.n_layers)
    got, eng = _streams(model, params, n_req=1, max_new=16,
                        spec_decode=drafter)
    want, eng_off = _streams(model, params, n_req=1, max_new=16)
    assert got == want
    assert eng.stats["acceptance_rate"] == 1.0      # full-depth self-draft
    assert eng.stats["ticks"] < eng_off.stats["ticks"]
