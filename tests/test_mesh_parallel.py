"""Ring attention + pipeline parallelism vs single-device oracles
(subprocess SPMD, like test_distributed)."""
from tests.test_distributed import run_spmd


def test_ring_attention_matches_full_attention():
    run_spmd("""
        from repro.core.comm import Comm
        from repro.mesh.ring import ring_attention
        from repro.kernels import ref

        rng = np.random.default_rng(0)
        B, S, Hq, Hkv, D = 2, 256, 4, 2, 32
        q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
        want = ref.flash_attention(q, k, v, causal=True)

        mesh = jax.make_mesh((8,), ("sp",))
        def body(q, k, v):
            return ring_attention(q, k, v, Comm("sp"), causal=True)
        from repro.core.comm import shard_map
        got = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P(None, "sp", None, None),) * 3,
            out_specs=P(None, "sp", None, None), check_vma=False))(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        print("ring attention OK")
    """)


def test_pipeline_matches_sequential():
    run_spmd("""
        from repro.mesh.pipeline import (pipeline_apply, reference_apply,
                                         bubble_fraction)
        rng = np.random.default_rng(0)
        n_stages, n_micro, mb, d = 4, 6, 2, 16
        params = {"w": jnp.asarray(rng.normal(size=(n_stages, d, d)) * 0.2,
                                   jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(n_stages, d)) * 0.1,
                                   jnp.float32)}
        x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)

        def stage_fn(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        mesh = jax.make_mesh((4,), ("pod",))
        got = pipeline_apply(stage_fn, params, x, mesh, axis="pod")
        want = reference_apply(stage_fn, params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
        print("pipeline OK")
    """, n_devices=4)
