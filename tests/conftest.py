"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device (the dry-run sets its own 512-device flag in its own process;
multi-device tests spawn subprocesses)."""
import importlib.util
import pathlib
import sys

# Property tests use hypothesis when available (``pip install -e .[props]``
# — CI's props-real-hypothesis job); otherwise fall back to the
# deterministic stub so collection never dies on the missing import.
if importlib.util.find_spec("hypothesis") is None:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).parent / "_hypothesis_stub.py")
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
