"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device (the dry-run sets its own 512-device flag in its own process;
multi-device tests spawn subprocesses)."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
