"""Serving tests: continuous-batching engine correctness vs aligned decode,
scheduler edge cases, and the sampling heads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine
from repro.serve.engine import _bucket
from repro.serve.sampling import (greedy, sample_temperature, sample_top_k,
                                  sample_top_p)


@pytest.fixture(scope="module")
def dense():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _reference_generate(model, params, prompt, n_new, max_len=128):
    cfg = model.cfg
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    cache, hidden = jax.jit(lambda p, b: model.prefill(p, b, None, max_len))(
        params, {"tokens": toks})
    logits = model.lm_head(params, hidden[:, -1:], None)
    out = [int(greedy(logits, true_vocab=cfg.vocab)[0, -1])]
    pos = toks.shape[1]
    dec = jax.jit(lambda p, s, t, q: model.decode_step(p, s, t, q, None))
    for _ in range(n_new - 1):
        cache, logits = dec(params, cache,
                            jnp.asarray([[out[-1]]], jnp.int32),
                            jnp.asarray(pos, jnp.int32))
        out.append(int(greedy(logits, true_vocab=cfg.vocab)[0, -1]))
        pos += 1
    return out


@pytest.mark.parametrize("paged", [False, True])
def test_engine_matches_aligned_reference(dense, paged):
    """Ragged continuous batching == one-request-at-a-time decoding, on both
    the dense-cache path and the paged (pool + chunked prefill) path."""
    model, params = dense
    prompts = [[5, 17, 33, 2, 9], [100, 200, 300], [7] * 11,
               [42, 41, 40, 39, 38, 37, 36]]
    want = [_reference_generate(model, params, p, 8) for p in prompts]
    eng = ServeEngine(model, params, max_slots=3, max_len=128, paged=paged,
                      prefill_chunk=16)
    for p in prompts:
        eng.submit(p, max_new_tokens=8)
    done = eng.run_until_drained()
    got = {r.rid: r.output for r in done}
    for i, w in enumerate(want):
        assert got[i] == w, (i, got[i], w)
    # slots were reused: 4 requests through 3 slots
    assert eng.stats["prefills"] == 4


def test_engine_eos_stops_early(dense):
    model, params = dense
    ref = _reference_generate(model, params, [5, 6, 7], 16)
    eos = ref[3]
    eng = ServeEngine(model, params, max_slots=2, max_len=128)
    eng.submit([5, 6, 7], max_new_tokens=16, eos_id=eos)
    done = eng.run_until_drained()
    assert done[0].output[-1] == eos
    assert len(done[0].output) == 4


def test_engine_eos_on_first_token(dense):
    """A request whose very first sampled token is EOS retires right after
    prefill — no decode tick is spent on it."""
    model, params = dense
    ref = _reference_generate(model, params, [5, 6, 7], 2)
    eng = ServeEngine(model, params, max_slots=2, max_len=128)
    eng.submit([5, 6, 7], max_new_tokens=16, eos_id=ref[0])
    done = eng.run_until_drained()
    assert done[0].output == [ref[0]]
    assert eng.stats["ticks"] == 0


def test_engine_latency_stats(dense):
    model, params = dense
    eng = ServeEngine(model, params, max_slots=2, max_len=128)
    eng.submit([1, 2, 3], max_new_tokens=4)
    done = eng.run_until_drained()
    r = done[0]
    assert r.first_token_at >= r.submitted_at
    assert r.done_at >= r.first_token_at


def test_engine_rejects_oversized_prompt_at_submit(dense):
    model, params = dense
    eng = ServeEngine(model, params, max_slots=2, max_len=128)
    with pytest.raises(ValueError, match="prompt length 200"):
        eng.submit(list(range(200)), max_new_tokens=4)
    assert eng.queue == []                 # nothing was enqueued


def test_engine_slot_exhaustion_queues_requests(dense):
    """More requests than slots: the overflow waits in the queue and every
    request still completes once capacity frees up."""
    model, params = dense
    eng = ServeEngine(model, params, max_slots=2, max_len=128)
    for i in range(5):
        eng.submit([1 + i, 2, 3], max_new_tokens=3)
    eng.tick()
    assert len(eng.queue) == 3             # two admitted, three waiting
    done = eng.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.output) == 3 for r in done)
    assert eng.stats["prefills"] == 5


@pytest.mark.parametrize("paged", [False, True])
def test_engine_bad_request_retires_with_error(dense, paged):
    """A request that can never prefill (oversized, bypassing submit()'s
    validation) is retired with ``req.error`` set; concurrently admitted
    requests are unaffected and the engine keeps draining (strict=False)."""
    import numpy as _np
    from repro.serve.engine import Request
    model, params = dense
    eng = ServeEngine(model, params, max_slots=3, max_len=128, paged=paged)
    eng.submit([5, 17, 33], max_new_tokens=4)
    eng.queue.append(Request(1000, _np.arange(200, dtype=_np.int32), 4))
    eng.submit([7, 8, 9], max_new_tokens=4)
    done = eng.run_until_drained()
    failed = [r for r in done if r.error is not None]
    assert [r.rid for r in failed] == [1000] and failed[0].done_at is not None
    assert isinstance(failed[0].error, ValueError)
    ok = sorted(r.rid for r in done if r.error is None)
    assert ok == [0, 1]
    assert all(len(r.output) == 4 for r in done if r.error is None)


@pytest.mark.parametrize("paged", [False, True])
def test_engine_empty_prompt_retires_with_error(dense, paged):
    """A zero-length prompt can never prefill: it must retire with
    ``req.error`` instead of hanging in the prefill state forever."""
    from repro.serve.engine import Request
    model, params = dense
    eng = ServeEngine(model, params, max_slots=2, max_len=128, paged=paged)
    eng.queue.append(Request(7, np.zeros(0, np.int32), 4))
    eng.submit([5, 6, 7], max_new_tokens=3)
    done = eng.run_until_drained(max_ticks=50)
    by_rid = {r.rid: r for r in done}
    assert isinstance(by_rid[7].error, ValueError)
    assert by_rid[0].error is None and len(by_rid[0].output) == 3
    if paged:
        assert eng.pool.pages_in_use == 0      # nothing leaked


def test_engine_strict_raises_on_bad_request(dense):
    import numpy as _np
    from repro.serve.engine import Request
    model, params = dense
    eng = ServeEngine(model, params, max_slots=3, max_len=128, strict=True)
    eng.submit([5, 17, 33], max_new_tokens=4)
    eng.queue.append(Request(1000, _np.arange(200, dtype=_np.int32), 4))
    eng.submit([7, 8, 9], max_new_tokens=4)
    with pytest.raises(RuntimeError,
                       match=r"prefill failed for request\(s\) \[1000\]"):
        eng.run_until_drained()
    # the failed request is retired with its error recorded, not lost
    failed = [r for r in eng.finished if r.error is not None]
    assert [r.rid for r in failed] == [1000]
    # healthy work was committed before the raise; draining completes it
    done = eng.run_until_drained()
    assert sorted(r.rid for r in done if r.error is None) == [0, 1]


def test_engine_close_releases_prefill_pool(dense):
    model, params = dense
    with ServeEngine(model, params, max_slots=2, max_len=128,
                     paged=False) as eng:
        eng.submit([1, 2, 3], max_new_tokens=2)
        eng.run_until_drained()
        assert eng._prefill_farm._pool is not None
    assert eng._prefill_farm._pool is None      # context exit shut it down
    # engine remains usable: pool transparently recreated
    eng.submit([4, 5], max_new_tokens=2)
    done = eng.run_until_drained()
    assert len(done) == 2


def test_bucket_boundaries():
    assert _bucket(1) == 32
    assert _bucket(32) == 32
    assert _bucket(33) == 64
    assert _bucket(512) == 512
    assert _bucket(4096) == 4096
    assert _bucket(4097) == 8192
    assert _bucket(8193) == 12288          # beyond the table: 4096 multiples


def test_per_request_sampler_override(dense):
    """A request carrying its own sampler is sampled with it while the rest
    of the batch keeps the engine default (greedy here)."""
    model, params = dense
    v = model.cfg.vocab
    const = lambda key, logits: jnp.asarray(7, jnp.int32)
    eng = ServeEngine(model, params, max_slots=2, max_len=128)
    eng.submit([5, 6, 7], max_new_tokens=4, sampler=const)
    eng.submit([9, 8, 7], max_new_tokens=4)
    done = eng.run_until_drained()
    by_rid = {r.rid: r.output for r in done}
    assert by_rid[0] == [7, 7, 7, 7]
    assert by_rid[1] == _reference_generate(model, params, [9, 8, 7], 4)


# ---------------------------------------------------------------------------
# sampling heads
# ---------------------------------------------------------------------------

def test_sampling_greedy_masks_padded_vocab():
    logits = jnp.zeros((1, 10)).at[0, 9].set(5.0)   # argmax in padded tail
    assert int(greedy(logits, true_vocab=8)[0]) < 8


def test_sample_top_k_respects_temperature_zero():
    logits = jnp.asarray([[0.0, 3.0, 1.0]])
    out = sample_top_k(jax.random.PRNGKey(0), logits, k=3, temperature=0.0)
    assert int(out[0]) == 1


def test_sample_top_k_distribution():
    logits = jnp.log(jnp.asarray([[0.7, 0.2, 0.1]]))
    keys = jax.random.split(jax.random.PRNGKey(0), 300)
    draws = np.asarray([int(sample_top_k(k, logits, k=3)[0]) for k in keys])
    freq = np.bincount(draws, minlength=3) / 300
    assert abs(freq[0] - 0.7) < 0.1


def test_sample_temperature_zero_is_greedy():
    logits = jnp.asarray([[0.0, 3.0, 1.0]])
    out = sample_temperature(jax.random.PRNGKey(0), logits, temperature=0.0)
    assert int(out[0]) == 1


def test_sample_temperature_distribution():
    logits = jnp.log(jnp.asarray([[0.6, 0.3, 0.1]]))
    keys = jax.random.split(jax.random.PRNGKey(1), 300)
    draws = np.asarray([int(sample_temperature(k, logits)[0]) for k in keys])
    freq = np.bincount(draws, minlength=3) / 300
    assert abs(freq[0] - 0.6) < 0.1


def test_sample_top_p_truncates_tail():
    """With p=0.5 only the 0.6-mass top token survives the nucleus."""
    logits = jnp.log(jnp.asarray([[0.6, 0.25, 0.15]]))
    keys = jax.random.split(jax.random.PRNGKey(2), 100)
    draws = {int(sample_top_p(k, logits, p=0.5)[0]) for k in keys}
    assert draws == {0}


def test_sample_top_p_keeps_nucleus():
    """p=0.8 keeps {0.6, 0.25} (the smallest prefix reaching 0.8) and drops
    the 0.15 tail token."""
    logits = jnp.log(jnp.asarray([[0.6, 0.25, 0.15]]))
    keys = jax.random.split(jax.random.PRNGKey(3), 200)
    draws = np.asarray([int(sample_top_p(k, logits, p=0.8)[0]) for k in keys])
    assert set(draws) == {0, 1}
    freq = np.bincount(draws, minlength=3) / 200
    assert abs(freq[0] - 0.6 / 0.85) < 0.12


def test_sample_top_p_masks_padded_vocab():
    logits = jnp.zeros((1, 8)).at[0, 7].set(9.0)
    keys = jax.random.split(jax.random.PRNGKey(4), 50)
    draws = {int(sample_top_p(k, logits, p=0.9, true_vocab=6)[0])
             for k in keys}
    assert all(d < 6 for d in draws)
