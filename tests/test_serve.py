"""Serving tests: continuous-batching engine correctness vs aligned decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.api import build_model
from repro.serve import ServeEngine
from repro.serve.sampling import greedy, sample_top_k


@pytest.fixture(scope="module")
def dense():
    cfg = smoke_config("qwen2-7b").replace(remat="none")
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _reference_generate(model, params, prompt, n_new, max_len=128):
    cfg = model.cfg
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    cache, hidden = jax.jit(lambda p, b: model.prefill(p, b, None, max_len))(
        params, {"tokens": toks})
    logits = model.lm_head(params, hidden[:, -1:], None)
    out = [int(greedy(logits, true_vocab=cfg.vocab)[0, -1])]
    pos = toks.shape[1]
    dec = jax.jit(lambda p, s, t, q: model.decode_step(p, s, t, q, None))
    for _ in range(n_new - 1):
        cache, logits = dec(params, cache,
                            jnp.asarray([[out[-1]]], jnp.int32),
                            jnp.asarray(pos, jnp.int32))
        out.append(int(greedy(logits, true_vocab=cfg.vocab)[0, -1]))
        pos += 1
    return out


def test_engine_matches_aligned_reference(dense):
    """Ragged continuous batching == one-request-at-a-time decoding."""
    model, params = dense
    prompts = [[5, 17, 33, 2, 9], [100, 200, 300], [7] * 11,
               [42, 41, 40, 39, 38, 37, 36]]
    want = [_reference_generate(model, params, p, 8) for p in prompts]
    eng = ServeEngine(model, params, max_slots=3, max_len=128)
    for p in prompts:
        eng.submit(p, max_new_tokens=8)
    done = eng.run_until_drained()
    got = {r.rid: r.output for r in done}
    for i, w in enumerate(want):
        assert got[i] == w, (i, got[i], w)
    # slots were reused: 4 requests through 3 slots
    assert eng.stats["prefills"] == 4


def test_engine_eos_stops_early(dense):
    model, params = dense
    ref = _reference_generate(model, params, [5, 6, 7], 16)
    eos = ref[3]
    eng = ServeEngine(model, params, max_slots=2, max_len=128)
    eng.submit([5, 6, 7], max_new_tokens=16, eos_id=eos)
    done = eng.run_until_drained()
    assert done[0].output[-1] == eos
    assert len(done[0].output) == 4


def test_engine_latency_stats(dense):
    model, params = dense
    eng = ServeEngine(model, params, max_slots=2, max_len=128)
    eng.submit([1, 2, 3], max_new_tokens=4)
    done = eng.run_until_drained()
    r = done[0]
    assert r.first_token_at >= r.submitted_at
    assert r.done_at >= r.first_token_at


def test_engine_rejects_oversized_prompt_at_submit(dense):
    model, params = dense
    eng = ServeEngine(model, params, max_slots=2, max_len=128)
    with pytest.raises(ValueError, match="prompt length 200"):
        eng.submit(list(range(200)), max_new_tokens=4)
    assert eng.queue == []                 # nothing was enqueued


def test_engine_bad_request_does_not_drop_concurrent_admits(dense):
    """One failing prefill must not lose the requests admitted concurrently
    with it (an unforeseen failure — submit()'s validation is bypassed)."""
    import numpy as _np
    from repro.serve.engine import Request
    model, params = dense
    eng = ServeEngine(model, params, max_slots=3, max_len=128)
    eng.submit([5, 17, 33], max_new_tokens=4)
    eng.queue.append(Request(1000, _np.arange(200, dtype=_np.int32), 4))
    eng.submit([7, 8, 9], max_new_tokens=4)
    with pytest.raises(RuntimeError,
                       match=r"prefill failed for request\(s\) \[1000\]"):
        eng.run_until_drained()
    # the failed request is retired with its error recorded, not lost
    failed = [r for r in eng.finished if r.error is not None]
    assert [r.rid for r in failed] == [1000] and failed[0].done_at is not None
    # the two good requests were admitted and can finish
    done = eng.run_until_drained()
    ok = sorted(r.rid for r in done if r.error is None)
    assert ok == [0, 1]
    assert all(len(r.output) == 4 for r in done if r.error is None)


def test_engine_close_releases_prefill_pool(dense):
    model, params = dense
    with ServeEngine(model, params, max_slots=2, max_len=128) as eng:
        eng.submit([1, 2, 3], max_new_tokens=2)
        eng.run_until_drained()
        assert eng._prefill_farm._pool is not None
    assert eng._prefill_farm._pool is None      # context exit shut it down
    # engine remains usable: pool transparently recreated
    eng.submit([4, 5], max_new_tokens=2)
    done = eng.run_until_drained()
    assert len(done) == 2


def test_sampling_greedy_masks_padded_vocab():
    logits = jnp.zeros((1, 10)).at[0, 9].set(5.0)   # argmax in padded tail
    assert int(greedy(logits, true_vocab=8)[0]) < 8


def test_sample_top_k_respects_temperature_zero():
    logits = jnp.asarray([[0.0, 3.0, 1.0]])
    out = sample_top_k(jax.random.PRNGKey(0), logits, k=3, temperature=0.0)
    assert int(out[0]) == 1


def test_sample_top_k_distribution():
    logits = jnp.log(jnp.asarray([[0.7, 0.2, 0.1]]))
    keys = jax.random.split(jax.random.PRNGKey(0), 300)
    draws = np.asarray([int(sample_top_k(k, logits, k=3)[0]) for k in keys])
    freq = np.bincount(draws, minlength=3) / 300
    assert abs(freq[0] - 0.7) < 0.1
